"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; prefill<->decode consistency for the
decode-capable families (this pins the SSD chunk-scan against the stepwise
recurrence and the KV cache against the training attention)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import list_archs, skip_reason
from repro.configs.reduced import reduced
from repro.models import build_model

ARCHS = [a for a in list_archs()]


def tiny_batch(cfg, key, batch=2, seq=32):
    ks = jax.random.split(key, 4)
    b = {}
    if cfg.family == "audio":
        b["frontend"] = jax.random.normal(ks[0], (batch, seq, 1024),
                                          jnp.bfloat16)
        b["labels"] = jax.random.randint(ks[1], (batch, seq), 0,
                                         cfg.vocab_size)
        b["mask"] = jax.random.bernoulli(ks[2], 0.3, (batch, seq))
        return b
    text = seq - cfg.frontend_tokens if cfg.frontend_tokens else seq
    b["tokens"] = jax.random.randint(ks[0], (batch, text), 0, cfg.vocab_size)
    b["labels"] = jax.random.randint(ks[1], (batch, text), 0, cfg.vocab_size)
    if cfg.frontend_tokens:
        b["frontend"] = jax.random.normal(
            ks[2], (batch, cfg.frontend_tokens, 1024), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCHS)
class TestSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = reduced(arch)
        model = build_model(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        batch = tiny_batch(cfg, jax.random.PRNGKey(1), batch=2,
                           seq=32 + cfg.frontend_tokens)

        def loss_fn(p):
            l, m = model.loss(p, batch)
            return l, m

        (loss, metrics), grads = jax.jit(
            jax.value_and_grad(loss_fn, has_aux=True))(params)
        assert np.isfinite(float(loss)), f"{arch}: loss not finite"
        assert float(loss) > 0
        # logits shape check
        logits, aux = jax.jit(
            lambda p: model.forward(p, batch.get("tokens"),
                                    batch.get("frontend"),
                                    batch.get("mask")))(params)
        b = 2
        s_total = (batch["frontend"].shape[1] if cfg.family == "audio"
                   else batch["tokens"].shape[1] + model.prefix_tokens)
        assert logits.shape == (b, s_total, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        # gradients flow to every leaf
        gnorms = jax.tree.map(
            lambda g: float(jnp.abs(g.astype(jnp.float32)).sum()), grads)
        flat = jax.tree.leaves(gnorms)
        assert all(np.isfinite(v) for v in flat)
        nonzero = sum(v > 0 for v in flat)
        assert nonzero >= len(flat) * 0.7, \
            f"{arch}: only {nonzero}/{len(flat)} grads nonzero"

    def test_prefill_decode_consistency(self, arch):
        if skip_reason(arch, "decode_32k"):
            pytest.skip(skip_reason(arch, "decode_32k"))
        cfg = reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        seq = 24
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, seq), 0,
                                  cfg.vocab_size)
        fe = (jax.random.normal(jax.random.PRNGKey(3),
                                (1, cfg.frontend_tokens, 1024), jnp.bfloat16)
              if cfg.frontend_tokens else None)

        # ground truth: full forward over all tokens
        full_logits, _ = jax.jit(
            lambda p: model.forward(p, toks, fe))(params)

        # prefill on the first seq-1 tokens, then one decode step
        prefill_logits, cache = jax.jit(
            lambda p: model.prefill(p, toks[:, : seq - 1], fe,
                                    max_len=seq + 4))(params)
        np.testing.assert_allclose(
            np.asarray(prefill_logits[:, 0]),
            np.asarray(full_logits[:, seq - 2 + model.prefix_tokens]),
            rtol=2e-2, atol=2e-2)

        step_logits, cache2 = jax.jit(
            lambda p, c: model.decode_step(p, c, toks[:, seq - 1:]))(
                params, cache)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]),
            np.asarray(full_logits[:, seq - 1 + model.prefix_tokens]),
            rtol=5e-2, atol=5e-2)
        assert int(cache2.length) == int(cache.length) + 1

    def test_param_count_close_to_analytic(self, arch):
        from repro.models.layers import count_params
        cfg = reduced(arch)
        model = build_model(cfg)
        actual = count_params(model.param_defs())
        analytic = cfg.num_params()
        # analytic formula ignores small bits (frontend proj, fuse norms...)
        assert abs(actual - analytic) / max(analytic, 1) < 0.25, \
            f"{arch}: actual {actual} vs analytic {analytic}"


class TestFullConfigsAbstract:
    """Full configs must *declare* cleanly (no allocation)."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_param_shapes_and_count(self, arch):
        from repro.config import get_model_config
        from repro.models.layers import count_params
        cfg = get_model_config(arch)
        model = build_model(cfg)
        n = count_params(model.param_defs())
        analytic = cfg.num_params()
        assert abs(n - analytic) / analytic < 0.1, \
            f"{arch}: declared {n/1e9:.2f}B vs analytic {analytic/1e9:.2f}B"

    def test_published_param_totals(self):
        """Sanity-pin the headline sizes of the named checkpoints."""
        from repro.config import get_model_config
        from repro.models.layers import count_params
        from repro.models import build_model as bm
        # NOTE: ranges pin the ASSIGNED specs (which are authoritative here),
        # not the hf checkpoints — e.g. the assigned moonshot spec says 48L
        # where the Moonlight-16B checkpoint has 27, so the assigned variant
        # is ~28B total (still 3B active).
        expect = {
            "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
            "moonshot-v1-16b-a3b": (26e9, 30e9),
            "glm4-9b": (8e9, 10.5e9),
            "phi3-medium-14b": (12e9, 15e9),
            "gemma2-9b": (8e9, 11e9),
            "yi-6b": (5.5e9, 7e9),
            "mamba2-2.7b": (2.4e9, 3.0e9),
            "hubert-xlarge": (0.8e9, 1.1e9),
            "hymba-1.5b": (1.2e9, 1.8e9),
            "llava-next-mistral-7b": (6.5e9, 8e9),
        }
        for arch, (lo, hi) in expect.items():
            n = count_params(bm(get_model_config(arch)).param_defs())
            assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}," \
                                  f" {hi/1e9}]B"
