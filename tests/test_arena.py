"""Batched arena tests: oracle equivalence, single-search stepping, and
refill/masking accounting (core/arena.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MCTSConfig
from repro.core.arena import Arena
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources, match, play_game

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
CAP = 20


@pytest.fixture(scope="module")
def players(engine5):
    a = MCTS(engine5, double_resources(CFG))
    b = MCTS(engine5, CFG)
    return a, b


@pytest.fixture(scope="module")
def oracle(engine5, players):
    a, b = players
    return jax.jit(lambda k, ab: play_game(engine5, a, b, k, ab, CAP))


def _assert_matches_oracle(oracle, recs, keys):
    """Every arena game must equal the sequential oracle bit-for-bit."""
    for i, r in enumerate(recs):
        want = oracle(keys[i], jnp.bool_(r.a_is_black))
        assert float(want.winner) == r.winner, i
        assert int(want.moves) == r.moves, i
        assert int(want.tree_nodes) == r.tree_nodes, i


class TestOracleEquivalence:
    @pytest.mark.slow
    def test_arena_matches_sequential_play_game(self, engine5, players,
                                                oracle):
        a, b = players
        arena = Arena(engine5, a, b, slots=4, max_moves=CAP)
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(7), 4))
        recs = arena.play_games(4, game_keys=keys)
        assert len(recs) == 4
        # both colour assignments exercised
        assert {r.a_is_black for r in recs} == {True, False}
        _assert_matches_oracle(oracle, recs, keys)


class TestSingleSearchPerMove:
    def test_one_search_per_game_per_step(self, engine5, players):
        """Per arena step the traced search batches cover each live game
        exactly once — G searched games for G moves, not the seed's 2G."""
        a, b = players
        searched = []

        def counting(player, tag):
            orig = player.search_batch

            def wrapped(roots, rngs):
                searched.append((tag, int(rngs.shape[0])))
                return orig(roots, rngs)
            player.search_batch = wrapped

        a2 = MCTS(engine5, double_resources(CFG))
        b2 = MCTS(engine5, CFG)
        counting(a2, "A")
        counting(b2, "B")
        G = 4
        arena = Arena(engine5, a2, b2, slots=G, max_moves=CAP)
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(0), G))
        slot = arena._initial_slots(jnp.asarray(keys))
        slot, rec = arena._step(slot, jnp.int32(0))
        jax.block_until_ready(rec.done)
        # the trace hit each player once, half the batch each
        assert sorted(searched) == [("A", G // 2), ("B", G // 2)]
        # ... and those G searches produced exactly G moves (one per slot)
        assert int(slot.states.move_count.sum()) == G


class TestRefillMasking:
    @pytest.mark.slow
    def test_refill_preserves_per_game_statistics(self, engine5, players,
                                                  oracle):
        """More games than slots: finished slots refill from the pending
        queue, and every game's (winner, length, nodes) still equals the
        sequential oracle under its recorded colour."""
        a, b = players
        arena = Arena(engine5, a, b, slots=2, max_moves=CAP)
        games = 5
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), games))
        recs = arena.play_games(games, game_keys=keys)
        assert len(recs) == games
        assert all(r.winner in (-1.0, 0.0, 1.0) for r in recs)
        assert all(0 < r.moves <= CAP for r in recs)
        # colour balance holds under refills (paper: alternating colours)
        n_black = sum(r.a_is_black for r in recs)
        assert abs(n_black - (games - n_black)) <= 1
        _assert_matches_oracle(oracle, recs, keys)

    def test_match_accounting_with_refills(self, engine5):
        cfg = dataclasses.replace(CFG, sims_per_move=8)
        res = match(engine5, double_resources(cfg), cfg, games=5, seed=2,
                    max_moves=CAP, batch=2)
        assert res.a_wins + res.b_wins + res.draws == 5
        assert res.rate.games == 5
        assert 0.0 <= res.rate.lo <= res.rate.rate <= res.rate.hi <= 1.0


class TestArenaValidation:
    def test_odd_slots_rejected(self, engine5, players):
        a, b = players
        with pytest.raises(ValueError):
            Arena(engine5, a, b, slots=3)

    def test_bad_game_keys_shape_rejected(self, engine5, players):
        a, b = players
        arena = Arena(engine5, a, b, slots=2, max_moves=CAP)
        with pytest.raises(ValueError):
            arena.play_games(2, game_keys=np.zeros((3, 2), np.uint32))
