"""Property suite for the go/board.py flood-fill invariants.

The PR 8 rewrite replaced the data-dependent ``while_loop`` flood fills
(``group_info``, ``_reach``) with a static-trip-count min-label fixpoint
(``_min_label_components``).  These properties pin the rules against an
independent pure-Python BFS reference so the reshape cannot silently
change them:

* group ids are a partition rooted at the minimum same-colour index;
* per-stone liberty counts equal the BFS reference exactly;
* ``_reach`` / ``score`` agree with BFS reachability;
* capture / suicide / ko legality agrees with a semantic reference
  (place, resolve captures, then test the placed group's liberties);
* adversarial serpentine / comb / long-corridor boards — the topologies
  that maximise label-propagation diameter — still converge within the
  engine's static ``label_rounds`` bound.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.go import GoEngine
from repro.go.board import GoState, NO_KO

try:                                    # property tier (CI installs .[test])
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    SETTINGS = dict(max_examples=15, deadline=None,
                    suppress_health_check=list(hypothesis.HealthCheck))
except ImportError:                     # seeded-sweep tier still runs
    hypothesis = None


# ----------------------------------------------------- pure-Python reference


def _nbrs(p, size):
    r, c = divmod(p, size)
    out = []
    if r > 0:
        out.append(p - size)
    if r < size - 1:
        out.append(p + size)
    if c > 0:
        out.append(p - 1)
    if c < size - 1:
        out.append(p + 1)
    return out


def bfs_groups(board, size):
    """(ids, libs): min-index group roots + exact per-group liberties."""
    n2 = size * size
    ids = np.full(n2, n2, np.int32)
    libs = np.zeros(n2, np.int32)
    seen = set()
    for p in range(n2):
        if board[p] == 0 or p in seen:
            continue
        comp, q = [p], [p]
        seen.add(p)
        while q:
            u = q.pop()
            for v in _nbrs(u, size):
                if board[v] == board[p] and v not in seen:
                    seen.add(v)
                    comp.append(v)
                    q.append(v)
        lib = {v for u in comp for v in _nbrs(u, size) if board[v] == 0}
        for u in comp:
            ids[u] = min(comp)
            libs[u] = len(lib)
    return ids, libs


def bfs_reach(board, size, color):
    """Cells reachable from ``color`` stones through empty cells."""
    mask = board == color
    frontier = list(np.nonzero(mask)[0])
    while frontier:
        u = frontier.pop()
        for v in _nbrs(u, size):
            if board[v] == 0 and not mask[v]:
                mask[v] = True
                frontier.append(v)
    return mask


def ref_play(board, size, p, me):
    """Place ``me`` at empty ``p``; resolve captures.  Returns the new
    board, or None if the move is suicide."""
    b = board.copy()
    b[p] = me
    _, libs = bfs_groups(b, size)
    captured = (b == -me) & (libs == 0)
    b[captured] = 0
    _, libs = bfs_groups(b, size)
    if libs[p] == 0:
        return None
    return b


def ref_legal(board, size, me, ko):
    """Semantic legality: empty, not the ko point, and not suicide."""
    n2 = size * size
    out = np.zeros(n2 + 1, bool)
    out[n2] = True                                    # pass
    for p in range(n2):
        if board[p] != 0 or p == ko:
            continue
        out[p] = ref_play(board, size, p, me) is not None
    return out


def _state(board, me=1, ko=NO_KO):
    return GoState(board=jnp.asarray(board), to_play=jnp.int8(me),
                   ko=jnp.int32(ko), pass_count=jnp.int32(0),
                   move_count=jnp.int32(0), done=jnp.bool_(False))


# --------------------------------------------- seeded sweep (always runs)


class TestSeededSweepVsBFS:
    """Deterministic random-board sweep against the BFS reference —
    independent of hypothesis so bare containers still pin the rules."""

    @pytest.mark.parametrize("size,boards", [(5, 20), (9, 6)])
    def test_groups_libs_reach_score(self, size, boards):
        rng = np.random.default_rng(size)
        eng = GoEngine(size)
        for _ in range(boards):
            board = rng.choice(np.int8([0, 1, -1]),
                               size=size * size).astype(np.int8)
            ids, libs = map(np.asarray, eng.group_info(jnp.asarray(board)))
            rids, rlibs = bfs_groups(board, size)
            np.testing.assert_array_equal(ids, rids)
            np.testing.assert_array_equal(libs, rlibs)
            rb = bfs_reach(board.copy(), size, 1)
            rw = bfs_reach(board.copy(), size, -1)
            np.testing.assert_array_equal(
                np.asarray(eng._reach(jnp.asarray(board), 1)), rb)
            np.testing.assert_array_equal(
                np.asarray(eng._reach(jnp.asarray(board), -1)), rw)
            empty = board == 0
            want = ((board == 1).sum() + (empty & rb & ~rw).sum()
                    - (board == -1).sum() - (empty & rw & ~rb).sum())
            assert float(eng.score(jnp.asarray(board))) == float(want)

    def test_legality_and_capture_sweep(self):
        rng = np.random.default_rng(7)
        eng = GoEngine(5)
        for _ in range(12):
            board = rng.choice(np.int8([0, 1, -1]), size=25).astype(np.int8)
            me = int(rng.choice([1, -1]))
            ko = int(rng.integers(-1, 25))
            got = np.asarray(eng.legal_moves(_state(board, me, ko)))
            want = ref_legal(board, 5, me, ko)
            np.testing.assert_array_equal(got, want)
            pts = np.nonzero(want[:25])[0]
            if pts.size:
                p = int(rng.choice(pts))
                nxt = eng.play(_state(board, me), jnp.int32(p))
                np.testing.assert_array_equal(np.asarray(nxt.board),
                                              ref_play(board, 5, p, me))

    def test_simple_ko_cycle(self):
        """The canonical ko: recapture is forbidden immediately, allowed
        after a tenuki elsewhere."""
        eng = GoEngine(5)
        #  . X O .
        #  X . . O   <- black plays 6 capturing nothing; build ko shape:
        b = np.zeros(25, np.int8)
        # black: 1, 5, 11, 7; white: 2, 8, 12 -> white 6 is in atari mirror
        for p in (1, 5, 11):
            b[p] = 1
        for p in (2, 8, 12):
            b[p] = -1
        b[6] = -1                     # white stone in the ko mouth
        state = _state(b, me=1)
        nxt = eng.play(state, jnp.int32(7))    # black captures at 7
        assert int(nxt.ko) == 6                # ko point set
        legal = np.asarray(eng.legal_moves(nxt))
        assert not legal[6]                    # immediate recapture illegal
        # after a pass the ko lifts
        lifted = eng.play(nxt, jnp.int32(eng.pass_action))
        assert int(lifted.ko) == NO_KO


# --------------------------------------------------- adversarial topologies


def snake_board(size, fill):
    """Boustrophedon snake of BLACK (path-graph topology, diameter n2) on
    a ``fill`` background — the label-propagation worst case."""
    b = np.full((size, size), fill, np.int8)
    b[::2, :] = 1
    for r in range(1, size, 2):
        b[r, size - 1 if (r // 2) % 2 == 0 else 0] = 1
    return b.reshape(-1)


def comb_board(size):
    """Spine column + every-other-row teeth: one group, many liberties."""
    b = np.zeros((size, size), np.int8)
    b[:, 0] = 1
    b[::2, :] = 1
    return b.reshape(-1)


def corridor_board(size):
    """Empty snake corridor walled by WHITE with a single BLACK seed at
    the far end — worst case for ``_reach`` (one seed, full diameter)."""
    b = np.where(snake_board(size, -1) == 1, 0, -1).astype(np.int8)
    # seed: one black stone on the corridor's tail cell — reach must then
    # propagate the full path length to cover the rest
    b[(size - 1) * size] = 1
    return b


class TestAdversarialConvergence:
    @pytest.mark.parametrize("size", [5, 9, 13])
    @pytest.mark.parametrize("maker", [lambda s: snake_board(s, -1),
                                       lambda s: snake_board(s, 0),
                                       comb_board])
    def test_groups_converge_on_diameter_maximisers(self, size, maker):
        board = maker(size)
        eng = GoEngine(size)
        ids, libs = map(np.asarray, eng.group_info(jnp.asarray(board)))
        rids, rlibs = bfs_groups(board, size)
        np.testing.assert_array_equal(ids, rids)
        np.testing.assert_array_equal(libs, rlibs)

    @pytest.mark.parametrize("size", [5, 9, 13])
    def test_reach_traverses_full_corridor(self, size):
        board = corridor_board(size)
        eng = GoEngine(size)
        got = np.asarray(eng._reach(jnp.asarray(board), 1))
        want = bfs_reach(board.copy(), size, 1)
        np.testing.assert_array_equal(got, want)
        # the corridor really is traversed end to end
        assert got[board == 0].all()


# ------------------------------------------------ hypothesis tier (optional)


if hypothesis is not None:
    @st.composite
    def random_board(draw, size=5):
        cells = draw(st.lists(st.sampled_from([0, 1, -1]),
                              min_size=size * size, max_size=size * size))
        return np.array(cells, np.int8)

    class TestFloodFillProperties:
        @settings(**SETTINGS)
        @given(random_board())
        def test_group_ids_partition_and_libs(self, board):
            """Labels are the BFS partition (min-index roots) and liberty
            counts are exact — for every cell, not just statistically."""
            eng = GoEngine(5)
            ids, libs = map(np.asarray, eng.group_info(jnp.asarray(board)))
            rids, rlibs = bfs_groups(board, 5)
            np.testing.assert_array_equal(ids, rids)
            np.testing.assert_array_equal(libs, rlibs)

        @settings(**SETTINGS)
        @given(random_board(size=9))
        def test_group_info_size9(self, board):
            eng = GoEngine(9)
            ids, libs = map(np.asarray, eng.group_info(jnp.asarray(board)))
            rids, rlibs = bfs_groups(board, 9)
            np.testing.assert_array_equal(ids, rids)
            np.testing.assert_array_equal(libs, rlibs)

        @settings(**SETTINGS)
        @given(random_board())
        def test_reach_and_score(self, board):
            eng = GoEngine(5)
            for color in (1, -1):
                got = np.asarray(eng._reach(jnp.asarray(board), color))
                np.testing.assert_array_equal(
                    got, bfs_reach(board.copy(), 5, color))
            rb = bfs_reach(board.copy(), 5, 1)
            rw = bfs_reach(board.copy(), 5, -1)
            empty = board == 0
            want = ((board == 1).sum() + (empty & rb & ~rw).sum()
                    - (board == -1).sum() - (empty & rw & ~rb).sum())
            assert float(eng.score(jnp.asarray(board))) == float(want)

    class TestLegalityProperties:
        @settings(**SETTINGS)
        @given(random_board(), st.sampled_from([1, -1]),
               st.integers(-1, 24))
        def test_capture_suicide_ko_agree(self, board, me, ko):
            """The engine's liberty-precomputed legality formula equals
            the semantic place-capture-check reference on arbitrary
            positions, any player to move, any ko point."""
            eng = GoEngine(5)
            got = np.asarray(eng.legal_moves(_state(board, me, ko)))
            np.testing.assert_array_equal(got, ref_legal(board, 5, me, ko))

        @settings(**SETTINGS)
        @given(random_board(), st.sampled_from([1, -1]))
        def test_play_resolves_captures_like_reference(self, board, me):
            """Playing any legal point move produces the reference board
            (placement + capture removal)."""
            eng = GoEngine(5)
            state = _state(board, me)
            legal = np.asarray(eng.legal_moves(state))[:25]
            if not legal.any():
                return
            p = int(np.nonzero(legal)[0][0])
            nxt = eng.play(state, jnp.int32(p))
            np.testing.assert_array_equal(np.asarray(nxt.board),
                                          ref_play(board, 5, p, me))
            assert int(nxt.to_play) == -me
