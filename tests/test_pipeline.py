"""Streaming dispatch pipeline tests (core/streaming.py + service views).

The PR 5 tentpole contracts:

* **depth invariance** — a submit-then-drain workload returns the exact
  result sequence at any ``pipeline_depth`` (the device program never
  depends on host read timing), under ``mesh=None`` and on faked
  multi-device meshes;
* **depth 1 is the synchronous path** — ``drain()`` at the default depth
  reproduces the explicit flush -> dispatch -> poll loop bit for bit,
  including the host-sync count;
* **accounting** — ``submitted == completed + in_flight`` at every
  reconcile;
* **overflow** — a host that polls too late gets a RuntimeError, never a
  silently overwritten ring row;
* **staleness** — views issued before a ``reset()`` are evicted/refused;
* **multi-hop rebalance** — the doubling hop schedule reaches shard
  ``i+2`` on the second superstep where the PR 3 one-hop ring cannot;
* **placement estimates** — landed results shift load comparisons but
  never the hard capacity gate.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_service_mesh
from repro.config import MCTSConfig
from repro.core import placement
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources
from repro.core.service import SearchService
from repro.core.streaming import DispatchPipeline

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
CAP = 12
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def players(engine5):
    return MCTS(engine5, double_resources(CFG)), MCTS(engine5, CFG)


@pytest.fixture(scope="module")
def mid_state(engine5):
    st = engine5.init_state()
    for mv in (3, 7, 12, 16):
        st = engine5.jit_play(st, jnp.int32(mv))
    return st


def _submit_mixed(svc, games, serves, mid_state, seed=0):
    """Reset + queue a fixed mixed workload; returns the tickets."""
    svc.reset(seed=seed, colour_cap=(games + 1) // 2 or 1,
              game_capacity=max(2, games))
    gk = np.asarray(jax.random.split(jax.random.PRNGKey(7), max(1, games)))
    sk = np.asarray(jax.random.split(jax.random.PRNGKey(9), max(1, serves)))
    tickets = [svc.submit_game(key=gk[i]) for i in range(games)]
    tickets += [svc.submit_serve(mid_state, key=sk[i])
                for i in range(serves)]
    return tickets


def _assert_same_results(want, got):
    """Full-sequence equality: order, every scalar field, visits."""
    assert [r.ticket for r in want] == [r.ticket for r in got]
    for w, g in zip(want, got):
        assert w[:7] == g[:7]
        assert w.finished_step == g.finished_step
        np.testing.assert_array_equal(w.root_visits, g.root_visits)


class TestDepthInvariance:
    def test_depth4_bit_identical_to_sync(self, engine5, players,
                                          mid_state):
        """The acceptance pin: pipeline_depth=1 vs >1 drain the identical
        result sequence (tickets, scalars, visit distributions, even the
        completion-step stamps) under mesh=None."""
        runs = {}
        for depth in (1, 4):
            svc = SearchService(engine5, *players, slots=4, max_moves=CAP,
                                pipeline_depth=depth)
            tickets = _submit_mixed(svc, 5, 3, mid_state)
            recs = svc.drain()
            assert sorted(r.ticket for r in recs) == sorted(tickets)
            assert svc.last_drain_stats["max_in_flight"] == depth
            runs[depth] = recs
        _assert_same_results(runs[1], runs[4])
        # single shard: ring FIFO == device completion order, so the
        # finished_step stamps are monotone across the whole drain
        steps = [r.finished_step for r in runs[4]]
        assert steps == sorted(steps)

    def test_depth1_pipeline_is_the_sync_loop(self, engine5, players,
                                              mid_state):
        """drain() at depth 1 == the explicit PR 4 superstep loop, bit
        for bit including the blocking-sync count."""
        a, b = players
        manual = SearchService(engine5, a, b, slots=4, max_moves=CAP)
        _submit_mixed(manual, 4, 2, mid_state)
        manual.flush()
        want = []
        while manual.outstanding > 0:
            manual.dispatch()
            want.extend(manual.poll())

        piped = SearchService(engine5, a, b, slots=4, max_moves=CAP)
        _submit_mixed(piped, 4, 2, mid_state)
        got = piped.drain()
        _assert_same_results(want, got)
        assert piped.host_syncs == manual.host_syncs

    def test_pipeline_depth_validation(self, engine5, players):
        a, b = players
        with pytest.raises(ValueError):
            SearchService(engine5, a, b, slots=2, pipeline_depth=0)
        svc = SearchService(engine5, a, b, slots=2)
        with pytest.raises(ValueError):
            DispatchPipeline(svc, depth=-1)
        with pytest.raises(ValueError):
            DispatchPipeline(svc, depth=0)    # must not fall back to default
        with pytest.raises(ValueError):
            DispatchPipeline(svc, steps=0)


class TestPipelineMechanics:
    def test_accounting_invariant_every_reconcile(self, engine5, players,
                                                  mid_state):
        """submitted == completed + in-flight at every reconcile, and the
        window never exceeds the configured depth."""
        svc = SearchService(engine5, *players, slots=4, max_moves=CAP,
                            pipeline_depth=3)
        _submit_mixed(svc, 6, 2, mid_state)
        pipe = DispatchPipeline(svc)
        svc.flush()
        got = []
        while svc.outstanding > 0:
            pipe.pump()
            assert pipe.in_flight_supersteps <= 3
            got.extend(pipe.reconcile(block=True))  # raises on drift
            submitted, completed, in_flight = svc.accounting()
            assert submitted == completed + in_flight
            assert submitted == 8
        assert len(got) == 8
        assert pipe.reconciles > 0
        assert pipe.stats()["max_in_flight"] == 3

    def test_ring_overflow_raises_when_host_polls_late(self, engine5,
                                                       players, mid_state):
        """A deep window over a tiny ring must fail loudly on reconcile,
        not silently overwrite unread results."""
        a, _ = players
        svc = SearchService(engine5, a, a, slots=4, max_moves=CAP,
                            superstep=4, pipeline_depth=4)
        svc.reset(seed=0, serve_capacity=16, ring_capacity=4)
        sk = np.asarray(jax.random.split(jax.random.PRNGKey(2), 12))
        for i in range(12):
            svc.submit_serve(mid_state, key=sk[i])
        pipe = DispatchPipeline(svc)
        pipe.pump()                       # 4 supersteps in flight, no polls
        with pytest.raises(RuntimeError, match="overflowed"):
            pipe.reconcile(block=True)

    def test_out_of_order_view_is_harmless(self, engine5, players,
                                           mid_state):
        """Polling an older view after a newer one must be a no-op — the
        read cursor never rolls backward into duplicate delivery."""
        a, _ = players
        svc = SearchService(engine5, a, a, slots=4, max_moves=CAP,
                            superstep=1, pipeline_depth=2)
        svc.reset(seed=0)
        sk = np.asarray(jax.random.split(jax.random.PRNGKey(3), 6))
        tickets = [svc.submit_serve(mid_state, key=sk[i]) for i in range(6)]
        svc.flush()
        v1 = svc.dispatch_async()         # 2 serves complete (2 A-cells)
        v2 = svc.dispatch_async()         # 2 more
        newer = svc.poll(view=v2)
        assert len(newer) == 4
        assert svc.poll(view=v1) == []    # older view: already drained
        rest = svc.drain()
        assert sorted(r.ticket for r in newer + rest) == tickets

    def test_stale_views_evicted_on_reset(self, engine5, players,
                                          mid_state):
        svc = SearchService(engine5, *players, slots=4, max_moves=CAP,
                            pipeline_depth=2)
        _submit_mixed(svc, 0, 2, mid_state)
        pipe = DispatchPipeline(svc)
        svc.flush()
        pipe.pump()
        view = svc.dispatch_async()
        svc.reset(seed=1)
        assert pipe.reconcile(block=True) == []      # window evicted
        assert pipe.in_flight_supersteps == 0
        with pytest.raises(RuntimeError, match="stale"):
            svc.poll(view=view)


class TestPlacementEstimates:
    def test_landed_estimate_shifts_load_comparison(self):
        """A shard whose results landed (but were not yet polled) looks
        less loaded to the least-loaded policies — per request class."""
        pol = placement.PlacementPolicy("colour_balanced", 2)
        assert [pol.choose(placement.CLS_GAME, 8) for _ in range(3)] \
            == [0, 1, 0]                  # raw in-flight now [2, 1]
        landed = np.zeros((2, 2), np.int64)
        landed[placement.CLS_GAME, 0] = 2  # shard 0's games finished
        pol.note_landed(landed)
        assert pol.choose(placement.CLS_GAME, 8) == 0   # estimate wins
        pol.release(placement.CLS_GAME, 0)
        assert pol.landed[placement.CLS_GAME, 0] == 1   # poll retires one

    def test_landed_estimate_is_class_aware(self):
        """Landed serve results must not make a shard's *games* look
        done: the estimate is classified per request class."""
        pol = placement.PlacementPolicy("colour_balanced", 2)
        assert [pol.choose(placement.CLS_GAME, 8) for _ in range(5)] \
            == [0, 1, 0, 1, 0]            # games in flight [3, 2]
        landed = np.zeros((2, 2), np.int64)
        landed[placement.CLS_SERVE, 0] = 3   # only serves landed there
        pol.note_landed(landed)
        assert pol.choose(placement.CLS_GAME, 8) == 1   # still least-loaded

    def test_capacity_gate_ignores_estimates(self):
        """Estimates re-order shards but can never oversubscribe the hard
        per-shard in-flight cap (device queues must not overflow)."""
        pol = placement.PlacementPolicy("colour_balanced", 1)
        assert pol.choose(placement.CLS_GAME, 2) == 0
        assert pol.choose(placement.CLS_GAME, 2) == 0
        landed = np.zeros((2, 1), np.int64)
        landed[placement.CLS_GAME, 0] = 2
        pol.note_landed(landed)
        assert pol.choose(placement.CLS_GAME, 2) is None


class TestGoServicePipelined:
    def test_streaming_answers_equal_sync(self):
        """Pipelined serving returns bit-identical moves (the serve RNG
        contract is read-timing independent)."""
        from repro.serving.go_service import GoService
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(5), 4))
        boards = []
        for i in range(4):
            b = np.zeros(25, np.int8)
            b[5 + 3 * i] = 1
            boards.append(b)

        def serve(depth):
            svc = GoService(board_size=5, komi=0.5, max_sims=8, lanes=2,
                            slots=4, seed=0, pipeline_depth=depth)
            tickets = [svc.submit(b, to_play=-1, key=keys[i])
                       for i, b in enumerate(boards)]
            svc.flush()
            return [svc.result(t) for t in tickets]

        want, got = serve(1), serve(3)
        for w, g in zip(want, got):
            assert (w.action, w.coord, w.is_pass) == \
                (g.action, g.coord, g.is_pass)
            np.testing.assert_array_equal(w.root_visits, g.root_visits)


@multidevice
class TestPipelineMesh:
    """In-process multi-device coverage (CI: the test-multidevice job)."""

    def test_depth4_bit_identical_on_4_shards(self, engine5, players,
                                              mid_state):
        runs = {}
        for depth in (1, 4):
            svc = SearchService(engine5, *players, slots=8, max_moves=CAP,
                                mesh=make_service_mesh(4),
                                pipeline_depth=depth)
            tickets = _submit_mixed(svc, 6, 3, mid_state)
            recs = svc.drain()
            assert sorted(r.ticket for r in recs) == sorted(tickets)
            runs[depth] = recs
        _assert_same_results(runs[1], runs[4])

    def test_multihop_reaches_hop2_in_two_supersteps(self, engine5,
                                                     players):
        """The doubling schedule donates straight to shard i+2 on its
        second superstep; the one-hop ring provably cannot (its only
        path to shard 2 chains through shard 1's backlog)."""
        a, b = players

        def probe(multihop):
            svc = SearchService(engine5, a, b, slots=8, max_moves=CAP,
                                mesh=make_service_mesh(4),
                                placement="fill_first", multihop=multihop)
            svc.reset(seed=0, colour_cap=3, game_capacity=6)
            for _ in range(6):
                svc.submit_game()
            svc.flush()
            svc.dispatch(steps=1)         # rebalance hop 1
            svc.dispatch(steps=1)         # hop 2 (multihop) / 1 (single)
            sizes = np.asarray(jax.device_get(svc._pool.games.size))
            recs = svc.drain()
            return sizes, len(recs)

        multi_sizes, multi_n = probe(True)
        single_sizes, single_n = probe(False)
        assert multi_n == single_n == 6   # both drain completely
        assert multi_sizes[2] > 0         # hop-2 donation landed
        assert single_sizes[2] == 0       # one-hop ring: not yet


@pytest.mark.slow
class TestPipelineSubprocess:
    """8-fake-device depth invariance for single-device tier-1 runs."""

    def test_depth_invariance_8_fake_devices(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                            + env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
assert jax.device_count() == 8
from repro.compat import make_service_mesh
from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources
from repro.core.service import SearchService
from repro.go import GoEngine

eng = GoEngine(5, komi=0.5)
cfg = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
a, b = MCTS(eng, double_resources(cfg)), MCTS(eng, cfg)
keys = np.asarray(jax.random.split(jax.random.PRNGKey(7), 8))

def run(depth):
    svc = SearchService(eng, a, b, slots=8, max_moves=10,
                        mesh=make_service_mesh(4), pipeline_depth=depth)
    svc.reset(seed=0, colour_cap=4, game_capacity=8)
    for i in range(8):
        svc.submit_game(key=keys[i])
    return svc.drain()

r1, r4 = run(1), run(4)
assert [r.ticket for r in r1] == [r.ticket for r in r4]
for w, g in zip(r1, r4):
    assert w[:7] == g[:7] and w.finished_step == g.finished_step
    np.testing.assert_array_equal(w.root_visits, g.root_visits)
print("OK", len(r1))
"""], env=env, capture_output=True, text=True, timeout=480)
        assert out.returncode == 0, \
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
