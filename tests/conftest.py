"""Shared fixtures.

NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
benchmarks must see the single real CPU device.  Multi-device tests spawn
subprocesses that set ``--xla_force_host_platform_device_count`` themselves.
"""
import jax
import pytest


def pytest_configure(config):
    # registered in pyproject.toml too; kept here so a bare pytest
    # invocation from any rootdir still knows the tier marker
    config.addinivalue_line(
        "markers",
        "slow: long-running integration/substrate tests (excluded from the "
        "CI fast tier; run locally with plain pytest)")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def engine5():
    from repro.go import GoEngine
    return GoEngine(5, komi=0.5)


@pytest.fixture(scope="session")
def engine9():
    from repro.go import GoEngine
    return GoEngine(9, komi=6.0)
