"""League service tests: adaptive scheduling, crash/resume, colour balance.

The PR 9 tentpole contracts:

* **forced-colour admission** — ``submit_game(a_black=...)`` is honoured
  exactly (the result's ``a_is_black`` equals the forced demand), FIFO
  order and the aggregate colour cap included;
* **adaptive league** — a tiny 3-config league separates its cross
  table at the target confidence, stops funding resolved pairings, and
  keeps every pairing's Black/White ledger within +-1;
* **kill/resume bit-identity** — ``PreemptionHandler.trigger()``
  mid-schedule, restart from the wave-boundary snapshot, and the final
  cross table (win matrix, game counts, colour ledger) is identical to
  an uninterrupted run; torn snapshots fall back to the previous wave;
* **tournament colour balance** — the multiplexed all-play-all path
  restores the strict per-pairing +-1 balance the PR 4 aggregate cap
  had weakened, under both ``mesh=None`` and the 8-faked-device mesh
  (CI's test-multidevice job).
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro.compat import make_service_mesh
from repro.config import MCTSConfig
from repro.core.league import League, LeagueResult, game_key
from repro.core.mcts import MCTS
from repro.core.service import SearchService
from repro.core.tournament import Tournament

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
# distinct playout budgets double as config identity in the submission
# log (the colour-balance tests recover the pairing from the sims pair)
# and as a real strength ladder the league can actually separate
CONFIGS = (CFG,
           dataclasses.replace(CFG, sims_per_move=4, c_uct=0.8),
           dataclasses.replace(CFG, sims_per_move=2, c_uct=2.0))
# long enough for 5x5 games to mostly finish naturally: a tighter cap
# scores half-played boards and flattens the strength ladder the
# convergence tests rely on
MOVE_CAP = 30

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def league(engine5, **kw) -> League:
    kw.setdefault("z", 1.0)
    kw.setdefault("budget", 16)
    kw.setdefault("games_per_wave", 2)
    kw.setdefault("seed", 3)
    kw.setdefault("max_moves", MOVE_CAP)
    return League(engine5, CONFIGS, **kw)


def cross_table(res: LeagueResult) -> tuple:
    return (res.win_matrix.tolist(), res.games.tolist(),
            res.blacks.tolist())


class TestForcedColourAdmission:
    def test_forced_colours_honoured_exactly(self, engine5):
        """Each game's a_is_black equals its forced demand, in order."""
        player = MCTS(engine5, CFG)
        svc = SearchService(engine5, player, player, 4,
                            max_moves=MOVE_CAP)
        forced = [True, False, True, False, False, True]
        svc.reset(seed=0, colour_cap=3, game_capacity=len(forced),
                  ring_capacity=len(forced) + 4)
        tickets = [svc.submit_game(a_black=f) for f in forced]
        got = {r.ticket: r.a_is_black for r in svc.drain()}
        assert [got[t] for t in tickets] == forced

    def test_free_submissions_unchanged(self, engine5):
        """a_black=None keeps the cell-assigned +-1 colour discipline."""
        player = MCTS(engine5, CFG)
        svc = SearchService(engine5, player, player, 4,
                            max_moves=MOVE_CAP)
        svc.reset(seed=0, colour_cap=3, game_capacity=6,
                  ring_capacity=10)
        for _ in range(6):
            svc.submit_game()
        colours = [r.a_is_black for r in svc.drain()]
        assert abs(sum(colours) - 3) <= 1


class TestLeague:
    @pytest.fixture(scope="class")
    def converged(self, engine5) -> LeagueResult:
        return league(engine5, budget=40).run()

    def test_converges_at_confidence(self, converged):
        assert converged.converged
        est = converged.elo
        for (i, j) in ((0, 1), (0, 2), (1, 2)):
            assert est.separated(i, j)

    def test_adaptive_stops_funding_resolved_pairings(self, converged):
        # adaptive focus: not every pairing gets the same games (the
        # round-robin degenerate) unless all separated on the same wave
        per_pair = [converged.games[i, j]
                    for (i, j) in ((0, 1), (0, 2), (1, 2))]
        assert converged.games_played < 40           # beat the budget
        assert min(per_pair) >= 2                    # everyone played
        assert len(set(per_pair)) > 1                # focus happened

    def test_colour_ledger_strictly_balanced(self, converged):
        for (i, j) in ((0, 1), (0, 2), (1, 2)):
            assert abs(converged.blacks[i, j]
                       - converged.blacks[j, i]) <= 1
            assert (converged.blacks[i, j] + converged.blacks[j, i]
                    == converged.games[i, j])

    def test_cross_table_consistent(self, converged):
        assert np.array_equal(converged.games, converged.games.T)
        np.testing.assert_allclose(
            converged.win_matrix + converged.win_matrix.T,
            converged.games)

    def test_rejects_static_shape_mix(self, engine5):
        bad = CONFIGS[:2] + (dataclasses.replace(CFG, lanes=4),)
        with pytest.raises(ValueError, match="trace-compatible"):
            League(engine5, bad)

    def test_game_keys_are_pure(self):
        a = game_key(3, 0, 1, 5)
        assert np.array_equal(a, game_key(3, 0, 1, 5))
        assert not np.array_equal(a, game_key(3, 0, 1, 6))
        assert not np.array_equal(a, game_key(3, 0, 2, 5))


class TestKillResume:
    @pytest.fixture(scope="class")
    def reference(self, engine5) -> LeagueResult:
        """The uninterrupted run every resume variant must reproduce."""
        return league(engine5).run()

    def test_resume_reproduces_cross_table(self, engine5, reference,
                                           tmp_path_factory):
        sd = str(tmp_path_factory.mktemp("league_state"))
        lg = league(engine5, state_dir=sd)
        lg.on_wave = lambda rec: (rec["wave"] >= 2
                                  and lg.preemption.trigger())
        part = lg.run()
        assert part.stopped and part.waves == 2
        assert part.games_played < reference.games_played

        resumed = league(engine5, state_dir=sd, resume=True).run()
        assert cross_table(resumed) == cross_table(reference)
        assert resumed.waves == reference.waves
        assert resumed.games_played == reference.games_played

    def test_torn_snapshot_falls_back(self, engine5, reference, tmp_path):
        sd = str(tmp_path)
        lg = league(engine5, state_dir=sd)
        lg.on_wave = lambda rec: (rec["wave"] >= 2
                                  and lg.preemption.trigger())
        lg.run()
        snaps = sorted(f for f in os.listdir(sd) if f.endswith(".json"))
        assert len(snaps) == 2
        # tear the newest snapshot mid-write
        newest = os.path.join(sd, snaps[-1])
        torn = open(newest).read()[:40]
        with open(newest, "w") as f:
            f.write(torn)
        restored = league(engine5, state_dir=sd, resume=True)
        assert restored.wave == 1                    # previous snapshot
        # ...and a resumed run from wave 1 still reaches the reference
        resumed = restored.run()
        assert cross_table(resumed) == cross_table(reference)

    def test_resume_rejects_mismatched_settings(self, engine5, tmp_path):
        sd = str(tmp_path)
        lg = league(engine5, state_dir=sd)
        lg.run_wave()
        with pytest.raises(ValueError, match="different settings"):
            league(engine5, state_dir=sd, resume=True, seed=4)

    def test_resume_without_snapshots_is_fresh(self, engine5, tmp_path):
        lg = league(engine5, state_dir=str(tmp_path), resume=True)
        assert lg.wave == 0 and lg.games_played == 0

    def test_snapshot_is_atomic(self, engine5, tmp_path):
        lg = league(engine5, state_dir=str(tmp_path))
        lg.win[0, 1] = 1.0
        path = lg.save_state()
        assert not os.path.exists(path + ".tmp")
        assert json.load(open(path))["win"][0][1] == 1.0


def tournament_ledger(engine5, mesh=None, games_per_pair: int = 4):
    """Run a multiplexed tournament; recover colours from submissions.

    The submission log identifies each game's configs by their (unique)
    sims pair and its Black owner from the forced ``a_black``, i.e. the
    observable service contract TestForcedColourAdmission pins.
    """
    sims_to_cfg = {c.sims_per_move: n for n, c in enumerate(CONFIGS)}
    log = []
    orig = SearchService.submit_game

    def recording(self, *a, **kw):
        log.append(kw)
        return orig(self, *a, **kw)

    t = Tournament(engine5, CONFIGS, games_per_pair=games_per_pair,
                   multiplex=True, max_moves=MOVE_CAP, seed=1, mesh=mesh)
    try:
        SearchService.submit_game = recording
        res = t.round_robin()
    finally:
        SearchService.submit_game = orig
    assert res.games == games_per_pair * 3
    blacks = np.zeros((3, 3))
    for kw in log:
        a = sims_to_cfg[kw["sims"][0]]
        b = sims_to_cfg[kw["sims"][1]]
        assert kw["a_black"] in (True, False)
        black, other = (a, b) if kw["a_black"] else (b, a)
        blacks[black, other] += 1
    return log, blacks


class TestTournamentColourBalance:
    def test_per_pairing_ledger_within_one(self, engine5):
        log, blacks = tournament_ledger(engine5)
        for i in range(3):
            for j in range(i + 1, 3):
                assert abs(blacks[i, j] - blacks[j, i]) <= 1, blacks
                assert blacks[i, j] + blacks[j, i] == 4
        # aggregate cap discipline: pool-wide Black grants alternate
        agg = sum(bool(kw["a_black"]) for kw in log)
        assert abs(2 * agg - len(log)) <= 1

    @multidevice
    def test_per_pairing_ledger_within_one_sharded(self, engine5):
        _, blacks = tournament_ledger(engine5,
                                      mesh=make_service_mesh(4),
                                      games_per_pair=2)
        for i in range(3):
            for j in range(i + 1, 3):
                assert abs(blacks[i, j] - blacks[j, i]) <= 1, blacks
                assert blacks[i, j] + blacks[j, i] == 2
