"""First unit tests for runtime/ft.py (fault-tolerance runtime).

The league (core/league.py) is the first consumer of
``PreemptionHandler``; these tests pin the rest of the module's
contracts so later consumers (multi-host training, straggler-driven
restarts) inherit tested behaviour:

* ``Heartbeat.beat`` is an atomic write-then-rename — no ``.tmp``
  residue, and the beacon is always whole JSON;
* ``StragglerMonitor`` skips torn/partial heartbeat files instead of
  crashing, flags hosts by beacon age (``dead_hosts``) and by step time
  against the fleet median (``stragglers``);
* ``elastic_mesh_for`` degenerate cases: fewer devices than the TP
  degree (shrink TP to the largest power of two that fits), and
  non-power-of-two survivor counts (floor the data axis).
"""
import json
import os
import signal

from repro.runtime.ft import (Heartbeat, PreemptionHandler,
                              StragglerMonitor, elastic_mesh_for)


def write_beat(directory, host, ts, step_time_s=1.0, step=10):
    with open(os.path.join(directory, f"heartbeat_{host}.json"), "w") as f:
        json.dump({"host": host, "step": step,
                   "step_time_s": step_time_s, "ts": ts}, f)


class TestPreemptionHandler:
    def test_trigger_sets_flag(self):
        h = PreemptionHandler(signals=())
        assert not h.should_stop
        h.trigger()
        assert h.should_stop

    def test_signal_flips_flag_and_restore(self):
        h = PreemptionHandler(signals=(signal.SIGUSR1,))
        try:
            assert not h.should_stop
            os.kill(os.getpid(), signal.SIGUSR1)
            assert h.should_stop
        finally:
            h.restore()

    def test_no_signals_leaves_handlers_alone(self):
        before = signal.getsignal(signal.SIGTERM)
        PreemptionHandler(signals=())
        assert signal.getsignal(signal.SIGTERM) is before


class TestHeartbeat:
    def test_beat_writes_whole_json(self, tmp_path):
        hb = Heartbeat(str(tmp_path), host_id=3)
        hb.beat(step=42, step_time_s=0.5)
        payload = json.load(open(hb.path))
        assert payload["host"] == 3 and payload["step"] == 42
        assert payload["step_time_s"] == 0.5 and "ts" in payload

    def test_beat_atomic_replace_leaves_no_tmp(self, tmp_path):
        hb = Heartbeat(str(tmp_path), host_id=0)
        for step in range(3):
            hb.beat(step=step, step_time_s=1.0)
        assert sorted(os.listdir(tmp_path)) == ["heartbeat_0.json"]
        assert json.load(open(hb.path))["step"] == 2


class TestStragglerMonitor:
    def test_dead_hosts_by_beacon_age(self, tmp_path):
        mon = StragglerMonitor(str(tmp_path), dead_after_s=60.0)
        now = 1000.0
        write_beat(tmp_path, 0, ts=now - 10)         # alive
        write_beat(tmp_path, 1, ts=now - 120)        # dead
        write_beat(tmp_path, 2, ts=now - 61)         # just dead
        assert mon.dead_hosts(now=now) == [1, 2]

    def test_torn_heartbeat_skipped(self, tmp_path):
        mon = StragglerMonitor(str(tmp_path), dead_after_s=60.0)
        now = 1000.0
        write_beat(tmp_path, 0, ts=now - 120)
        with open(os.path.join(tmp_path, "heartbeat_1.json"), "w") as f:
            f.write('{"host": 1, "step_t')         # torn mid-write
        assert [b["host"] for b in mon.read()] == [0]
        assert mon.dead_hosts(now=now) == [0]        # torn != crash

    def test_stragglers_vs_fleet_median(self, tmp_path):
        mon = StragglerMonitor(str(tmp_path), straggler_factor=2.0)
        now = 1000.0
        for host, t in enumerate([1.0, 1.1, 0.9, 5.0]):
            write_beat(tmp_path, host, ts=now, step_time_s=t)
        assert mon.stragglers() == [3]

    def test_single_host_never_straggles(self, tmp_path):
        mon = StragglerMonitor(str(tmp_path))
        write_beat(tmp_path, 0, ts=1000.0, step_time_s=99.0)
        assert mon.stragglers() == []

    def test_missing_directory_is_empty(self, tmp_path):
        mon = StragglerMonitor(str(tmp_path / "never_made"))
        assert mon.read() == []
        assert mon.dead_hosts() == []
        assert mon.stragglers() == []


class TestElasticMesh:
    def test_survivors_keep_tp_degree(self):
        assert elastic_mesh_for(16, 4) == (4, 4)
        assert elastic_mesh_for(12, 4) == (3, 4)     # non-pow2 data axis

    def test_fewer_devices_than_tp_shrinks_tp(self):
        assert elastic_mesh_for(3, 8) == (1, 2)      # largest pow2 <= 3
        assert elastic_mesh_for(1, 8) == (1, 1)

    def test_floor_division_drops_stragglers(self):
        assert elastic_mesh_for(7, 2) == (3, 2)      # 1 device idles
