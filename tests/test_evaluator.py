"""Evaluation-lane tests: the PR 7 neural policy/value graft.

Four invariant groups:

* **prior hygiene** — every stored tree prior is a distribution over
  legal moves (illegal mass zeroed, unit sum, uniform fallback), on the
  root-install path under vmapped batch init and on the net output;
* **w = 0 bit-identity** — a guided player with traced ``prior_w = 0``
  reproduces the unguided program bit for bit (action, visit counts,
  values), standalone and through a SearchService pool;
* **one trace** — any mix of guided/unguided slots (prior_w 0 / 0.5 / 1)
  shares a single compiled dispatch, under ``mesh=None`` and under 8
  faked devices (CI's test-multidevice job);
* **plumbing** — EvalService inference/training contracts, checkpoint
  loading, eval-batch occupancy accounting.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MCTSConfig
from repro.core.evaluator import EvalConfig, EvalService
from repro.core.mcts import MCTS, SearchParams
from repro.core.service import SearchService
from repro.core.tree import normalize_prior, uniform_prior

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
CAP = 12
ECFG = EvalConfig(board_size=5, d_model=16, num_layers=1, num_heads=2,
                  d_ff=32)

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def evaluator():
    return EvalService(ECFG)


@pytest.fixture(scope="module")
def guided(engine5, evaluator):
    return MCTS(engine5, CFG, evaluator=evaluator)


@pytest.fixture(scope="module")
def plain(engine5):
    return MCTS(engine5, CFG)


@pytest.fixture(scope="module")
def roots2(engine5):
    st = engine5.init_state()
    for mv in (3, 7, 12):
        st = engine5.jit_play(st, jnp.int32(mv))
    return jax.tree.map(lambda a, b: jnp.stack([a, b]),
                        engine5.init_state(), st)


@pytest.fixture(scope="module")
def keys2():
    return np.asarray(jax.random.split(jax.random.PRNGKey(13), 2))


def _params(prior_w, g=2):
    return SearchParams(jnp.full((g,), CFG.c_uct),
                        jnp.full((g,), CFG.virtual_loss),
                        jnp.asarray(prior_w, jnp.float32))


# --------------------------------------------------------------- priors


class TestPriorHygiene:
    def test_normalize_prior_zeroes_illegal_mass(self):
        legal = jnp.array([True, False, True, False, True])
        raw = jnp.array([0.2, 5.0, 0.3, 4.0, 0.5])
        p = normalize_prior(raw, legal)
        np.testing.assert_array_equal(np.asarray(p)[~np.asarray(legal)], 0.0)
        assert float(p.sum()) == pytest.approx(1.0)
        np.testing.assert_allclose(np.asarray(p)[[0, 2, 4]],
                                   [0.2, 0.3, 0.5])

    def test_normalize_prior_degenerate_falls_back_uniform(self):
        legal = jnp.array([True, False, True, False])
        raw = jnp.array([0.0, 1.0, 0.0, 1.0])     # all mass illegal
        np.testing.assert_array_equal(np.asarray(normalize_prior(raw, legal)),
                                      np.asarray(uniform_prior(legal)))

    def test_root_prior_fn_normalized_under_batch_init(self, engine5,
                                                       roots2, keys2):
        """The ``prior_fn`` root path (dormant pre-PR 7): a policy that
        emits unnormalised mass on illegal points must land in the tree
        as a legal-move distribution, per game under the search vmap."""
        a = engine5.num_actions

        def messy_prior(_state, _legal):
            return jnp.arange(1.0, a + 1.0)       # mass everywhere

        mcts = MCTS(engine5, CFG, prior_fn=messy_prior, use_puct=True)
        res = mcts.search_batch(roots2, jnp.asarray(keys2))
        root_prior = np.asarray(res.tree.prior[:, 0])      # [G, A]
        root_legal = np.asarray(res.tree.legal[:, 0])
        for g in range(2):
            assert (root_prior[g][~root_legal[g]] == 0.0).all()
            assert root_prior[g].sum() == pytest.approx(1.0)
        # game 1 has occupied points -> its legal set (and prior) differ
        assert root_legal[0].sum() != root_legal[1].sum()

    def test_net_prior_is_legal_distribution(self, engine5, evaluator):
        st = engine5.init_state()
        for mv in (0, 6, 12, 18):
            st = engine5.jit_play(st, jnp.int32(mv))
        states = jax.tree.map(lambda x: jnp.stack([x, x]), st)
        legal = jax.vmap(engine5.legal_moves)(states)
        prior, value = evaluator.policy_value(states, legal)
        p, m = np.asarray(prior), np.asarray(legal)
        assert (p[~m] == 0.0).all()
        np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-6)
        assert (np.abs(np.asarray(value)) <= 1.0).all()


# --------------------------------------------------- w = 0 bit-identity


class TestBitIdentity:
    def test_w0_bit_identical_to_unguided(self, plain, guided, roots2,
                                          keys2):
        """The tentpole acceptance pin: traced prior_w = 0 reproduces the
        no-eval program exactly — across *different* compiled programs
        (blended scoring + value mixing vs the static path)."""
        base = plain.search_batch(roots2, jnp.asarray(keys2))
        got = guided.search_batch(roots2, jnp.asarray(keys2),
                                  params=_params([0.0, 0.0]))
        np.testing.assert_array_equal(np.asarray(got.action),
                                      np.asarray(base.action))
        np.testing.assert_array_equal(np.asarray(got.root_visits),
                                      np.asarray(base.root_visits))
        np.testing.assert_array_equal(np.asarray(got.root_values),
                                      np.asarray(base.root_values))
        np.testing.assert_array_equal(np.asarray(got.tree.visit),
                                      np.asarray(base.tree.visit))

    def test_guided_search_differs(self, plain, guided, roots2, keys2):
        base = plain.search_batch(roots2, jnp.asarray(keys2))
        got = guided.search_batch(roots2, jnp.asarray(keys2),
                                  params=_params([1.0, 1.0]))
        assert (np.asarray(got.root_visits)
                != np.asarray(base.root_visits)).any()

    def test_mixed_pool_rows_equal_pure_runs(self, guided, roots2, keys2):
        """One vmapped search over [w=0, w=1] slots gives each row the
        bit-exact result of a pure run at that weight."""
        mixed = guided.search_batch(roots2, jnp.asarray(keys2),
                                    params=_params([0.0, 1.0]))
        for g, w in enumerate((0.0, 1.0)):
            pure = guided.search_batch(roots2, jnp.asarray(keys2),
                                       params=_params([w, w]))
            np.testing.assert_array_equal(
                np.asarray(mixed.root_visits[g]),
                np.asarray(pure.root_visits[g]))
            assert int(mixed.action[g]) == int(pure.action[g])

    def test_prior_w_values_are_traced(self, guided, roots2, keys2):
        fn = jax.jit(guided.search_batch)
        for w in ([0.0, 0.0], [0.5, 1.0], [1.0, 0.25]):
            fn(roots2, jnp.asarray(keys2), params=_params(w))
        assert fn._cache_size() == 1


# ------------------------------------------------------- service lane


class TestServiceEvalLane:
    def _run(self, engine, player, keys, prior_weight):
        svc = SearchService(engine, player, player, slots=2, max_moves=CAP)
        svc.reset(seed=0, colour_cap=2)
        tickets = [svc.submit_game(key=k, prior_weight=prior_weight)
                   for k in keys]
        recs = {r.ticket: r for r in svc.drain()}
        return svc, [recs[t] for t in tickets]

    def test_w0_pool_bit_identical_to_plain_pool(self, engine5, plain,
                                                 guided):
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(5), 4))
        _, want = self._run(engine5, plain, keys, None)
        svc, got = self._run(engine5, guided, keys, 0.0)
        for w, g in zip(want, got):
            assert w[:7] == g[:7]            # every scalar result field
            np.testing.assert_array_equal(w.root_visits, g.root_visits)
        # nothing counted as guided work
        assert float(svc.eval_occupancy().sum()) == 0.0

    def test_one_trace_across_guided_and_unguided(self, engine5, guided):
        """Guided (w > 0), half-guided, and unguided (w = 0) requests —
        games and serves — share one compiled dispatch."""
        svc = SearchService(engine5, guided, guided, slots=4,
                            max_moves=CAP)
        st = engine5.init_state()
        for seed, pw in enumerate((0.0, 0.5, 1.0)):
            svc.reset(seed=seed)
            svc.submit_game(prior_weight=pw)
            svc.submit_serve(st, prior_weight=pw)
            assert len(svc.drain()) == 2
        assert svc._dispatch._cache_size() == 1
        assert svc._push_games._cache_size() == 1
        assert svc._push_serve._cache_size() == 1

    def test_eval_occupancy_counts_guided_slots(self, engine5, guided):
        svc, _ = self._run(engine5, guided, np.asarray(
            jax.random.split(jax.random.PRNGKey(9), 4)), 1.0)
        occ = svc.eval_occupancy()
        assert occ.shape == (1,)
        assert 0.0 < float(occ[0]) <= 1.0

    def test_asymmetric_guided_a_plain_b(self, engine5, plain, guided):
        """A guided A-side and an unguided B-side coexist in one pool;
        the B side statically ignores the pw knob."""
        svc = SearchService(engine5, guided, plain, slots=2, max_moves=CAP)
        svc.reset(seed=0, colour_cap=2)
        t = svc.submit_game(prior_weight=1.0)
        recs = {r.ticket: r for r in svc.drain()}
        assert recs[t].moves > 0


# --------------------------------------------------------- sharded lane


@multidevice
class TestShardedEvalLane:
    def test_mixed_pool_sharded_matches_unsharded(self, engine5, guided):
        """Serve answers with heterogeneous prior_w are placement-
        independent: an 8-shard pool answers bit-for-bit like mesh=None,
        from one compiled dispatch."""
        from repro.compat import make_service_mesh
        st = engine5.init_state()
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), 4))
        weights = (0.0, 0.5, 1.0, 0.0)

        def serve(mesh, slots):
            svc = SearchService(engine5, guided, guided, slots=slots,
                                max_moves=CAP, mesh=mesh)
            svc.reset(seed=0)
            tickets = [svc.submit_serve(st, key=k, prior_weight=w)
                       for k, w in zip(keys, weights)]
            recs = {r.ticket: r for r in svc.drain()}
            return svc, [recs[t] for t in tickets]

        _, want = serve(None, 4)
        svc, got = serve(make_service_mesh(8), 8)
        for w, g in zip(want, got):
            assert w.action == g.action
            np.testing.assert_array_equal(w.root_visits, g.root_visits)
        assert svc._dispatch._cache_size() == 1


# ------------------------------------------------------------ win rate


@pytest.mark.slow
class TestWinRate:
    def test_distilled_prior_beats_uniform_at_9x9(self, engine9):
        """The lane must buy strength, not just run: a heuristic-
        distilled checkpoint (tests/fixtures/distill_eval9.py, committed
        under tests/fixtures/eval9/) guides one side of a small 9x9
        arena match at a fixed sims budget and must outscore the
        uniform-prior side.  Colours alternate by the arena's balanced
        assignment, so the margin is not a komi artifact."""
        import os

        from repro.core.arena import Arena
        fix = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "fixtures", "eval9")
        # keep in sync with ECFG in tests/fixtures/distill_eval9.py
        ev = EvalService(dataclasses.replace(
            EvalConfig(board_size=9, d_model=16, num_layers=1,
                       num_heads=2, d_ff=32), ckpt_dir=fix))
        cfg = MCTSConfig(board_size=9, komi=6.0, lanes=4,
                         sims_per_move=24, max_nodes=160)
        guided = MCTS(engine9, cfg, evaluator=ev)
        uniform = MCTS(engine9, cfg)
        arena = Arena(engine9, guided, uniform, slots=8, max_moves=70)
        recs = arena.play_games(8, seed=2, prior_weight=1.0)
        score = sum((1.0 if (r.winner > 0) == r.a_is_black else 0.0)
                    if r.winner != 0 else 0.5 for r in recs)
        assert score > len(recs) / 2, \
            f"guided scored {score}/{len(recs)} vs uniform priors"


# ------------------------------------------------------------- plumbing


class TestEvalServicePlumbing:
    def test_deterministic_init(self):
        a, b = EvalService(ECFG), EvalService(ECFG)
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), a.params, b.params)

    def test_config_parse(self):
        cfg = EvalConfig.parse("d_model=64,value_weight=0.25",
                               board_size=5)
        assert (cfg.d_model, cfg.value_weight, cfg.board_size) \
            == (64, 0.25, 5)
        with pytest.raises(ValueError):
            EvalConfig.parse("d_modle=64")
        with pytest.raises(ValueError):
            EvalConfig.parse("d_model")

    def test_checkpoint_round_trip_into_service(self, evaluator, tmp_path):
        """A saved param tree is what a fresh EvalService loads."""
        from repro.ckpt.checkpoint import save_checkpoint
        bumped = jax.tree.map(lambda x: x + 1.0, evaluator.params)
        save_checkpoint(str(tmp_path), 3, bumped, extra={})
        loaded = EvalService(dataclasses.replace(
            ECFG, ckpt_dir=str(tmp_path)))
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), loaded.params, bumped)

    def test_loss_and_train_step(self, engine5, evaluator):
        """The evaluator satisfies the training/step.py model contract."""
        from repro.config import TrainConfig
        from repro.training.step import init_train_state, make_train_step
        b, a, s = 4, engine5.num_actions, engine5.n2 + 1
        rng = np.random.default_rng(0)
        legal = jnp.asarray(rng.random((b, a)) > 0.3)
        pol = normalize_prior(jnp.asarray(rng.random((b, a)), jnp.float32),
                              legal)
        batch = {
            "tokens": jnp.asarray(rng.integers(1, 6, (b, s)), jnp.int32),
            "legal": legal,
            "policy": pol,
            "value": jnp.asarray(rng.uniform(-1, 1, b), jnp.float32),
        }
        loss, metrics = evaluator.loss(evaluator.params, batch)
        assert np.isfinite(float(loss))
        assert set(metrics) >= {"ce", "value_mse"}

        tcfg = TrainConfig(steps=2, warmup_steps=1, z_loss=0.0)
        state = init_train_state(evaluator, tcfg, jax.random.PRNGKey(0))
        step = make_train_step(evaluator, tcfg)
        state, m1 = step(state, batch)
        assert int(state.step) == 1 and np.isfinite(float(m1["loss"]))
