"""Checkpoint round-trips for *model* param trees (PR 7 satellite).

tests/test_substrate.py covers the generic pytree plumbing; this file
pins the contracts the evaluation lane leans on: a full
``EvalService.init`` tree (nested dicts, mixed shapes, tied embeddings)
survives save -> restore bit-for-bit, and ``AsyncCheckpointer`` keeps its
flush ordering — snapshot-at-save semantics, one write in flight,
errors surfaced on the next ``wait()``.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (AsyncCheckpointer, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.core.evaluator import EvalConfig, EvalService

ECFG = EvalConfig(board_size=5, d_model=16, num_layers=1, num_heads=2,
                  d_ff=32)


@pytest.fixture(scope="module")
def tree():
    return EvalService(ECFG).params


def _assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = jax.tree_util.tree_leaves_with_path(b)
    assert [p for p, _ in la] == [p for p, _ in lb]
    for (path, x), (_, y) in zip(la, lb):
        assert x.dtype == y.dtype, path
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(path))


class TestModelTreeRoundTrip:
    def test_save_restore_bit_identical(self, tree, tmp_path):
        save_checkpoint(str(tmp_path), 5, tree, extra={"gen": 1})
        got, step, extra = restore_checkpoint(str(tmp_path), tree)
        assert (step, extra) == (5, {"gen": 1})
        _assert_trees_equal(tree, got)

    def test_restore_picks_latest_and_explicit_step(self, tree, tmp_path):
        save_checkpoint(str(tmp_path), 1, tree)
        bumped = jax.tree.map(lambda x: x + 1.0, tree)
        save_checkpoint(str(tmp_path), 2, bumped)
        assert latest_step(str(tmp_path)) == 2
        got, step, _ = restore_checkpoint(str(tmp_path), tree)
        assert step == 2
        _assert_trees_equal(bumped, got)
        old, step, _ = restore_checkpoint(str(tmp_path), tree, step=1)
        assert step == 1
        _assert_trees_equal(tree, old)

    def test_loads_into_fresh_eval_service(self, tree, tmp_path):
        """The EvalService ckpt_dir path end to end: trained params in,
        identical service out."""
        bumped = jax.tree.map(lambda x: x * 2.0 + 1.0, tree)
        save_checkpoint(str(tmp_path), 7, bumped)
        import dataclasses
        svc = EvalService(dataclasses.replace(ECFG,
                                              ckpt_dir=str(tmp_path)))
        _assert_trees_equal(bumped, svc.params)


class TestAsyncFlushOrdering:
    def test_snapshot_at_save_not_at_write(self, tmp_path):
        """save() snapshots device arrays immediately; mutating the live
        tree afterwards must not leak into the in-flight write."""
        live = {"w": jnp.arange(8.0)}
        ck = AsyncCheckpointer(str(tmp_path))
        ck.save(1, live)
        live["w"] = live["w"] * 0.0          # "training step" after save
        ck.wait()
        got, _, _ = restore_checkpoint(str(tmp_path), live)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(8.0))

    def test_one_in_flight_back_to_back_saves(self, tree, tmp_path):
        """A second save() drains the first before snapshotting — every
        step lands, in order, even with zero explicit wait()s between."""
        ck = AsyncCheckpointer(str(tmp_path), keep=5)
        trees = [jax.tree.map(lambda x, s=s: x + float(s), tree)
                 for s in range(3)]
        for s, t in enumerate(trees):
            ck.save(s, t)
        ck.wait()
        for s in range(3):
            got, _, _ = restore_checkpoint(str(tmp_path), tree, step=s)
            _assert_trees_equal(trees[s], got)

    def test_gc_keeps_newest(self, tree, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path), keep=2)
        for s in range(4):
            ck.save(s, tree)
        ck.wait()
        kept = sorted(d for d in os.listdir(tmp_path)
                      if d.startswith("step_"))
        assert kept == ["step_00000002", "step_00000003"]

    def test_write_error_surfaces_on_wait(self, tree, tmp_path):
        blocker = tmp_path / "not_a_dir"
        blocker.write_text("occupied")
        ck = AsyncCheckpointer(str(blocker))
        ck.save(1, tree)
        with pytest.raises(BaseException):
            ck.wait()
        ck.wait()                            # error cleared, not sticky
