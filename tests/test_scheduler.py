"""Unified multi-bucket scheduler tests (core/scheduler.py + GoService).

Tiers, mirroring tests/test_sharded_service.py:

* pure host-side unit tests — DepthController clamp/hysteresis/
  convergence, BucketScheduler shard partitions + headroom borrowing
  (against a stub service, no device);
* bit-identity pins under ``mesh=None`` — mixed-komi streaming through
  the unified scheduler at ``depth=1`` with borrowing disabled answers
  every ticket identically (action, root visits) to the per-bucket
  ``_pipes`` path, while spending strictly fewer host syncs; with a
  single bucket the two paths are bit-identical *including* host syncs
  (the acceptance invariant: unified is the old program when there is
  nothing to unify);
* an 8-faked-device subprocess test (slow tier) re-pins the mixed-komi
  identity with real shard partitions and borrowing, following the
  tests/test_distributed.py discipline so single-device tier-1 runs
  still cover the sharded path.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.scheduler import BucketScheduler, DepthController
from repro.serving.go_service import DeadlinePolicy, GoService

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _boards(n, n2=25, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        b = np.zeros(n2, np.int8)
        stones = rng.choice(n2, size=4, replace=False)
        b[stones[:2]] = 1
        b[stones[2:]] = -1
        out.append(b.tolist())
    return out


def _drain(svc, tickets):
    """Poll until every ticket answers; returns ticket -> MoveResult."""
    done = {}
    polls = 0
    while len(done) < len(tickets):
        for t in svc.poll():
            done[t] = svc.result(t, wait=False)
        polls += 1
        assert polls < 10_000, "drain stalled"
    return done


# --------------------------------------------------------------------------
# DepthController


class TestDepthController:
    def test_validation(self):
        with pytest.raises(ValueError):
            DepthController(min_depth=0)
        with pytest.raises(ValueError):
            DepthController(min_depth=3, max_depth=2)
        with pytest.raises(ValueError):
            DepthController(lo_wait_s=0.5, hi_wait_s=0.1)

    def test_raises_to_clamp_and_never_past(self):
        c = DepthController(min_depth=1, max_depth=3, patience=2)
        depth, seen = 1, []
        # device always ahead: zero wait, results landed and waiting
        for _ in range(50):
            depth = c.observe(depth, blocked_s=0.0, landed_lag=4)
            seen.append(depth)
        assert max(seen) == 3                    # reached the clamp ...
        assert seen[-1] == 3                     # ... and stayed
        assert all(1 <= d <= 3 for d in seen)    # never outside it

    def test_lowers_under_blocking_and_floors(self):
        c = DepthController(min_depth=1, max_depth=4, patience=2)
        depth, seen = 4, []
        for _ in range(50):                      # device always behind
            depth = c.observe(depth, blocked_s=1.0, landed_lag=0)
            seen.append(depth)
        assert seen[-1] == 1
        assert all(1 <= d <= 4 for d in seen)

    def test_deadband_converges(self):
        c = DepthController(min_depth=1, max_depth=4,
                            lo_wait_s=1e-4, hi_wait_s=1e-2)
        depth = 2
        # steady mid-band wait: inside the deadband, depth never moves
        for _ in range(100):
            depth = c.observe(depth, blocked_s=1e-3, landed_lag=2)
        assert depth == 2
        assert c.adjustments == 0

    def test_patience_filters_one_off_spikes(self):
        c = DepthController(min_depth=1, max_depth=4, patience=3)
        depth = 2
        # a single raise signal between holds must not move the depth
        depth = c.observe(depth, blocked_s=0.0, landed_lag=1)
        depth = c.observe(depth, blocked_s=1e-3, landed_lag=0)
        assert depth == 2 and c.adjustments == 0


# --------------------------------------------------------------------------
# BucketScheduler partitions + borrowing (stub service, host only)


class _StubService:
    """Just enough SearchService surface for mask/partition tests."""

    def __init__(self, n_shard):
        self.n_shard = n_shard
        self.pipeline_depth = 1
        self.superstep = 2
        self._shard_filter = None


class TestBucketPartitions:
    def test_partition_covers_and_disjoint(self):
        sched = BucketScheduler(_StubService(8))
        for k in (5.5, 6.0, 6.5, 7.5):
            sched.bucket(k)
        masks = [sched._partition(b.index)
                 for b in sched.buckets.values()]
        stack = np.stack(masks)
        assert (stack.sum(axis=0) == 1).all()    # disjoint and covering
        assert all(m.sum() == 2 for m in masks)  # 8 shards / 4 buckets

    def test_more_buckets_than_shards_overlap(self):
        sched = BucketScheduler(_StubService(2))
        for k in range(5):
            sched.bucket(float(k))
        for b in sched.buckets.values():
            assert sched._partition(b.index).sum() >= 1

    def test_borrowing_lends_idle_shards_and_reclaims(self):
        svc = _StubService(8)
        sched = BucketScheduler(svc, borrowing=True)
        busy, idle = sched.bucket(6.0), sched.bucket(7.5)
        busy.outstanding = 4
        # idle bucket lends: the busy bucket may place on every shard
        assert sched._allowed(6.0, 1).all()
        # lender submits -> reclaimed on demand: mask shrinks to own half
        idle.outstanding = 1
        own = sched._partition(busy.index)
        assert (sched._allowed(6.0, 1) == own).all()
        # the filter is installed on the service
        assert svc._shard_filter == sched._allowed

    def test_borrowing_disabled_pins_partition(self):
        sched = BucketScheduler(_StubService(8), borrowing=False)
        b = sched.bucket(6.0)
        sched.bucket(7.5)          # idle, but must not be lent
        assert (sched._allowed(6.0, 1) == sched._partition(b.index)).all()

    def test_unregistered_komi_sees_all_shards(self):
        sched = BucketScheduler(_StubService(8))
        sched.bucket(6.0)
        assert sched._allowed(99.0, 0) is None

    def test_single_shard_mask_is_none(self):
        sched = BucketScheduler(_StubService(1))
        sched.bucket(6.0)
        sched.bucket(7.5)
        assert sched._allowed(6.0, 1) is None    # mesh=None: nothing to mask

    def test_max_depth_below_initial_rejected(self):
        with pytest.raises(ValueError):
            BucketScheduler(_StubService(1), depth=3, max_depth=2)


# --------------------------------------------------------------------------
# DeadlinePolicy censored calibration (satellite: learn from sheds too)


class TestCensoredCalibration:
    def test_shed_wait_raises_optimistic_estimate(self):
        pol = DeadlinePolicy(base_s=0.0, sim_cost_s=1e-6, slots=8,
                             calibrate=True, ewma=0.5)
        pol.observe_censored(waited_s=1.0, sims=10, depth=0)
        assert pol.sim_cost_s > 1e-6             # pulled up toward 0.05

    def test_fast_shed_never_lowers_estimate(self):
        pol = DeadlinePolicy(base_s=0.0, sim_cost_s=1e-2, slots=8,
                             calibrate=True, ewma=0.5)
        pol.observe_censored(waited_s=1e-5, sims=10, depth=0)
        assert pol.sim_cost_s == 1e-2            # censored: one-sided

    def test_calibrate_off_is_inert(self):
        pol = DeadlinePolicy(sim_cost_s=1e-3, calibrate=False)
        pol.observe_censored(waited_s=9.9, sims=10, depth=0)
        assert pol.sim_cost_s == 1e-3


# --------------------------------------------------------------------------
# bit-identity pins, mesh=None


def _service(unified, **kw):
    kw.setdefault("board_size", 5)
    kw.setdefault("komi", 6.0)
    kw.setdefault("max_sims", 8)
    kw.setdefault("lanes", 4)
    kw.setdefault("slots", 8)
    kw.setdefault("seed", 0)
    return GoService(unified=unified, **kw)


class TestUnifiedIdentity:
    def test_mixed_komi_same_moves_fewer_syncs(self):
        boards = _boards(8)
        komis = [6.0, 7.5] * 4                   # interleaved buckets
        uni = _service(True, borrowing=False)
        leg = _service(False)
        out = {}
        for svc in (uni, leg):
            tickets = [svc.submit(b, komi=k)
                       for b, k in zip(boards, komis)]
            out[svc] = (tickets, _drain(svc, tickets))
        t_uni, r_uni = out[uni]
        t_leg, r_leg = out[leg]
        assert t_uni == t_leg                    # same ticket numbering
        for tu, tl in zip(t_uni, t_leg):
            assert r_uni[tu].action == r_leg[tl].action
            assert np.array_equal(r_uni[tu].root_visits,
                                  r_leg[tl].root_visits)
        # the tentpole's win: one pump stream instead of one per bucket
        assert uni.host_syncs < leg.host_syncs
        # one compiled dispatch serves both komis
        assert uni._buckets[6.0]._dispatch._cache_size() == 1

    def test_single_bucket_bit_identical_including_syncs(self):
        boards = _boards(6)
        uni = _service(True)
        leg = _service(False)
        for svc in (uni, leg):
            tickets = [svc.submit(b) for b in boards]
            done = _drain(svc, tickets)
            svc._pin = (tickets,
                        [done[t].action for t in tickets],
                        [done[t].root_visits for t in tickets])
        assert uni._pin[0] == leg._pin[0]
        assert uni._pin[1] == leg._pin[1]
        for a, b in zip(uni._pin[2], leg._pin[2]):
            assert np.array_equal(a, b)
        assert uni.host_syncs == leg.host_syncs  # bit-identical pump loop
        assert uni.host_blocked_s > 0 and leg.host_blocked_s > 0

    def test_adaptive_depth_clamped_and_converges(self):
        svc = _service(True, pipeline_depth=1, max_pipeline_depth=3)
        assert svc.adaptive_depth                # headroom engages it
        boards = _boards(16)
        tickets = [svc.submit(b, komi=6.0 if i % 2 else 7.5)
                   for i, b in enumerate(boards)]
        depths = []
        done = {}
        polls = 0
        while len(done) < len(tickets):
            for t in svc.poll():
                done[t] = svc.result(t, wait=False)
            depths.append(svc._sched.depth)
            polls += 1
            assert polls < 10_000
        assert all(1 <= d <= 3 for d in depths)  # never past the clamp
        # converged: the tail of the run settles on one depth
        tail = depths[-max(3, len(depths) // 4):]
        assert len(set(tail)) == 1

    def test_scheduler_stats_shapes(self):
        svc = _service(True)
        svc.best_move(_boards(1)[0], komi=7.5)
        s = svc.scheduler_stats()
        assert s["unified"] and s["buckets"] == 2
        for entry in s["per_bucket"].values():
            assert {"queue_depth", "submitted", "completed",
                    "shards_owned"} <= set(entry)
        assert s["in_flight_supersteps"] == 0    # drained
        occ = svc.shard_occupancy()
        assert occ.shape == (1,) and 0.0 <= occ[0] <= 1.0

    def test_metrics_payload_exports_scheduler(self):
        from repro.serving.server import GoMoveServer
        svc = _service(True)
        payload = GoMoveServer(svc)._metrics_payload()
        assert payload["scheduler"]["unified"]
        assert "per_bucket" in payload["scheduler"]
        assert payload["shard_occupancy"] == [0.0]


# --------------------------------------------------------------------------
# 8-shard identity (subprocess so tier-1 single-device runs cover it)

_SHARDED_SRC = r"""
import numpy as np
from repro.compat import make_service_mesh
from repro.serving.go_service import GoService

mesh = make_service_mesh(8)
kw = dict(board_size=5, komi=6.0, max_sims=8, lanes=4, slots=16,
          seed=0, mesh=mesh)
rng = np.random.default_rng(0)
boards = []
for _ in range(12):
    b = np.zeros(25, np.int8)
    stones = rng.choice(25, size=4, replace=False)
    b[stones[:2]] = 1
    b[stones[2:]] = -1
    boards.append(b.tolist())
komis = [6.0, 7.5, 5.5] * 4

def run(unified, **extra):
    svc = GoService(unified=unified, **kw, **extra)
    tickets = [svc.submit(b, komi=k) for b, k in zip(boards, komis)]
    done = {}
    while len(done) < len(tickets):
        for t in svc.poll():
            done[t] = svc.result(t, wait=False)
    return ([done[t].action for t in tickets], svc.host_syncs,
            svc._buckets[6.0]._dispatch_mesh._cache_size()
            if unified else None)

moves_u, syncs_u, traces = run(True, borrowing=False)
moves_b, syncs_b, _ = run(True, borrowing=True)
moves_l, syncs_l, _ = run(False)
assert moves_u == moves_l, (moves_u, moves_l)   # partitioned == per-bucket
assert moves_b == moves_l, (moves_b, moves_l)   # borrowing changes nothing
assert syncs_u < syncs_l, (syncs_u, syncs_l)
assert traces == 1, traces                      # one dispatch, 3 komis
print("OK", syncs_u, syncs_l)
"""


@pytest.mark.slow
def test_sharded_unified_identity_subprocess():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", _SHARDED_SRC], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout


@multidevice
def test_sharded_unified_identity_inprocess():
    from repro.compat import make_service_mesh
    mesh = make_service_mesh(8)
    boards = _boards(6)
    komis = [6.0, 7.5] * 3
    results = {}
    for unified in (True, False):
        svc = _service(unified, slots=16, mesh=mesh,
                       **({"borrowing": False} if unified else {}))
        tickets = [svc.submit(b, komi=k) for b, k in zip(boards, komis)]
        done = _drain(svc, tickets)
        results[unified] = [done[t].action for t in tickets]
    assert results[True] == results[False]
