"""Serving front door: deadlines, load shedding, metrics, HTTP surface.

Pins the ISSUE 6 SLO contracts:

* :class:`DeadlinePolicy` admit / downgrade / shed boundaries are exact
  (fixed-cost policy, no calibration);
* FIFO completion order survives mixed-deadline load;
* ``/metrics`` percentile math matches numpy on a recorded trace (up to
  the histogram's geometric bucket resolution);
* the shed path leaves the slot pool consistent:
  ``submitted == completed + in_flight + shed``;
* with no deadline the HTTP path drains **bit-identical** results to a
  direct ``GoService.best_move`` (serve purity contract over the wire);
* the deadline/budget fields add no new jit traces (compile count
  asserted after mixed SLO traffic);
* ``GoService.result`` honours ``timeout_s`` instead of spinning.
"""
import asyncio
import threading
import time

import numpy as np
import pytest

from repro.serving.go_service import (DeadlineExceededError, DeadlinePolicy,
                                      GoService, OverCapacityError)
from repro.serving.metrics import LatencyHistogram, ServingMetrics
from repro.serving.server import GoMoveServer, http_json

BOARD = 5
N2 = BOARD * BOARD
KOMI = 0.5
SIMS = 8


def _service(**kw):
    base = dict(board_size=BOARD, komi=KOMI, max_sims=SIMS, lanes=2,
                slots=4, max_nodes=64, seed=0)
    base.update(kw)
    return GoService(**base)


@pytest.fixture(scope="module")
def direct():
    """One warmed GoService for the non-HTTP SLO tests."""
    gs = _service()
    gs.best_move([0] * N2, key=[0, 0])           # compile + warm
    return gs


@pytest.fixture(scope="module")
def served():
    """A second GoService behind a live GoMoveServer on a free port."""
    gs = _service()
    loop = asyncio.new_event_loop()
    thread = threading.Thread(target=loop.run_forever, daemon=True)
    thread.start()
    srv = GoMoveServer(gs, poll_idle_s=0.001)
    port = asyncio.run_coroutine_threadsafe(srv.start(), loop).result(30)

    def call(method, path, payload=None, timeout_s=180.0):
        return asyncio.run(http_json("127.0.0.1", port, method, path,
                                     payload, timeout_s=timeout_s))

    # warm the bucket through the full HTTP path
    status, _ = call("POST", "/v1/best_move",
                     {"board": [0] * N2, "key": [0, 0]})
    assert status == 200
    yield gs, call
    asyncio.run_coroutine_threadsafe(srv.stop(), loop).result(30)
    loop.call_soon_threadsafe(loop.stop)
    thread.join(timeout=10)


class TestDeadlinePolicy:
    def test_admit_downgrade_shed_boundaries(self):
        """Fixed-cost policy: the three verdict regions are exact."""
        p = DeadlinePolicy(base_s=0.01, sim_cost_s=0.001, floor_sims=4,
                           slots=4, calibrate=False)
        # full budget fits: est(64, 0) = 0.01 + 0.064 = 0.074
        assert p.decide(0.074, 0, 64) == ("admit", 64)
        assert p.decide(10.0, 0, 64) == ("admit", 64)
        # tighter: fit = (remaining - base) / per_sim
        assert p.decide(0.050, 0, 64) == ("downgrade", 40)
        # floor boundary: fit == floor admits the floor budget ...
        assert p.decide(0.01 + 0.004, 0, 64) == ("downgrade", 4)
        # ... one sim less sheds
        assert p.decide(0.01 + 0.0039, 0, 64) == ("shed", 0)
        assert p.decide(0.0, 0, 64) == ("shed", 0)
        # no deadline always admits the full budget
        assert p.decide(None, 1000, 64) == ("admit", 64)

    def test_queue_depth_scales_cost(self):
        """Depth adds waves: the same deadline downgrades harder."""
        p = DeadlinePolicy(base_s=0.01, sim_cost_s=0.001, floor_sims=4,
                           slots=4, calibrate=False)
        assert p.estimate_s(64, 0) == pytest.approx(0.074)
        assert p.estimate_s(64, 4) == pytest.approx(0.01 + 2 * 0.064)
        assert p.decide(0.074, 4, 64) == ("downgrade", 32)

    def test_downgrade_never_exceeds_full(self):
        p = DeadlinePolicy(base_s=0.0, sim_cost_s=0.001, floor_sims=1,
                           slots=4, calibrate=False)
        verdict, granted = p.decide(1.0, 0, 16)
        assert verdict == "admit" and granted == 16

    def test_calibration_moves_the_boundary(self):
        p = DeadlinePolicy(base_s=0.0, sim_cost_s=1e-3, floor_sims=1,
                           slots=4, calibrate=True, ewma=1.0)
        p.observe(latency_s=1.6, sims=16, depth=0)   # 0.1 s/sim observed
        assert p.sim_cost_s == pytest.approx(0.1)
        assert p.decide(0.2, 0, 16) == ("downgrade", 2)


class TestMetricsMath:
    def test_percentiles_match_numpy_on_recorded_trace(self):
        """Histogram percentiles track numpy within bucket resolution."""
        rng = np.random.default_rng(7)
        trace = rng.lognormal(mean=-3.0, sigma=1.2, size=400)
        h = LatencyHistogram(growth=1.07)
        for v in trace:
            h.record(v)
        for q in (50.0, 90.0, 95.0, 99.0):
            got = h.percentile(q)
            want = float(np.percentile(trace, q))
            assert got == pytest.approx(want, rel=0.08), q
        snap = h.snapshot()
        assert snap["count"] == 400
        assert snap["p50_ms"] <= snap["p95_ms"] <= snap["p99_ms"]
        assert snap["max_ms"] == pytest.approx(trace.max() * 1e3)

    def test_empty_and_single_sample(self):
        h = LatencyHistogram()
        assert h.percentile(99.0) == 0.0
        h.record(0.25)
        assert h.percentile(50.0) == pytest.approx(0.25, rel=0.08)

    def test_serving_metrics_ledger(self):
        m = ServingMetrics()
        m.bump("submitted")
        m.bump("shed_overload")
        m.bump("shed_deadline", 2)
        m.observe(0.01, 0.04, 0.05, deadline_missed=True)
        snap = m.snapshot()
        assert snap["submitted"] == 1
        assert snap["shed"] == 3
        assert snap["completed"] == 1 and snap["deadline_miss"] == 1
        assert snap["total"]["count"] == 1
        with pytest.raises(KeyError):
            m.bump("not_a_counter")


class TestSLOPaths:
    def test_fifo_preserved_under_mixed_deadline_load(self, direct):
        """Mixed generous deadlines never reorder serve completions."""
        deadlines = [None, 60_000.0, None, 30_000.0, 90_000.0, None]
        tickets = [direct.submit([0] * N2, key=[i + 1, 0],
                                 deadline_ms=d)
                   for i, d in enumerate(deadlines)]
        order = []
        for _ in range(1000):
            order.extend(direct.poll())
            if len(order) == len(tickets):
                break
        assert order == tickets
        for t in tickets:
            res = direct.result(t, wait=False)
            assert res is not None and not res.downgraded

    def test_shed_path_leaves_pool_consistent(self, direct):
        """An expired host-buffered query sheds; accounting balances."""
        bucket = direct._bucket(KOMI)
        shed0 = bucket.shed_total
        policy0 = direct.deadline_policy
        try:
            # zero-cost policy admits any deadline; a ~0 one then expires
            # while still host-buffered and sheds at the next poll
            direct.deadline_policy = DeadlinePolicy(
                base_s=0.0, sim_cost_s=0.0, floor_sims=1, calibrate=False)
            t_dead = direct.submit([0] * N2, key=[99, 0],
                                   deadline_ms=1e-6)
        finally:
            direct.deadline_policy = policy0
        t_live = direct.submit([0] * N2, key=[100, 0])
        while direct.result(t_live, wait=False) is None:
            direct.poll()
        assert direct.pop_shed() == {t_dead: "deadline"}
        with pytest.raises(DeadlineExceededError):
            direct.result(t_dead)
        submitted, completed, in_flight = bucket.accounting()
        shed = bucket.shed_total
        assert shed == shed0 + 1
        assert submitted == completed + in_flight + shed
        assert in_flight == 0
        # the pool still answers after the shed
        res = direct.best_move([0] * N2, key=[101, 0])
        assert 0 <= res.action <= N2

    def test_over_capacity_sheds_explicitly(self, direct):
        limit0 = direct.admission_limit
        try:
            direct.admission_limit = 2
            t1 = direct.submit([0] * N2, key=[1, 1])
            t2 = direct.submit([0] * N2, key=[2, 2])
            shed_before = direct.metrics.counters["shed_overload"]
            with pytest.raises(OverCapacityError):
                direct.submit([0] * N2, key=[3, 3])
            assert direct.metrics.counters["shed_overload"] \
                == shed_before + 1
        finally:
            direct.admission_limit = limit0
        for t in (t1, t2):
            assert direct.result(t) is not None

    def test_deadline_downgrade_cuts_traced_budget(self, direct):
        """A tight-but-meetable deadline downgrades instead of shedding."""
        policy0 = direct.deadline_policy
        try:
            direct.deadline_policy = DeadlinePolicy(
                base_s=0.0, sim_cost_s=1.0, floor_sims=2, slots=4,
                calibrate=False)          # 1 s/sim: SIMS sims never fit
            res = direct.best_move([0] * N2, key=[5, 5],
                                   deadline_ms=4000.0)
            assert res.downgraded and res.sims_granted == 4
            with pytest.raises(DeadlineExceededError):
                direct.submit([0] * N2, key=[6, 6], deadline_ms=500.0)
        finally:
            direct.deadline_policy = policy0

    def test_slo_traffic_adds_no_new_traces(self, direct):
        """Deadline/budget plumbing must not retrace the dispatch."""
        bucket = direct._bucket(KOMI)
        assert bucket._dispatch._cache_size() == 1
        assert bucket._push_serve._cache_size() == 1

    def test_result_timeout_instead_of_spin(self, direct):
        t = direct.submit([0] * N2, key=[7, 7])
        with pytest.raises(TimeoutError):
            direct.result(t, timeout_s=0.0)
        assert direct.result(t) is not None      # still answerable after
        with pytest.raises(KeyError):
            direct.result(999_999)


class TestHttpFrontDoor:
    def test_healthz_and_metrics(self, served):
        _, call = served
        status, body = call("GET", "/healthz")
        assert (status, body) == (200, {"ok": True})
        status, body = call("GET", "/metrics")
        assert status == 200
        assert body["metrics"]["completed"] >= 1
        assert body["buckets"] == [KOMI]
        assert set(body["metrics"]["total"]) >= {"p50_ms", "p95_ms",
                                                 "p99_ms", "count"}

    def test_no_deadline_path_bit_identical_to_direct(self, served,
                                                      direct):
        """Serve purity survives the wire: action + visits bit-equal."""
        _, call = served
        rng = np.random.default_rng(3)
        for i in range(3):
            board = np.zeros(N2, np.int8)
            board[rng.integers(0, N2)] = 1        # one black stone
            key = [int(rng.integers(1, 2 ** 31)), i]
            want = direct.best_move(board, key=key)
            status, got = call("POST", "/v1/best_move",
                               {"board": board.tolist(), "key": key})
            assert status == 200
            assert got["action"] == want.action
            assert got["is_pass"] == want.is_pass
            assert np.array_equal(
                np.asarray(got["root_visits"], np.float32),
                want.root_visits)
            assert not got["downgraded"] and not got["deadline_missed"]

    def test_submit_then_poll_result(self, served):
        _, call = served
        status, body = call("POST", "/v1/submit",
                            {"board": [0] * N2, "key": [11, 12]})
        assert status == 200
        ticket = body["ticket"]
        deadline = time.monotonic() + 60
        while True:
            status, body = call("GET", f"/v1/result/{ticket}")
            assert status == 200
            if body["done"]:
                break
            assert time.monotonic() < deadline, "result never landed"
            time.sleep(0.02)
        assert 0 <= body["action"] <= N2
        # fetched once -> gone
        status, body = call("GET", f"/v1/result/{ticket}")
        assert status == 404

    def test_over_capacity_is_503(self, served):
        gs, call = served
        limit0 = gs.admission_limit
        try:
            gs.admission_limit = -1               # every submit sheds
            status, body = call("POST", "/v1/best_move",
                                {"board": [0] * N2})
            assert status == 503
            assert body["error"] == "over_capacity"
        finally:
            gs.admission_limit = limit0

    def test_unmeetable_deadline_is_504(self, served):
        _, call = served
        status, body = call("POST", "/v1/best_move",
                            {"board": [0] * N2, "deadline_ms": 0.001})
        assert status == 504
        assert body["error"] == "deadline_shed"

    def test_bad_requests_are_400(self, served):
        _, call = served
        status, body = call("POST", "/v1/best_move", {"not_board": 1})
        assert status == 400
        status, body = call("POST", "/v1/best_move",
                            {"board": [0] * 7})   # wrong point count
        assert status == 400
        status, _ = call("GET", "/v1/result/not_an_int")
        assert status == 400
        status, _ = call("GET", "/nope")
        assert status == 404
