"""Regenerate tests/fixtures/eval9: a heuristic-distilled 9x9 eval net.

The win-rate sanity test (tests/test_evaluator.py, slow tier) needs a
checkpoint that is *deterministically better than uniform* without
shipping a real training run.  This script distils a classical Go
heuristic into the tiny EvalService transformer:

* policy target: softmax over legal moves of ``center preference +
  stone adjacency``, with pass strongly discouraged — enough signal
  that PUCT at small budgets clearly outplays uniform priors;
* value target: ``tanh((Tromp-Taylor score - komi) / 6)`` — current
  area lead as a black-perspective outcome estimate.

Positions are random-playout boards (uniform legal moves), so the net
sees the whole phase range.  The checkpoint directory
``tests/fixtures/eval9/`` is committed; rerun this script only to
refresh it:

    PYTHONPATH=src python tests/fixtures/distill_eval9.py
"""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import save_checkpoint
from repro.config import TrainConfig
from repro.core.evaluator import EvalConfig, EvalService
from repro.core.tree import normalize_prior
from repro.go import GoEngine
from repro.training.step import init_train_state, make_train_step

# keep in sync with FIXTURE_ECFG in tests/test_evaluator.py
ECFG = EvalConfig(board_size=9, d_model=16, num_layers=1, num_heads=2,
                  d_ff=32)
N_POSITIONS = 512
STEPS = 400
OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "eval9")


def heuristic_targets(engine: GoEngine, board: np.ndarray,
                      legal: np.ndarray):
    """(policy over A, value) targets for one position."""
    n = engine.size
    r, c = np.divmod(np.arange(n * n), n)
    center = (n - 1) / 2.0
    cheb = np.maximum(np.abs(r - center), np.abs(c - center))
    logits = 1.0 - 0.35 * cheb                      # center preference
    grid = board.reshape(n, n)
    occ = grid != 0
    near = np.zeros((n, n), bool)
    near[1:, :] |= occ[:-1, :]
    near[:-1, :] |= occ[1:, :]
    near[:, 1:] |= occ[:, :-1]
    near[:, :-1] |= occ[:, 1:]
    logits = logits + 0.8 * near.reshape(-1)        # contact moves
    logits = np.concatenate([logits, [-4.0]])       # pass: last resort
    masked = np.where(legal, logits, -1e9)
    e = np.exp(masked - masked.max())
    return e / e.sum(), float(np.tanh(
        (float(engine.score(jnp.asarray(board))) - engine.komi) / 6.0))


def make_batch(engine: GoEngine, evaluator: EvalService, n_pos: int,
               seed: int):
    rng = np.random.default_rng(seed)
    toks, legals, pols, vals = [], [], [], []
    for i in range(n_pos):
        st = engine.init_state()
        for _ in range(int(rng.integers(0, 50))):
            legal = np.asarray(engine.jit_legal(st))[: engine.n2]
            if not legal.any():
                break
            st = engine.jit_play(st, jnp.int32(rng.choice(
                np.where(legal)[0])))
        legal = np.asarray(engine.jit_legal(st))
        board = np.asarray(st.board)
        pol, val = heuristic_targets(engine, board, legal)
        toks.append(np.asarray(evaluator.tokens(st)))
        legals.append(legal)
        pols.append(pol)
        vals.append(val)
    return {"tokens": jnp.asarray(np.stack(toks), jnp.int32),
            "legal": jnp.asarray(np.stack(legals)),
            "policy": jnp.asarray(np.stack(pols), jnp.float32),
            "value": jnp.asarray(np.asarray(vals), jnp.float32)}


def main() -> None:
    engine = GoEngine(ECFG.board_size, komi=6.0)
    evaluator = EvalService(ECFG)
    batch = make_batch(engine, evaluator, N_POSITIONS, seed=0)

    tcfg = TrainConfig(steps=STEPS, lr=5e-3, warmup_steps=20,
                       weight_decay=0.0, z_loss=0.0, remat=False)
    state = init_train_state(evaluator, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(evaluator, tcfg))
    for i in range(STEPS):
        state, metrics = step(state, batch)
        if i % 100 == 0 or i == STEPS - 1:
            print(f"step {i:4d}: loss {float(metrics['loss']):.4f} "
                  f"ce {float(metrics['ce']):.4f}")
    # sanity: the distilled prior must prefer the center opening move
    st = engine.init_state()
    trained = EvalService(ECFG, params=state.params)
    prior = np.asarray(trained.prior_fn(st, engine.legal_moves(st)))
    print(f"center mass {prior[40]:.3f} vs corner {prior[0]:.3f} "
          f"vs pass {prior[-1]:.5f}")
    assert prior[40] > prior[0] and prior[40] > prior[-1]
    path = save_checkpoint(OUT, 1, state.params,
                           extra={"distilled": "center+contact heuristic"})
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
