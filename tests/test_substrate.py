"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
fault-tolerance runtime, PowerSGD compression, training loop convergence."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # training-loop convergence runs: full tier

from repro.optim import (adamw, adafactor, sgdm, clip_by_global_norm,
                         global_norm, make_schedule)


class TestOptimizers:
    def _quadratic_converges(self, opt, lr=0.1, steps=200):
        params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
        target = {"w": jnp.asarray([0.5, 0.5]), "b": jnp.asarray(-0.25)}
        state = opt.init(params)

        def loss(p):
            return sum(jnp.sum((a - b) ** 2)
                       for a, b in zip(jax.tree.leaves(p),
                                       jax.tree.leaves(target)))

        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, lr)
        return float(loss(params))

    def test_adamw_converges(self):
        assert self._quadratic_converges(adamw(weight_decay=0.0)) < 1e-3

    def test_adafactor_converges(self):
        assert self._quadratic_converges(adafactor(), lr=0.3) < 1e-2

    def test_sgdm_converges(self):
        assert self._quadratic_converges(sgdm(), lr=0.05) < 1e-3

    def test_adamw_matches_reference_formula(self):
        opt = adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
        p = {"w": jnp.asarray([1.0])}
        g = {"w": jnp.asarray([0.5])}
        st = opt.init(p)
        p2, st2 = opt.update(g, st, p, 0.1)
        # step1: m=0.05 v=0.00025/... bias-corrected => update = g/|g| = 1
        expect = 1.0 - 0.1 * (0.5 / (np.sqrt(0.25) + 1e-8 / 1))
        np.testing.assert_allclose(float(p2["w"][0]), expect, rtol=1e-5)

    def test_adafactor_state_is_factored(self):
        opt = adafactor()
        p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
        st = opt.init(p)
        assert st.vr["w"].shape == (64,)
        assert st.vc["w"].shape == (32,)
        assert st.vr["b"].shape == (64,)
        # factored state is ~ (m+n) not m*n
        total = sum(x.size for x in jax.tree.leaves((st.vr, st.vc)))
        assert total < 64 * 32 / 4

    def test_bf16_params_stay_bf16(self):
        opt = adamw()
        p = {"w": jnp.ones((4,), jnp.bfloat16)}
        g = {"w": jnp.ones((4,), jnp.bfloat16)}
        st = opt.init(p)
        p2, _ = opt.update(g, st, p, 0.01)
        assert p2["w"].dtype == jnp.bfloat16

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.asarray([3.0, 4.0])}
        clipped, norm = clip_by_global_norm(tree, 1.0)
        np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
        np.testing.assert_allclose(float(global_norm(clipped)), 1.0,
                                   rtol=1e-5)


class TestSchedule:
    def test_warmup_then_cosine(self):
        s = make_schedule("cosine", 1e-3, 100, 1000)
        assert float(s(0)) == 0.0
        np.testing.assert_allclose(float(s(50)), 5e-4, rtol=1e-6)
        np.testing.assert_allclose(float(s(100)), 1e-3, rtol=1e-6)
        assert float(s(1000)) < float(s(500)) < float(s(100))
        np.testing.assert_allclose(float(s(1000)), 1e-4, rtol=1e-3)

    def test_linear(self):
        s = make_schedule("linear", 1.0, 0, 100, final_frac=0.0)
        np.testing.assert_allclose(float(s(50)), 0.5, rtol=1e-5)


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        from repro.data import SyntheticLM
        from repro.configs.reduced import reduced
        cfg = reduced("yi-6b")
        src = SyntheticLM(cfg, seq_len=16, global_batch=4, seed=7)
        b1 = src.batch_at(10)
        b2 = src.batch_at(10)       # same step => identical batch (resume)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = src.batch_at(11)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_families_have_right_keys(self):
        from repro.data import SyntheticLM
        from repro.configs.reduced import reduced
        for arch, keys in [("hubert-xlarge", {"frontend", "labels", "mask"}),
                           ("llava-next-mistral-7b",
                            {"tokens", "labels", "frontend"}),
                           ("mamba2-2.7b", {"tokens", "labels"})]:
            cfg = reduced(arch)
            seq = 32 + cfg.frontend_tokens
            b = SyntheticLM(cfg, seq, 2).batch_at(0)
            assert set(b) == keys, arch

    def test_memmap_tokens(self, tmp_path):
        from repro.data import MemmapTokens
        path = str(tmp_path / "toks.bin")
        np.arange(10000, dtype=np.int32).tofile(path)
        src = MemmapTokens(path, seq_len=32, global_batch=4, seed=0)
        b = src.batch_at(3)
        assert b["tokens"].shape == (4, 32)
        # labels are next-token shifted
        np.testing.assert_array_equal(b["labels"][:, :-1],
                                      b["tokens"][:, 1:])

    def test_prefetcher_overlaps(self):
        from repro.data import Prefetcher
        calls = []

        def batch_fn(step):
            calls.append(step)
            return {"x": np.full((2,), step)}

        pf = Prefetcher(batch_fn, start_step=5, depth=2)
        s, b = next(pf)
        assert s == 5 and b["x"][0] == 5
        s, b = next(pf)
        assert s == 6
        pf.close()


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"params": {"w": jax.random.normal(k, (8, 4)),
                           "b": jnp.zeros((4,))},
                "opt": {"m": jnp.ones((8, 4)) * 0.5},
                "none_leaf": None}

    def test_roundtrip(self, tmp_path):
        from repro.ckpt import save_checkpoint, restore_checkpoint
        tree = self._tree()
        save_checkpoint(str(tmp_path), 42, tree, extra={"data_step": 42})
        got, step, extra = restore_checkpoint(str(tmp_path), tree)
        assert step == 42 and extra["data_step"] == 42
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_and_gc(self, tmp_path):
        from repro.ckpt import (AsyncCheckpointer, latest_step,
                                restore_checkpoint)
        ck = AsyncCheckpointer(str(tmp_path), keep=2)
        tree = self._tree()
        for s in (1, 2, 3):
            ck.save(s, tree)
        ck.wait()
        assert latest_step(str(tmp_path)) == 3
        kept = sorted(os.listdir(tmp_path))
        assert "step_00000001" not in kept          # gc'd
        got, step, _ = restore_checkpoint(str(tmp_path), tree)
        assert step == 3

    def test_corruption_detected(self, tmp_path):
        from repro.ckpt import save_checkpoint, restore_checkpoint
        tree = self._tree()
        path = save_checkpoint(str(tmp_path), 1, tree)
        # flip a byte in one leaf
        victim = [f for f in os.listdir(path) if f.endswith(".npy")][0]
        fp = os.path.join(path, victim)
        raw = bytearray(open(fp, "rb").read())
        raw[-1] ^= 0xFF
        open(fp, "wb").write(bytes(raw))
        with pytest.raises(IOError):
            restore_checkpoint(str(tmp_path), tree)

    def test_elastic_reshard_restore(self, tmp_path):
        """Save unsharded, restore under an explicit (new) sharding."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.ckpt import save_checkpoint, restore_checkpoint
        tree = {"w": jnp.arange(16.0).reshape(8, 2)}
        save_checkpoint(str(tmp_path), 7, tree)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        got, _, _ = restore_checkpoint(str(tmp_path), tree, shardings=sh)
        assert got["w"].sharding.is_equivalent_to(sh["w"], 2)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))


class TestRuntimeFT:
    def test_preemption_flag(self):
        from repro.runtime import PreemptionHandler
        h = PreemptionHandler(signals=())
        assert not h.should_stop
        h.trigger()
        assert h.should_stop

    def test_heartbeat_and_straggler(self, tmp_path):
        from repro.runtime import Heartbeat, StragglerMonitor
        for host, t in [(0, 1.0), (1, 1.1), (2, 5.0), (3, 0.9)]:
            Heartbeat(str(tmp_path), host).beat(step=10, step_time_s=t)
        mon = StragglerMonitor(str(tmp_path), straggler_factor=2.0)
        assert mon.stragglers() == [2]
        assert mon.dead_hosts() == []
        assert mon.dead_hosts(now=time.time() + 120) == [0, 1, 2, 3]

    def test_elastic_mesh(self):
        from repro.runtime import elastic_mesh_for
        assert elastic_mesh_for(512, 16) == (32, 16)
        assert elastic_mesh_for(496, 16) == (31, 16)   # lost a host: DP -16
        assert elastic_mesh_for(8, 16) == (1, 8)       # degenerate TP shrink


class TestPowerSGD:
    def test_compress_decompress_rank_sufficient(self):
        from repro.parallel.compress import (init_powersgd, powersgd_compress,
                                             powersgd_decompress)
        # rank-2 matrix compressed at rank 4 -> near-exact after 1 iter
        a = jnp.outer(jnp.arange(1.0, 9.0), jnp.ones(8))
        b = jnp.outer(jnp.ones(8), jnp.arange(1.0, 9.0))
        g = {"w": a + b}
        st = init_powersgd(g, rank=4)
        p, q, m = powersgd_compress(g["w"], st.q["w"], st.error["w"])
        approx = powersgd_decompress(p, q, g["w"].shape)
        np.testing.assert_allclose(np.asarray(approx), np.asarray(g["w"]),
                                   rtol=1e-4, atol=1e-4)

    def test_error_feedback_accumulates(self):
        from repro.parallel.compress import init_powersgd
        g = {"w": jnp.eye(16), "tiny": jnp.ones((3,))}
        st = init_powersgd(g, rank=2)
        assert st.q["w"].shape == (16, 2)
        assert st.q["tiny"].size == 0      # uncompressed leaf placeholder
        assert st.error["w"].shape == (16, 16)


class TestTrainLoopIntegration:
    def test_loss_decreases_small_lm(self):
        """End-to-end: reduced dense LM + AdamW on a learnable synthetic
        task for 30 steps -> loss must drop."""
        from repro.config import TrainConfig
        from repro.configs.reduced import reduced
        from repro.models import build_model
        from repro.training import init_train_state, make_train_step
        import dataclasses

        cfg = dataclasses.replace(reduced("yi-6b"), vocab_size=64)
        model = build_model(cfg)
        tcfg = TrainConfig(steps=30, microbatches=2, optimizer="adamw",
                           lr=3e-3, warmup_steps=5, grad_clip=1.0)
        state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
        step_fn = jax.jit(make_train_step(model, tcfg))

        # learnable task: fixed token sequence repeated (memorise it)
        rng = np.random.default_rng(0)
        seq = rng.integers(0, 64, (1, 17), dtype=np.int32)
        batch = {"tokens": jnp.asarray(np.repeat(seq[:, :-1], 4, 0)),
                 "labels": jnp.asarray(np.repeat(seq[:, 1:], 4, 0))}

        losses = []
        for _ in range(30):
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] * 0.5, losses[:3] + losses[-3:]

    def test_checkpoint_restart_resumes_identically(self, tmp_path):
        from repro.config import TrainConfig
        from repro.configs.reduced import reduced
        from repro.models import build_model
        from repro.training import init_train_state, make_train_step
        from repro.ckpt import save_checkpoint, restore_checkpoint
        from repro.data import SyntheticLM

        cfg = reduced("yi-6b")
        model = build_model(cfg)
        tcfg = TrainConfig(steps=10, microbatches=1, lr=1e-3, warmup_steps=2)
        step_fn = jax.jit(make_train_step(model, tcfg))
        data = SyntheticLM(cfg, 16, 2, seed=3)

        def to_batch(b):
            return {k: jnp.asarray(v) for k, v in b.items()}

        state = init_train_state(model, tcfg, jax.random.PRNGKey(1))
        for s in range(4):
            state, _ = step_fn(state, to_batch(data.batch_at(s)))
        save_checkpoint(str(tmp_path), 4, state._asdict())
        # continue original
        cont = state
        for s in range(4, 7):
            cont, m_a = step_fn(cont, to_batch(data.batch_at(s)))
        # restart from checkpoint (data resumes by step => same batches)
        got, step, _ = restore_checkpoint(str(tmp_path), state._asdict())
        from repro.training.step import TrainState
        res = TrainState(**got)
        for s in range(4, 7):
            res, m_b = step_fn(res, to_batch(data.batch_at(s)))
        np.testing.assert_allclose(float(m_a["loss"]), float(m_b["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(cont.params),
                        jax.tree.leaves(res.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
