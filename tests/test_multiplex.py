"""Heterogeneous multiplexing tests: per-slot traced (c_uct, virtual_loss).

The PR 4 tentpole contract, three invariants:

* **bit-identity** — a pool whose requests carry explicit traced params
  equal to the players' static configs plays bit-for-bit the games (and
  answers bit-for-bit the queries) of the static PR 3 path, and a mixed
  pool's serve answers equal each config's dedicated single-config pool;
* **no retrace** — >= 3 distinct (c_uct, virtual_loss, sims) configs share
  exactly one compiled dispatch, under both ``mesh=None`` and a device
  mesh (the 8-fake-device variant lives in tests/test_sharded_service.py);
* **tournament multiplexing** — the all-play-all scheduler runs every
  pairing through one pool/one trace and derives a consistent cross
  table (win matrix, points, Elo).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MCTSConfig
from repro.core.mcts import MCTS, SearchParams
from repro.core.service import SearchService
from repro.core.tournament import Tournament, elo_ratings, trace_compatible

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
CAP = 12
# three trace-compatible configurations (only traced fields differ)
CONFIGS = (CFG,
           dataclasses.replace(CFG, c_uct=1.7, virtual_loss=2.5),
           dataclasses.replace(CFG, c_uct=0.4, virtual_loss=0.0,
                               sims_per_move=4))


@pytest.fixture(scope="module")
def base_player(engine5):
    return MCTS(engine5, CFG)


@pytest.fixture(scope="module")
def mid_state(engine5):
    st = engine5.init_state()
    for mv in (3, 7, 12, 16):
        st = engine5.jit_play(st, jnp.int32(mv))
    return st


def _serve_all(svc, mid_state, queries):
    """Submit (key, sims, c_uct, vl) queries; return results by ticket."""
    tickets = [svc.submit_serve(mid_state, key=k, sims=s, c_uct=c,
                                virtual_loss=v)
               for (k, s, c, v) in queries]
    recs = {r.ticket: r for r in svc.drain()}
    return [recs[t] for t in tickets]


class TestSearchBatchParams:
    def test_params_equal_to_config_bit_identical(self, engine5,
                                                  base_player):
        """Traced params carrying the config constants reproduce the
        static path exactly (the homogeneous acceptance invariant)."""
        roots = jax.tree.map(lambda x: x[None], engine5.init_state())
        key = jax.random.PRNGKey(4)[None]
        base = base_player.search_batch(roots, key)
        got = base_player.search_batch(
            roots, key,
            params=SearchParams(jnp.asarray([CFG.c_uct]),
                                jnp.asarray([CFG.virtual_loss])))
        assert int(got.action[0]) == int(base.action[0])
        np.testing.assert_array_equal(np.asarray(got.root_visits),
                                      np.asarray(base.root_visits))
        np.testing.assert_array_equal(np.asarray(got.tree.visit),
                                      np.asarray(base.tree.visit))

    def test_params_match_statically_configured_player(self, engine5,
                                                       base_player):
        """search_batch(params=(c, v)) == a player whose MCTSConfig bakes
        (c, v) statically — for every heterogeneous config."""
        roots = jax.tree.map(lambda x: x[None], engine5.init_state())
        key = jax.random.PRNGKey(7)[None]
        for cfg in CONFIGS[1:]:
            want = MCTS(engine5, dataclasses.replace(
                cfg, sims_per_move=CFG.sims_per_move)).search_batch(
                    roots, key)
            got = base_player.search_batch(
                roots, key,
                params=SearchParams(jnp.asarray([cfg.c_uct]),
                                    jnp.asarray([cfg.virtual_loss])))
            np.testing.assert_array_equal(np.asarray(got.root_visits),
                                          np.asarray(want.root_visits))

    def test_params_are_traced_not_static(self, engine5, base_player):
        """Changing (c_uct, vl_weight) values must not recompile."""
        fn = jax.jit(base_player.search_batch)
        roots = jax.tree.map(lambda x: x[None], engine5.init_state())
        key = jax.random.PRNGKey(0)[None]
        for cfg in CONFIGS:
            fn(roots, key, jnp.asarray([cfg.sims_per_move], jnp.int32),
               SearchParams(jnp.asarray([cfg.c_uct]),
                            jnp.asarray([cfg.virtual_loss])))
        assert fn._cache_size() == 1


class TestMixedConfigPool:
    def test_explicit_params_bit_identical_to_static_players(self, engine5):
        """A pool of base players + per-game traced (c_uct, vl) plays
        bit-for-bit the games of a pool whose players bake the same
        values statically (the PR 3 path) — including an asymmetric
        A-side/B-side pairing.  (Budgets stay at the shared loop bound:
        the traced ``sims`` contract is full-budget bit-identity plus
        masked truncation, PR 2.)"""
        cfg_a = dataclasses.replace(CFG, c_uct=1.7, virtual_loss=2.5)
        cfg_b = dataclasses.replace(CFG, c_uct=0.4, virtual_loss=0.5)
        static = SearchService(engine5, MCTS(engine5, cfg_a),
                               MCTS(engine5, cfg_b), slots=2, max_moves=CAP)
        shared = MCTS(engine5, CFG)
        traced = SearchService(engine5, shared, shared, slots=2,
                               max_moves=CAP)
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(5), 4))

        def run(svc, **kw):
            svc.reset(seed=0, colour_cap=2)
            tickets = [svc.submit_game(key=keys[i], **kw) for i in range(4)]
            recs = {r.ticket: r for r in svc.drain()}
            return [recs[t] for t in tickets]

        want = run(static)
        got = run(traced,
                  c_uct=(cfg_a.c_uct, cfg_b.c_uct),
                  virtual_loss=(cfg_a.virtual_loss, cfg_b.virtual_loss))
        for w, g in zip(want, got):
            assert w[:7] == g[:7]           # every scalar result field
            np.testing.assert_array_equal(w.root_visits, g.root_visits)

    def test_mixed_serve_matches_single_config_pools(self, engine5,
                                                     base_player,
                                                     mid_state):
        """Each config's answers from one mixed pool equal a dedicated
        pool statically configured for it, interleaved arbitrarily."""
        mixed = SearchService(engine5, base_player, base_player, slots=4,
                              max_moves=CAP)
        mixed.reset(seed=0)
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(11),
                                           len(CONFIGS)))
        queries = [(keys[n], cfg.sims_per_move, cfg.c_uct, cfg.virtual_loss)
                   for n, cfg in enumerate(CONFIGS)]
        got = _serve_all(mixed, mid_state, queries)
        for n, cfg in enumerate(CONFIGS):
            single_cfg = dataclasses.replace(
                cfg, sims_per_move=CFG.sims_per_move)   # same static shape
            player = MCTS(engine5, single_cfg)
            single = SearchService(engine5, player, player, slots=4,
                                   max_moves=CAP)
            single.reset(seed=0)
            want = _serve_all(single, mid_state,
                              [(keys[n], cfg.sims_per_move, None, None)])[0]
            assert got[n].action == want.action
            np.testing.assert_array_equal(got[n].root_visits,
                                          want.root_visits)

    def test_one_trace_across_three_configs(self, engine5, base_player,
                                            mid_state):
        """>= 3 distinct (c_uct, virtual_loss) pairs, zero retraces of the
        dispatch or the push paths (mesh=None; the sharded twin lives in
        tests/test_sharded_service.py)."""
        svc = SearchService(engine5, base_player, base_player, slots=4,
                            max_moves=CAP)
        for seed, cfg in enumerate(CONFIGS):
            svc.reset(seed=seed)
            svc.submit_game(sims=cfg.sims_per_move, c_uct=cfg.c_uct,
                            virtual_loss=cfg.virtual_loss)
            svc.submit_serve(mid_state, c_uct=cfg.c_uct,
                             virtual_loss=cfg.virtual_loss)
            assert len(svc.drain()) == 2
        assert svc._dispatch._cache_size() == 1
        assert svc._push_games._cache_size() == 1
        assert svc._push_serve._cache_size() == 1


class TestMultiplexedTournament:
    def test_all_play_all_one_pool_one_trace(self, engine5):
        t = Tournament(engine5, CONFIGS, names=("base", "hot", "cold"),
                       games_per_pair=4, max_moves=CAP, seed=3)
        assert t.multiplex
        res = t.round_robin()
        assert res.games == 4 * 3
        # one pool, one compiled dispatch for all three pairings
        assert t.service is not None
        assert t.service._dispatch._cache_size() == 1
        # cross-table consistency
        assert res.points.sum() == pytest.approx(res.games)
        np.testing.assert_allclose(
            res.win_matrix.sum(axis=1), res.points)
        assert res.elo.sum() == pytest.approx(0.0, abs=1e-6)
        assert res.elo.shape == (3,)
        for (i, j), pr in res.pairs.items():
            assert pr.i_wins + pr.j_wins + pr.draws == 4
            assert res.win_matrix[i, j] == pr.i_wins + 0.5 * pr.draws
        assert "elo" in res.table()

    def test_multiplex_validation_and_fallback(self, engine5):
        from repro.core.selfplay import double_resources
        incompatible = [CFG, double_resources(CFG)]    # lanes differ
        assert not trace_compatible(list(incompatible))
        with pytest.raises(ValueError):
            Tournament(engine5, incompatible, multiplex=True)
        t = Tournament(engine5, incompatible)
        assert not t.multiplex                         # auto-fallback
        assert trace_compatible(list(CONFIGS))

    def test_elo_orders_a_dominant_player(self):
        score = np.array([[0.0, 3.5, 4.0],
                          [0.5, 0.0, 2.0],
                          [0.0, 2.0, 0.0]])
        games = np.array([[0, 4, 4], [4, 0, 4], [4, 4, 0]], float)
        elo = elo_ratings(score, games)
        assert elo[0] > elo[1] > elo[2]
        assert elo.sum() == pytest.approx(0.0, abs=1e-9)


class TestGoServiceStrengthKnob:
    @pytest.fixture(scope="class")
    def go_service(self):
        from repro.serving.go_service import GoService
        return GoService(board_size=5, komi=0.5, max_sims=8, lanes=2,
                         slots=4, seed=0)

    def test_per_query_knob_matches_static_bucket(self, go_service,
                                                  engine5):
        """A query with c_uct/virtual_loss overrides equals the search of
        a player statically configured with those values, and the default
        (None) stays bit-identical to omitting the knob."""
        board = np.zeros(25, np.int8)
        board[12] = 1
        key = np.asarray(jax.random.PRNGKey(8))
        plain = go_service.best_move(board, to_play=-1, key=key)
        dflt = go_service.best_move(board, to_play=-1, key=key,
                                    c_uct=None, virtual_loss=None)
        assert plain.action == dflt.action
        np.testing.assert_array_equal(plain.root_visits, dflt.root_visits)

        hot = go_service.best_move(board, to_play=-1, key=key, c_uct=2.5,
                                   virtual_loss=0.5)
        bucket = go_service._buckets[0.5]
        cfg = dataclasses.replace(bucket.player_a.cfg, c_uct=2.5,
                                  virtual_loss=0.5)
        want = MCTS(bucket.engine, cfg).search_batch(
            jax.tree.map(lambda x: x[None],
                         bucket.engine.init_state()._replace(
                             board=jnp.asarray(board),
                             to_play=jnp.int8(-1))),
            jnp.asarray(key)[None])
        assert hot.action == int(want.action[0])
        np.testing.assert_array_equal(hot.root_visits,
                                      np.asarray(want.root_visits[0]))
        # the overrides reused the bucket's compiled dispatch
        assert bucket._dispatch._cache_size() == 1
