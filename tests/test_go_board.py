"""Go engine rules tests: groups, liberties, capture, suicide, ko, eyes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.go import BLACK, WHITE
from repro.go.board import NO_KO


def put(engine, stones):
    b = np.zeros(engine.n2, np.int8)
    for p, c in stones.items():
        b[p] = c
    return jnp.asarray(b)


class TestGroups:
    def test_single_stone_liberties(self, engine5):
        b = put(engine5, {12: BLACK})            # centre of 5x5
        ids, libs = engine5.group_info(b)
        assert int(libs[12]) == 4
        assert int(ids[12]) == 12

    def test_corner_liberties(self, engine5):
        b = put(engine5, {0: BLACK})
        _, libs = engine5.group_info(b)
        assert int(libs[0]) == 2

    def test_group_merge_shares_liberties(self, engine5):
        # two adjacent stones: 6 distinct liberties on 5x5 interior row
        b = put(engine5, {11: BLACK, 12: BLACK})
        ids, libs = engine5.group_info(b)
        assert int(ids[11]) == int(ids[12])
        assert int(libs[11]) == int(libs[12]) == 6

    def test_liberty_not_double_counted(self, engine5):
        # diagonal stones sharing two common liberty points stay separate
        b = put(engine5, {6: BLACK, 12: BLACK})
        ids, libs = engine5.group_info(b)
        assert int(ids[6]) != int(ids[12])
        assert int(libs[6]) == 4 and int(libs[12]) == 4

    def test_enemy_reduces_liberties(self, engine5):
        b = put(engine5, {12: BLACK, 11: WHITE})
        _, libs = engine5.group_info(b)
        assert int(libs[12]) == 3
        assert int(libs[11]) == 3


class TestCapture:
    def test_corner_capture(self, engine5):
        st = engine5.init_state()
        st = engine5.play(st, 1)   # B (0,1)
        st = engine5.play(st, 0)   # W corner
        st = engine5.play(st, 5)   # B (1,0): captures
        assert int(st.board[0]) == 0

    def test_multi_stone_capture(self, engine5):
        st = engine5.init_state()
        b = put(engine5, {1: WHITE, 2: WHITE,          # white pair on top edge
                          0: BLACK, 5: BLACK, 6: BLACK, 7: BLACK, 8: BLACK})
        st = st._replace(board=b)
        st = engine5.play(st, 3)   # B seals the last liberty
        assert int(st.board[1]) == 0 and int(st.board[2]) == 0

    def test_atari_then_capture(self, engine5):
        # white corner stone with one liberty survives until it is filled
        st = engine5.init_state()
        b = put(engine5, {0: WHITE, 1: BLACK})
        st = st._replace(board=b, to_play=jnp.int8(BLACK))
        _, libs = engine5.group_info(st.board)
        assert int(libs[0]) == 1           # atari
        st2 = engine5.play(st, 5)          # black fills the last liberty
        assert int(st2.board[0]) == 0      # captured now, not before


class TestLegality:
    def test_suicide_illegal(self, engine5):
        st = engine5.init_state()
        b = put(engine5, {1: BLACK, 5: BLACK})
        st = st._replace(board=b, to_play=jnp.int8(WHITE))
        legal = engine5.legal_moves(st)
        assert not bool(legal[0])

    def test_multi_stone_suicide_illegal(self, engine5):
        # white group of 2 would have zero liberties
        st = engine5.init_state()
        b = put(engine5, {0: BLACK, 2: BLACK, 5: BLACK, 7: BLACK, 10: BLACK,
                          12: BLACK, 11: BLACK, 1: WHITE})
        st = st._replace(board=b, to_play=jnp.int8(WHITE))
        legal = engine5.legal_moves(st)
        assert not bool(legal[6])

    def test_capture_in_enemy_eye_is_legal(self, engine5):
        # playing inside an enemy eye is legal when it captures
        st = engine5.init_state()
        b = put(engine5, {1: BLACK, 5: BLACK,            # black corner group
                          2: WHITE, 6: WHITE, 10: WHITE})  # white surrounds
        st = st._replace(board=b, to_play=jnp.int8(WHITE))
        legal = engine5.legal_moves(st)
        assert bool(legal[0])  # W at corner captures nothing... black 1,5 have libs
        # tighter: black group {1,5} liberties: 0? nbrs of 1: 0,2,6; of 5: 0,6,10
        _, libs = engine5.group_info(b)
        assert int(libs[1]) == 1  # only the corner
        st2 = engine5.play(st, 0)
        assert int(st2.board[1]) == 0 and int(st2.board[5]) == 0

    def test_pass_always_legal(self, engine5):
        legal = engine5.legal_moves(engine5.init_state())
        assert bool(legal[engine5.pass_action])

    def test_occupied_illegal(self, engine5):
        st = engine5.play(engine5.init_state(), 12)
        assert not bool(engine5.legal_moves(st)[12])


class TestKo:
    def _ko_state(self, engine5):
        st = engine5.init_state()
        b = put(engine5, {1: BLACK, 5: BLACK, 11: BLACK,
                          2: WHITE, 8: WHITE, 12: WHITE, 6: WHITE})
        return st._replace(board=b, to_play=jnp.int8(BLACK))

    def test_ko_point_set(self, engine5):
        st = engine5.play(self._ko_state(engine5), 7)  # B captures W at 6
        assert int(st.board[6]) == 0
        assert int(st.ko) == 6

    def test_ko_retake_illegal(self, engine5):
        st = engine5.play(self._ko_state(engine5), 7)
        assert not bool(engine5.legal_moves(st)[6])

    def test_ko_cleared_after_other_move(self, engine5):
        st = engine5.play(self._ko_state(engine5), 7)
        st = engine5.play(st, 20)  # white plays elsewhere
        assert int(st.ko) == NO_KO

    def test_multi_capture_no_ko(self, engine5):
        st = engine5.init_state()
        b = put(engine5, {1: WHITE, 2: WHITE, 0: BLACK, 5: BLACK,
                          6: BLACK, 7: BLACK, 8: BLACK})
        st = st._replace(board=b, to_play=jnp.int8(BLACK))
        st = engine5.play(st, 3)
        assert int(st.ko) == NO_KO


class TestEyesAndPlayout:
    def test_true_eye_detected(self, engine9):
        # black ring around (1,1)=10 in the corner region
        stones = {1: BLACK, 9: BLACK, 11: BLACK, 19: BLACK,
                  0: BLACK, 2: BLACK, 18: BLACK, 20: BLACK}
        b = put(engine9, stones)
        eyes = engine9.true_eyes(b, BLACK)
        assert bool(eyes[10])

    def test_eye_with_two_enemy_diagonals_rejected(self, engine9):
        stones = {1: BLACK, 9: BLACK, 11: BLACK, 19: BLACK,
                  0: WHITE, 2: WHITE, 18: BLACK, 20: BLACK}
        b = put(engine9, stones)
        eyes = engine9.true_eyes(b, BLACK)
        assert not bool(eyes[10])

    def test_playout_mask_excludes_own_eye(self, engine9):
        stones = {1: BLACK, 9: BLACK, 11: BLACK, 19: BLACK,
                  0: BLACK, 2: BLACK, 18: BLACK, 20: BLACK}
        st = engine9.init_state()._replace(board=put(engine9, stones))
        mask = engine9.playout_mask(st)
        assert not bool(mask[10])

    def test_playout_terminates_and_scores(self, engine5, rng):
        final = engine5.random_playout(engine5.init_state(), rng)
        assert bool(final.done)
        v = engine5.result(final)
        assert int(v) in (-1, 0, 1)


class TestScoring:
    def test_empty_board_draw_pre_komi(self, engine5):
        assert float(engine5.score(jnp.zeros(25, jnp.int8))) == 0.0

    def test_all_black(self, engine5):
        b = put(engine5, {12: BLACK})
        assert float(engine5.score(b)) == 25.0

    def test_split_board(self, engine9):
        # black wall on column 4 of 9x9 row 0..8? build wall on row 4
        stones = {4 * 9 + c: BLACK for c in range(9)}
        stones.update({6 * 9 + 4: WHITE})
        b = put(engine9, stones)
        s = float(engine9.score(b))
        # black: wall 9 + rows 0-3 territory 36 = 45; white: 1 stone; the
        # empty region below the wall touches both colours -> dame (TT rules)
        assert s == (9 + 36) - 1

    def test_game_end_two_passes(self, engine5):
        st = engine5.init_state()
        st = engine5.play(st, engine5.pass_action)
        assert not bool(st.done)
        st = engine5.play(st, engine5.pass_action)
        assert bool(st.done)


@pytest.mark.slow
class TestInvariantsProperty:
    """Property-style: random move sequences keep board invariants."""

    @pytest.mark.parametrize("seed", range(4))
    def test_no_zero_liberty_groups_ever(self, engine5, seed):
        key = jax.random.PRNGKey(seed)
        st = engine5.init_state()
        for _ in range(30):
            key, sub = jax.random.split(key)
            mask = engine5.playout_mask(st)
            if not bool(mask[: engine5.n2].any()):
                break
            st = engine5.playout_step(st, sub)
            _, libs = engine5.group_info(st.board)
            stone = np.asarray(st.board) != 0
            assert (np.asarray(libs)[stone] > 0).all(), \
                "a group with zero liberties survived"

    @pytest.mark.parametrize("seed", range(2))
    def test_vmap_matches_sequential(self, engine5, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        singles = [engine5.playout_value(engine5.init_state(), k)
                   for k in keys]
        batched = jax.vmap(
            lambda k: engine5.playout_value(engine5.init_state(), k))(keys)
        np.testing.assert_array_equal(np.asarray(singles),
                                      np.asarray(batched))
