"""Sharded SearchService tests (core/service.py mesh= + core/placement.py).

Three tiers:

* placement-policy unit tests — pure host-side numpy, run anywhere;
* one-shard oracle tests — a 1-device mesh in the normal process pins the
  shard_map-wrapped dispatch bit-for-bit against the PR 2 single-device
  dispatcher (the tentpole acceptance invariant);
* multi-device tests — run in-process when the suite already sees >= 8
  devices (CI's test-multidevice job sets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), plus a
  slow-marked subprocess test so single-device tier-1 runs still exercise
  the 8-shard paths (tests/test_distributed.py discipline).
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.compat import make_service_mesh
from repro.config import MCTSConfig
from repro.core import placement
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources
from repro.core.service import LANE_SERVE, SearchService

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
CAP = 12
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


class TestPlacementPolicies:
    def test_round_robin_cycles_and_skips_full(self):
        pol = placement.PlacementPolicy("round_robin", 3)
        assert [pol.choose(placement.CLS_GAME, 2) for _ in range(6)] \
            == [0, 1, 2, 0, 1, 2]
        assert pol.choose(placement.CLS_GAME, 2) is None    # all full
        pol.release(placement.CLS_GAME, 1)
        # the cursor skips still-full shards to the reopened one
        assert pol.choose(placement.CLS_GAME, 2) == 1

    def test_fill_first_saturates_lowest_shard(self):
        pol = placement.PlacementPolicy("fill_first", 3)
        assert [pol.choose(placement.CLS_GAME, 2) for _ in range(4)] \
            == [0, 0, 1, 1]

    def test_colour_balanced_tracks_least_loaded(self):
        pol = placement.PlacementPolicy("colour_balanced", 3)
        assert [pol.choose(placement.CLS_GAME, 4) for _ in range(4)] \
            == [0, 1, 2, 0]
        pol.release(placement.CLS_GAME, 2)
        assert pol.choose(placement.CLS_GAME, 4) == 2       # refilled hole

    def test_classes_tracked_independently(self):
        pol = placement.PlacementPolicy("round_robin", 2)
        assert pol.choose(placement.CLS_GAME, 4) == 0
        assert pol.choose(placement.CLS_SERVE, 4) == 0
        assert pol.choose(placement.CLS_GAME, 4) == 1

    def test_config_affine_sticks_then_falls_back(self):
        pol = placement.PlacementPolicy("config_affine", 3)
        k1, k2 = ("cfgA",), ("cfgB",)
        # first sighting: least loaded; repeats stick to the same shard
        assert pol.choose(placement.CLS_GAME, 2, config_key=k1) == 0
        assert pol.choose(placement.CLS_GAME, 2, config_key=k1) == 0
        # a different config key lands on the least-loaded shard
        assert pol.choose(placement.CLS_GAME, 2, config_key=k2) == 1
        # k1's shard is full -> displaced to least-loaded, new affinity
        assert pol.choose(placement.CLS_GAME, 2, config_key=k1) == 2
        assert pol.choose(placement.CLS_GAME, 2, config_key=k1) == 2

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError):
            placement.place("spiral", 0, np.zeros(2, np.int64), 4)
        with pytest.raises(ValueError):
            placement.PlacementPolicy("spiral", 2)


@pytest.fixture(scope="module")
def players(engine5):
    return MCTS(engine5, double_resources(CFG)), MCTS(engine5, CFG)


@pytest.fixture(scope="module")
def mid_state(engine5):
    import jax.numpy as jnp
    st = engine5.init_state()
    for mv in (3, 7, 12, 16):
        st = engine5.jit_play(st, jnp.int32(mv))
    return st


def _run_games_and_serve(svc, games, serves, mid_state, seed=0,
                         assignments=None):
    svc.reset(seed=seed, colour_cap=(games + 1) // 2 or 1,
              game_capacity=max(2, games))
    gk = np.asarray(jax.random.split(jax.random.PRNGKey(7), max(1, games)))
    sk = np.asarray(jax.random.split(jax.random.PRNGKey(9), max(1, serves)))
    tickets = [svc.submit_game(key=gk[i]) for i in range(games)]
    tickets += [svc.submit_serve(mid_state, key=sk[i])
                for i in range(serves)]
    if assignments is not None:       # ticket -> host-assigned shard
        assignments.update({t: svc._assigned[t][1] for t in tickets})
    return tickets, {r.ticket: r for r in svc.drain()}


class TestOneShardOracle:
    """mesh over one device == the PR 2 single-device dispatcher."""

    def test_bit_identical_to_plain_dispatcher(self, engine5, players,
                                               mid_state):
        a, b = players
        plain = SearchService(engine5, a, b, slots=2, max_moves=CAP)
        sharded = SearchService(engine5, a, b, slots=2, max_moves=CAP,
                                mesh=make_service_mesh(1))
        assert sharded.n_shard == 1
        tp, rp = _run_games_and_serve(plain, 3, 1, mid_state)
        ts, rs = _run_games_and_serve(sharded, 3, 1, mid_state)
        assert tp == ts
        for t in tp:
            assert rp[t][:7] == rs[t][:7]       # every scalar field
            np.testing.assert_array_equal(rp[t].root_visits,
                                          rs[t].root_visits)
        np.testing.assert_array_equal(plain.shard_occupancy(),
                                      sharded.shard_occupancy())

    def test_one_trace_across_configs_one_shard_mesh(self, engine5,
                                                     players, mid_state):
        """>= 3 distinct traced (c_uct, virtual_loss) pairs share one
        compiled sharded dispatch (the mesh twin of the mesh=None
        assertion in tests/test_multiplex.py)."""
        a, b = players
        svc = SearchService(engine5, a, b, slots=2, max_moves=CAP,
                            mesh=make_service_mesh(1))
        for seed, (cu, vl) in enumerate(((0.9, 1.0), (1.7, 2.5),
                                         (0.4, 0.0))):
            svc.reset(seed=seed)
            svc.submit_game(c_uct=cu, virtual_loss=vl)
            svc.submit_serve(mid_state, c_uct=cu, virtual_loss=vl)
            assert len(svc.drain()) == 2
        assert svc._dispatch_mesh._cache_size() == 1
        assert svc._push_games_mesh._cache_size() == 1
        assert svc._push_serve_mesh._cache_size() == 1

    def test_mesh_validation(self, engine5, players):
        a, b = players
        dev = np.asarray(jax.devices()[:1]).reshape(1, 1)
        two_axis = jax.sharding.Mesh(dev, ("a", "b"))
        with pytest.raises(ValueError):
            SearchService(engine5, a, b, slots=2, mesh=two_axis)
        with pytest.raises(ValueError):
            SearchService(engine5, a, b, slots=2, placement="spiral")
        with pytest.raises(ValueError):
            make_service_mesh(10 ** 6)


@multidevice
class TestMultiDevice:
    """In-process 8-device coverage (CI: the test-multidevice job)."""

    @pytest.fixture(scope="class")
    def svc4(self, engine5, players):
        """One compiled 4-shard pool (2 slots/shard), reset() per test."""
        a, b = players
        return SearchService(engine5, a, b, slots=8, max_moves=CAP,
                             mesh=make_service_mesh(4))

    def test_slots_must_divide_over_shards(self, engine5, players):
        a, b = players
        with pytest.raises(ValueError):
            SearchService(engine5, a, b, slots=6,
                          mesh=make_service_mesh(4))

    def test_mixed_lanes_complete_across_shards(self, svc4, mid_state):
        tickets, recs = _run_games_and_serve(svc4, 6, 3, mid_state)
        assert sorted(recs) == sorted(tickets)
        for t in tickets[:6]:
            assert recs[t].winner in (-1.0, 0.0, 1.0)
            assert 0 < recs[t].moves <= CAP
        for t in tickets[6:]:
            assert recs[t].lane == LANE_SERVE
            assert recs[t].moves == 1

    def test_placement_deterministic_under_same_key(self, svc4, mid_state):
        """Same seed + same submission order => bit-identical games and
        identical shard assignments (placement uses no RNG)."""
        a1, a2 = {}, {}
        t1, r1 = _run_games_and_serve(svc4, 5, 2, mid_state, seed=4,
                                      assignments=a1)
        t2, r2 = _run_games_and_serve(svc4, 5, 2, mid_state, seed=4,
                                      assignments=a2)
        assert t1 == t2
        assert [a1[t] for t in t1] == [a2[t] for t in t2]
        assert sorted(set(a1.values())) == [0, 1, 2, 3]  # round_robin spread
        for t in t1:
            assert r1[t][:7] == r2[t][:7]
            np.testing.assert_array_equal(r1[t].root_visits,
                                          r2[t].root_visits)

    def test_serve_answers_placement_independent(self, svc4, mid_state):
        """A query's (action, visits) must not depend on the placement
        policy that routed it — the serve RNG contract, sharded."""
        by_policy = {}
        for pol in placement.POLICIES:
            svc4.placement = pol
            _, recs = _run_games_and_serve(svc4, 0, 3, mid_state)
            by_policy[pol] = [(r.action, tuple(r.root_visits))
                              for r in sorted(recs.values(),
                                              key=lambda r: r.ticket)]
        svc4.placement = "round_robin"
        assert (by_policy["round_robin"] == by_policy["fill_first"]
                == by_policy["colour_balanced"])

    def test_empty_shards_do_not_stall_drain(self, svc4, mid_state):
        """fill_first with a tiny workload leaves tail shards entirely
        empty; the pool must still drain and report them idle."""
        svc4.placement = "fill_first"
        try:
            tickets, recs = _run_games_and_serve(svc4, 2, 0, mid_state)
        finally:
            svc4.placement = "round_robin"
        assert sorted(recs) == sorted(tickets)
        occ = svc4.shard_occupancy()
        assert occ.shape == (4,)
        assert occ[0] > 0
        assert occ[2] == 0 and occ[3] == 0      # beyond the rebalance hop

    def test_one_trace_across_configs_8_devices(self, engine5, players,
                                                mid_state):
        """The acceptance assertion on real (faked) multi-device shards:
        >= 3 distinct (c_uct, virtual_loss) configs, mixed game + serve
        lanes, exactly one compiled dispatch — and config_affine
        placement routes them without changing any serve answer."""
        a, b = players
        svc = SearchService(engine5, a, b, slots=8, max_moves=CAP,
                            mesh=make_service_mesh(4),
                            placement="config_affine")
        pairs = ((0.9, 1.0), (1.7, 2.5), (0.4, 0.0))
        svc.reset(seed=0, colour_cap=2)
        sk = np.asarray(jax.random.split(jax.random.PRNGKey(13), 3))
        game_t = [svc.submit_game(c_uct=cu, virtual_loss=vl)
                  for cu, vl in pairs]
        serve_t = [svc.submit_serve(mid_state, key=sk[n], c_uct=cu,
                                    virtual_loss=vl)
                   for n, (cu, vl) in enumerate(pairs)]
        recs = {r.ticket: r for r in svc.drain()}
        assert sorted(recs) == sorted(game_t + serve_t)
        assert svc._dispatch_mesh._cache_size() == 1
        # serve answers equal the unsharded mixed pool's (placement- and
        # shard-independence of the traced-param serve contract)
        plain = SearchService(engine5, a, b, slots=2, max_moves=CAP)
        plain.reset(seed=0)
        for n, (cu, vl) in enumerate(pairs):
            t = plain.submit_serve(mid_state, key=sk[n], c_uct=cu,
                                   virtual_loss=vl)
            want = {r.ticket: r for r in plain.drain()}[t]
            assert recs[serve_t[n]].action == want.action
            np.testing.assert_array_equal(recs[serve_t[n]].root_visits,
                                          want.root_visits)

    def test_multiplexed_tournament_over_mesh(self, engine5):
        """The all-play-all scheduler shards its single pool."""
        import dataclasses
        from repro.core.tournament import Tournament
        cfgs = [CFG, dataclasses.replace(CFG, c_uct=1.6),
                dataclasses.replace(CFG, virtual_loss=2.0)]
        t = Tournament(engine5, cfgs, games_per_pair=2, slots=8,
                       max_moves=10, seed=2, mesh=make_service_mesh(4))
        res = t.round_robin()
        assert t.multiplex
        assert res.games == 6
        assert t.service._dispatch_mesh._cache_size() == 1

    def test_rebalance_spreads_fill_first_backlog(self, engine5, players,
                                                  mid_state):
        """The ppermute rebalance must hand a hot shard's pending games to
        its neighbour: under fill_first every game is *assigned* to shard
        0, so any shard-1 occupancy is rebalance traffic."""
        a, b = players
        svc = SearchService(engine5, a, b, slots=8, max_moves=CAP,
                            mesh=make_service_mesh(4),
                            placement="fill_first")
        tickets, recs = _run_games_and_serve(svc, 8, 0, mid_state)
        assert sorted(recs) == sorted(tickets)
        occ = svc.shard_occupancy()
        assert occ[1] > 0


@pytest.mark.slow
class TestMultiDeviceSubprocess:
    """8-fake-device coverage for single-device tier-1 runs."""

    def test_sharded_arena_completes_and_rebalances(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                            + env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
assert jax.device_count() == 8
from repro.compat import make_service_mesh
from repro.config import MCTSConfig
from repro.core.arena import Arena
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources
from repro.go import GoEngine

eng = GoEngine(5, komi=0.5)
cfg = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
a, b = MCTS(eng, double_resources(cfg)), MCTS(eng, cfg)
arena = Arena(eng, a, b, slots=8, max_moves=10, mesh=make_service_mesh(4),
              placement="fill_first")
recs = arena.play_games(8, seed=3)
assert len(recs) == 8
occ = arena.service.shard_occupancy()
assert occ.shape == (4,) and occ[0] > 0 and occ[1] > 0, occ
print("OK", np.round(occ, 2))
"""], env=env, capture_output=True, text=True, timeout=480)
        assert out.returncode == 0, \
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
