"""End-to-end integration: elastic rescaling, launcher CLIs, the paper's
tournament setting, and the dry-run results contract."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # end-to-end subprocess runs: full tier only

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, devices: int = 8, timeout: int = 480, env_extra=None):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(env_extra or {})
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestElasticRescale:
    def test_train_save_then_resume_on_smaller_mesh(self, tmp_path):
        """Train on a (4, 2) mesh, checkpoint, lose half the fleet, resume
        on (2, 2) with resharded state — loss continues from where it was
        (same data stream by step index)."""
        run_sub(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import TrainConfig
from repro.configs.reduced import reduced
from repro.models import build_model
from repro.models import sharding as shlib
from repro.training import init_train_state, make_train_step
from repro.training.step import TrainState
from repro.ckpt import save_checkpoint, restore_checkpoint
from repro.data import SyntheticLM
from repro.runtime import elastic_mesh_for

cfg = reduced("yi-6b")
model = build_model(cfg)
tcfg = TrainConfig(steps=6, microbatches=1, lr=1e-3, warmup_steps=1)
data = SyntheticLM(cfg, 16, 8, seed=5)
tb = lambda s: {{k: jnp.asarray(v) for k, v in data.batch_at(s).items()}}

# phase 1: 8 devices, (4 data, 2 model)
mesh_a = jax.make_mesh((4, 2), ("data", "model"))
with shlib.use_mesh(mesh_a):
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, mesh=mesh_a))
    for s in range(3):
        state, m = step(state, tb(s))
losses_a = float(m["loss"])
save_checkpoint("{tmp_path}", 3, state._asdict(), extra={{"data_step": 3}})

# phase 2: "4 devices survive" -> elastic (2, 2) mesh, resharded restore
data_ax, model_ax = elastic_mesh_for(4, 2)
assert (data_ax, model_ax) == (2, 2)
mesh_b = jax.make_mesh((2, 2), ("data", "model"))
devs = np.array(jax.devices()[:4]).reshape(2, 2)
with shlib.use_mesh(mesh_b):
    template = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    from repro.models import param_shardings
    restored, step_idx, extra = restore_checkpoint(
        "{tmp_path}", template._asdict())
    state_b = TrainState(**restored)
    step_b = jax.jit(make_train_step(model, tcfg, mesh=mesh_b))
    for s in range(extra["data_step"], 6):
        state_b, mb = step_b(state_b, tb(s))
print("resumed loss", float(mb["loss"]))
assert np.isfinite(float(mb["loss"]))
assert int(state_b.step) == 6
print("OK elastic rescale")
""")


class TestPaperSetting:
    def test_tournament_config_runs(self):
        """The paper's exact setting: 9x9, komi 6, Chinese (area) scoring,
        alternating colours — one tiny match end to end."""
        from repro.config import MCTSConfig
        from repro.core.selfplay import effective_speedup_point
        from repro.go import GoEngine
        eng = GoEngine(9, komi=6.0)
        cfg = MCTSConfig(board_size=9, komi=6.0, lanes=2, sims_per_move=8,
                         max_nodes=128)
        res = effective_speedup_point(eng, cfg, games=2, seed=0,
                                      max_moves=24)
        assert res.a_wins + res.b_wins + res.draws == 2

    def test_19x19_engine(self):
        """The paper also ran 19x19; the engine is size-parametric."""
        from repro.go import GoEngine, BLACK
        eng = GoEngine(19, komi=7.5)
        st = eng.init_state()
        st = eng.play(st, 3 * 19 + 3)       # corner-ish opening
        assert int(st.board[3 * 19 + 3]) == BLACK
        legal = eng.legal_moves(st)
        assert int(np.asarray(legal).sum()) == 19 * 19 - 1 + 1  # + pass
        v = eng.playout_value(st, jax.random.PRNGKey(0))
        assert int(v) in (-1, 0, 1)


class TestLauncherCLIs:
    def test_train_cli_with_resume(self, tmp_path):
        env = {"CKPT": str(tmp_path)}
        script = f"""
import sys
sys.argv = ["train", "--arch", "yi-6b", "--reduced", "--steps", "4",
            "--batch", "2", "--seq", "32", "--ckpt-dir", "{tmp_path}",
            "--ckpt-every", "2", "--log-every", "2"]
from repro.launch.train import main
main()
# resume from the checkpoint
sys.argv += ["--resume"]
sys.argv[sys.argv.index("--steps") + 1] = "6"
main()
print("OK train cli resume")
"""
        out = run_sub(script, devices=1, timeout=600)
        assert "OK train cli resume" in out
        assert "[resume] restored step 4" in out

    def test_selfplay_cli(self):
        out = run_sub("""
import sys
sys.argv = ["selfplay", "--board", "5", "--lanes", "1", "--sims", "8",
            "--games", "2", "--max-nodes", "64"]
from repro.launch.selfplay import main
main()
""", devices=1, timeout=600)
        assert "win rate" in out


class TestDryrunContract:
    """The recorded dry-run must satisfy the deliverable's contract."""

    @pytest.fixture(scope="class")
    def results(self):
        path = os.path.join(REPO, "benchmarks", "results", "dryrun.json")
        if not os.path.exists(path):
            pytest.skip("dry-run cache not present")
        with open(path) as f:
            return json.load(f)

    def test_no_errors_and_full_coverage(self, results):
        from repro.config import SHAPES, list_archs, skip_reason
        errors = [k for k, v in results.items() if v.get("status") not in
                  ("ok", "skipped")]
        assert not errors, errors
        for mesh in ("16x16", "2x16x16"):
            for arch in list_archs():
                for shape in SHAPES:
                    key = f"{arch}|{shape}|{mesh}"
                    assert key in results, f"missing cell {key}"
                    want_skip = skip_reason(arch, shape) is not None
                    got = results[key]["status"]
                    assert got == ("skipped" if want_skip else "ok"), \
                        (key, got)
            assert results[f"fuego9|selfplay|{mesh}"]["status"] == "ok"

    def test_roofline_terms_present_and_positive(self, results):
        for k, v in results.items():
            if v.get("status") != "ok":
                continue
            r = v["roofline"]
            assert r["memory_s"] >= 0 and r["collective_s"] >= 0
            assert r["dominant"] in ("compute_s", "memory_s",
                                     "collective_s")
            assert v["memory"]["argument_bytes"] is not None

    def test_multi_pod_not_worse_per_device(self, results):
        """Pure-DP pod axis: per-device compute/memory terms must not grow
        going 256 -> 512 chips (beyond small partitioning noise) for dense
        train cells."""
        for arch in ("yi-6b", "glm4-9b", "gemma2-9b"):
            a = results[f"{arch}|train_4k|16x16"]["roofline"]
            b = results[f"{arch}|train_4k|2x16x16"]["roofline"]
            assert b["memory_s"] <= a["memory_s"] * 1.05
            assert b["compute_s"] <= a["compute_s"] * 1.05
