"""Fused MCTS superstep tests (kernels/mcts_step + the MCTS ``fused=`` flag).

Four invariant groups:

* **kernel parity** — interpret-mode Pallas ``mcts_select`` / ``mcts_backup``
  match the pure-jnp oracle over random tree forests, for both the plain and
  the prior-blended scoring program (the kernel-parity CI job runs these);
* **fused=False bit-identity** — the flag's off-position is the exact
  historical program: array_equal against a flagless player at the MCTS
  level, through a SearchService pool, and (slow tier) on 8 faked devices,
  with the dispatch compile count unchanged;
* **fused search invariants** — visit conservation, virtual-loss clearing,
  traced ``sims`` masking and traced ``SearchParams`` with one compiled
  trace, legality of chosen actions, evaluator lane under fusion;
* **fused service** — a fused player drives the SearchService dispatch
  end-to-end from a single compiled trace.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MCTSConfig
from repro.core.mcts import MCTS, SearchParams
from repro.core.service import SearchService
from repro.kernels.mcts_step.ops import mcts_backup, mcts_select
from repro.kernels.mcts_step.ref import tie_break_noise

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
CAP = 12
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _forest(seed, g=3, n=64, a=82):
    """Random tree slabs shaped like a mid-search arena (children > parent)."""
    rng = np.random.default_rng(seed)
    visit = rng.integers(0, 20, (g, n)).astype(np.float32)
    value = rng.normal(size=(g, n)).astype(np.float32) * 3
    vloss = rng.integers(0, 3, (g, n)).astype(np.float32)
    prior = rng.random((g, n, a)).astype(np.float32)
    legal = rng.random((g, n, a)) < 0.7
    legal[:, :, -1] = True                        # pass always legal
    children = np.full((g, n, a), -1, np.int32)
    for gi in range(g):
        for i in range(n // 2):
            for act in rng.choice(a, size=4, replace=False):
                children[gi, i, act] = rng.integers(i + 1, n)
    expanded = rng.random((g, n)) < 0.9
    terminal = rng.random((g, n)) < 0.05
    expanded[:, 0] = True
    terminal[:, 0] = False
    player = rng.choice([-1.0, 1.0], (g, n)).astype(np.float32)
    return tuple(jnp.asarray(x) for x in (
        visit, value, vloss, prior, legal, children, expanded, terminal,
        player))


SELECT_KW = dict(c_uct=0.9, vl_weight=1.0, lanes=4, max_depth=8,
                 expand_threshold=1)


# ------------------------------------------------------------ kernel parity


class TestSelectParity:
    @pytest.mark.parametrize("pw", [None, (0.0, 0.5, 1.0)])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_interpret_matches_ref(self, pw, seed):
        """The Pallas program (interpret mode) and the oracle agree on
        every selection output — paths, depths, leaves, actions, the
        expansion mask, and the accumulated virtual loss."""
        slabs = _forest(seed)
        seeds = jnp.arange(3, dtype=jnp.uint32) + 7
        pwa = None if pw is None else jnp.asarray(pw)
        ref = mcts_select(*slabs, seeds, prior_w=pwa, **SELECT_KW)
        ker = mcts_select(*slabs, seeds, prior_w=pwa, interpret=True,
                          **SELECT_KW)
        for name, r, k in zip(
                ("paths", "depth", "leaf", "act", "can_exp", "vloss"),
                ref, ker):
            r, k = np.asarray(r), np.asarray(k)
            if r.dtype.kind == "f":
                np.testing.assert_allclose(r, k, rtol=2e-6, atol=2e-6,
                                           err_msg=name)
            else:
                np.testing.assert_array_equal(r, k, err_msg=name)

    def test_use_puct_program(self):
        slabs = _forest(2)
        seeds = jnp.zeros((3,), jnp.uint32)
        kw = dict(SELECT_KW, use_puct=True)
        ref = mcts_select(*slabs, seeds, **kw)
        ker = mcts_select(*slabs, seeds, interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(ref[0]), np.asarray(ker[0]))
        np.testing.assert_allclose(np.asarray(ref[5]), np.asarray(ker[5]),
                                   rtol=2e-6)

    def test_lanes_accumulate_virtual_loss(self):
        """Each lane adds one unit of virtual loss per path node, root
        included — the cross-lane decorrelation the fusion preserves."""
        slabs = _forest(3)
        seeds = jnp.zeros((3,), jnp.uint32)
        paths, _, _, _, _, vl = mcts_select(*slabs, seeds, **SELECT_KW)
        added = np.asarray(vl) - np.asarray(slabs[2])
        assert added.sum() == (np.asarray(paths) != -1).sum()
        assert (added >= 0).all()

    def test_seed_perturbs_tie_breaks(self):
        """Different seeds must be able to change lane routes (the
        asynchronous-thread nondeterminism analogue)."""
        visit, value, vloss, prior, legal, ch, ex, te, pl = _forest(4)
        # flat landscape so only the tie-break noise orders the edges
        slabs = (jnp.zeros_like(visit), jnp.zeros_like(value),
                 jnp.zeros_like(vloss), jnp.ones_like(prior),
                 jnp.ones_like(legal), ch, ex, te, pl)
        a = mcts_select(*slabs, jnp.zeros((3,), jnp.uint32), **SELECT_KW)
        b = mcts_select(*slabs, jnp.full((3,), 99, jnp.uint32), **SELECT_KW)
        assert (np.asarray(a[3]) != np.asarray(b[3])).any()

    def test_noise_bounded_and_deterministic(self):
        iota = jnp.arange(128, dtype=jnp.uint32)
        x = tie_break_noise(7, 3, 2, iota)
        y = tie_break_noise(7, 3, 2, iota)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert float(x.min()) >= 0.0 and float(x.max()) < 1e-3
        assert len(np.unique(np.asarray(x))) > 100     # actually varies


class TestBackupParity:
    def test_interpret_matches_ref(self):
        rng = np.random.default_rng(0)
        g, lanes, d, n = 3, 4, 8, 64
        paths = np.full((g, lanes, d), -1, np.int32)
        for gi in range(g):
            for li in range(lanes):
                depth = rng.integers(1, d)
                paths[gi, li, :depth] = rng.choice(n, size=depth,
                                                   replace=False)
        val_sum = jnp.asarray(rng.normal(size=(g, lanes)), jnp.float32)
        visit = jnp.asarray(rng.integers(0, 9, (g, n)), jnp.float32)
        value = jnp.asarray(rng.normal(size=(g, n)), jnp.float32)
        ref = mcts_backup(visit, value, jnp.asarray(paths), val_sum,
                          playouts=2.0)
        ker = mcts_backup(visit, value, jnp.asarray(paths), val_sum,
                          playouts=2.0, interpret=True)
        for name, r, k in zip(("visit", "value"), ref, ker):
            np.testing.assert_allclose(np.asarray(r), np.asarray(k),
                                       rtol=2e-6, atol=2e-6, err_msg=name)

    def test_duplicate_path_nodes_accumulate(self):
        """Two lanes through the same node both deposit visits/value —
        the lock-free scatter-add contract of the paper's backups."""
        paths = jnp.asarray([[[0, 1, -1], [0, 1, 2]]], jnp.int32)
        vs = jnp.asarray([[1.0, -1.0]], jnp.float32)
        visit0 = jnp.zeros((1, 4))
        value0 = jnp.zeros((1, 4))
        visit, value = mcts_backup(visit0, value0, paths, vs, playouts=1.0,
                                   interpret=True)
        np.testing.assert_allclose(np.asarray(visit[0]), [2, 2, 1, 0])
        np.testing.assert_allclose(np.asarray(value[0]), [0, 0, -1, 0],
                                   atol=1e-6)


# ------------------------------------------------- fused=False bit-identity


@pytest.fixture(scope="module")
def roots2(engine5):
    st = engine5.init_state()
    for mv in (3, 7, 12):
        st = engine5.jit_play(st, jnp.int32(mv))
    return jax.tree.map(lambda a, b: jnp.stack([a, b]),
                        engine5.init_state(), st)


@pytest.fixture(scope="module")
def keys2():
    return jnp.asarray(jax.random.split(jax.random.PRNGKey(13), 2))


class TestFusedFalseBitIdentity:
    def test_mcts_level(self, engine5, roots2, keys2):
        """fused=False must leave search_batch on the exact historical
        program: every output array_equal to a flagless player's."""
        base = MCTS(engine5, CFG).search_batch(roots2, keys2)
        off = MCTS(engine5, CFG, fused=False).search_batch(roots2, keys2)
        np.testing.assert_array_equal(np.asarray(off.action),
                                      np.asarray(base.action))
        np.testing.assert_array_equal(np.asarray(off.root_visits),
                                      np.asarray(base.root_visits))
        np.testing.assert_array_equal(np.asarray(off.root_values),
                                      np.asarray(base.root_values))
        np.testing.assert_array_equal(np.asarray(off.tree.visit),
                                      np.asarray(base.tree.visit))
        np.testing.assert_array_equal(np.asarray(off.tree.value),
                                      np.asarray(base.tree.value))

    def test_mcts_level_with_sims_and_params(self, engine5, roots2, keys2):
        sims = jnp.asarray([4, 8], jnp.int32)
        params = SearchParams(jnp.full((2,), CFG.c_uct),
                              jnp.full((2,), CFG.virtual_loss))
        base = MCTS(engine5, CFG).search_batch(roots2, keys2, sims, params)
        off = MCTS(engine5, CFG, fused=False).search_batch(
            roots2, keys2, sims, params)
        np.testing.assert_array_equal(np.asarray(off.root_visits),
                                      np.asarray(base.root_visits))
        np.testing.assert_array_equal(np.asarray(off.tree.visit),
                                      np.asarray(base.tree.visit))

    def test_pool_level_one_trace(self, engine5):
        """A fused=False player through the SearchService pool: identical
        game records and an unchanged dispatch compile count."""
        def run(player):
            svc = SearchService(engine5, player, player, slots=2,
                                max_moves=CAP)
            svc.reset(seed=0, colour_cap=2)
            keys = np.asarray(jax.random.split(jax.random.PRNGKey(5), 4))
            tickets = [svc.submit_game(key=k) for k in keys]
            recs = {r.ticket: r for r in svc.drain()}
            return svc, [recs[t] for t in tickets]

        _, want = run(MCTS(engine5, CFG))
        svc, got = run(MCTS(engine5, CFG, fused=False))
        for w, g in zip(want, got):
            assert w[:7] == g[:7]          # every scalar result field
            np.testing.assert_array_equal(w.root_visits, g.root_visits)
        assert svc._dispatch._cache_size() == 1
        assert svc._push_games._cache_size() == 1


# -------------------------------------------------- fused search invariants


@pytest.fixture(scope="module")
def fused_player(engine5):
    return MCTS(engine5, CFG, fused=True)


class TestFusedSearch:
    def test_visit_conservation_and_vloss_cleared(self, fused_player,
                                                  roots2, keys2):
        out = fused_player.search_batch(roots2, keys2)
        it = fused_player.iterations
        # every iteration deposits lanes * playouts visits on the root
        np.testing.assert_allclose(np.asarray(out.tree.visit[:, 0]),
                                   1.0 + it * CFG.lanes)
        assert float(jnp.abs(out.tree.vloss).max()) == 0.0
        # root visit mass equals the sum over root actions + the init visit
        np.testing.assert_allclose(
            np.asarray(out.root_visits.sum(-1)),
            np.asarray(out.tree.visit[:, 0]) - 1.0)

    def test_actions_legal(self, fused_player, engine5, roots2, keys2):
        out = fused_player.search_batch(roots2, keys2)
        legal = jax.vmap(engine5.legal_moves)(roots2)
        for g in range(2):
            assert bool(legal[g, int(out.action[g])])

    def test_sims_masking_monotone(self, fused_player, engine5):
        g = 3
        roots = jax.vmap(lambda _: engine5.init_state())(jnp.arange(g))
        rngs = jnp.asarray(jax.random.split(jax.random.PRNGKey(0), g))
        sims = jnp.asarray([2, 4, 8], jnp.int32)
        out = fused_player.search_batch(roots, rngs, sims)
        sizes = np.asarray(out.tree.size)
        visits = np.asarray(out.tree.visit[:, 0])
        assert (np.diff(sizes) >= 0).all(), sizes
        assert (np.diff(visits) > 0).all(), visits

    def test_params_traced_one_trace(self, fused_player, roots2, keys2):
        fn = jax.jit(fused_player.search_batch)
        for cu, vl in ((0.9, 1.0), (1.7, 2.5), (0.4, 0.5)):
            fn(roots2, keys2,
               params=SearchParams(jnp.full((2,), cu), jnp.full((2,), vl)))
        assert fn._cache_size() == 1

    def test_deterministic(self, fused_player, roots2, keys2):
        a = fused_player.search_batch(roots2, keys2)
        b = fused_player.search_batch(roots2, keys2)
        np.testing.assert_array_equal(np.asarray(a.root_visits),
                                      np.asarray(b.root_visits))

    def test_tree_growth_bounded_by_capacity(self, engine5):
        """Deferred expansion must respect the arena: a tiny tree fills up
        and further iterations keep size pinned at max_nodes."""
        cfg = MCTSConfig(board_size=5, lanes=4, sims_per_move=64,
                         max_nodes=16)
        m = MCTS(engine5, cfg, fused=True, max_depth=8)
        roots = jax.vmap(lambda _: engine5.init_state())(jnp.arange(2))
        rngs = jnp.asarray(jax.random.split(jax.random.PRNGKey(1), 2))
        out = m.search_batch(roots, rngs)
        assert (np.asarray(out.tree.size) <= 16).all()

    def test_evaluator_lane_under_fusion(self, engine5, roots2, keys2,
                                         fused_player):
        """A guided fused player consumes net priors/values (differs from
        the unguided fused search) and w=0 rows stay playout-pure."""
        from repro.core.evaluator import EvalConfig, EvalService
        ev = EvalService(EvalConfig(board_size=5, d_model=16, num_layers=1,
                                    num_heads=2, d_ff=32))
        guided = MCTS(engine5, CFG, evaluator=ev, fused=True)

        def params(w):
            return SearchParams(jnp.full((2,), CFG.c_uct),
                                jnp.full((2,), CFG.virtual_loss),
                                jnp.asarray(w, jnp.float32))

        base = fused_player.search_batch(roots2, keys2)
        got = guided.search_batch(roots2, keys2, params=params([1.0, 1.0]))
        assert (np.asarray(got.root_visits)
                != np.asarray(base.root_visits)).any()
        # value mixing off at w=0: visit mass still conserved
        w0 = guided.search_batch(roots2, keys2, params=params([0.0, 0.0]))
        np.testing.assert_allclose(
            np.asarray(w0.tree.visit[:, 0]),
            1.0 + guided.iterations * CFG.lanes)


# ----------------------------------------------------------- fused service


class TestFusedService:
    def test_fused_pool_completes_games_one_trace(self, engine5):
        player = MCTS(engine5, CFG, fused=True)
        svc = SearchService(engine5, player, player, slots=2, max_moves=CAP)
        svc.reset(seed=0, colour_cap=2)
        tickets = [svc.submit_game() for _ in range(4)]
        recs = {r.ticket: r for r in svc.drain()}
        assert sorted(recs) == sorted(tickets)
        assert all(recs[t].moves > 0 for t in tickets)
        assert svc._dispatch._cache_size() == 1


@pytest.mark.slow
class TestFusedFalseSharded:
    """8-fake-device bit-identity for the flag's off-position."""

    def test_sharded_pool_matches_flagless(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                            + env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        out = subprocess.run([sys.executable, "-c", """
import jax, numpy as np
assert jax.device_count() == 8
from repro.compat import make_service_mesh
from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core.service import SearchService
from repro.go import GoEngine

eng = GoEngine(5, komi=0.5)
cfg = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
st = eng.init_state()
keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), 4))

def serve(player, mesh, slots):
    svc = SearchService(eng, player, player, slots=slots, max_moves=12,
                        mesh=mesh)
    svc.reset(seed=0)
    tickets = [svc.submit_serve(st, key=k) for k in keys]
    recs = {r.ticket: r for r in svc.drain()}
    return svc, [recs[t] for t in tickets]

_, want = serve(MCTS(eng, cfg), None, 4)
svc, got = serve(MCTS(eng, cfg, fused=False), make_service_mesh(8), 16)
for w, g in zip(want, got):
    assert w.action == g.action
    np.testing.assert_array_equal(w.root_visits, g.root_visits)
assert svc._dispatch_mesh._cache_size() == 1
print("OK")
"""], env=env, capture_output=True, text=True, timeout=480)
        assert out.returncode == 0, \
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
