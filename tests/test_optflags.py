"""Optimization levels (§Perf O1-O3) must preserve model semantics:
each level is self-consistent between training forward, prefill and
decode, and trains with finite grads.  (Levels change head wiring/dtypes,
so levels are checked for internal consistency, not bit-equality.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # per-level train/prefill/decode sweeps

from repro.configs.reduced import reduced
from repro.models import build_model
from repro.models import optflags


@pytest.fixture(autouse=True)
def restore_flags():
    yield
    optflags.set_level(0)


# glm4 reduced: Hkv=1... pick a GQA config with heads=4 kv=2 (yi reduced)
ARCHS = ["yi-6b", "gemma2-9b"]


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("level", [1, 2, 3])
class TestOptLevels:
    def test_consistency_and_training(self, arch, level):
        optflags.set_level(level)
        cfg = reduced(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        seq = 24
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, seq), 0,
                                  cfg.vocab_size)

        full_logits, _ = jax.jit(lambda p: model.forward(p, toks))(params)
        assert np.isfinite(np.asarray(full_logits)).all()

        pre_logits, cache = jax.jit(
            lambda p: model.prefill(p, toks[:, :seq - 1],
                                    max_len=seq + 2))(params)
        np.testing.assert_allclose(
            np.asarray(pre_logits[:, 0]),
            np.asarray(full_logits[:, seq - 2]), rtol=4e-2, atol=4e-2)

        step_logits, cache2 = jax.jit(
            lambda p, c: model.decode_step(p, c, toks[:, seq - 1:]))(
                params, cache)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]),
            np.asarray(full_logits[:, seq - 1]), rtol=6e-2, atol=6e-2)

        # training step: grads finite
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        (loss, _), grads = jax.jit(jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True))(params)
        assert np.isfinite(float(loss))
        for g in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(g, np.float32)).all()


class TestPaddedHeads:
    def test_wq_padded_and_pad_outputs_zero(self):
        from repro.config import AttnConfig, ModelConfig
        optflags.set_level(3)
        cfg = ModelConfig(
            name="t", family="dense", num_layers=1, d_model=32, d_ff=64,
            vocab_size=64,
            attn=AttnConfig(num_heads=5, num_kv_heads=5, head_dim=8),
            dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        # padded to 16 heads
        assert params["layers"]["attn"]["wq"].shape == (1, 32, 16 * 8)
        toks = jnp.zeros((1, 8), jnp.int32)
        logits, _ = jax.jit(lambda p: model.forward(p, toks))(params)
        assert np.isfinite(np.asarray(logits)).all()
