"""MCTS invariants + parallel-mode tests (the paper's algorithm)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core import tree as tree_lib
from repro.core import stats, affinity
from repro.core.selfplay import double_resources, match, play_game
from repro.go import GoEngine


CFG5 = MCTSConfig(board_size=5, lanes=4, sims_per_move=32, max_nodes=128)


@pytest.fixture(scope="module")
def search5(engine5):
    m = MCTS(engine5, CFG5)
    fn = jax.jit(lambda s, k: m._search(s, k))
    return m, fn


class TestTreeInvariants:
    def test_visit_conservation(self, engine5, search5, rng):
        m, fn = search5
        res = fn(engine5.init_state(), rng)
        t = res.tree
        # root visits = 1 (init) + iterations * lanes * leaf_playouts
        expected = 1 + m.iterations * CFG5.lanes * max(1, CFG5.leaf_playouts)
        assert float(t.visit[0]) == expected

    def test_child_visits_sum_to_parent(self, engine5, search5, rng):
        _, fn = search5
        t = fn(engine5.init_state(), rng).tree
        size = int(t.size)
        visit = np.asarray(t.visit)
        children = np.asarray(t.children)
        for n in range(size):
            kids = children[n]
            kid_sum = sum(visit[k] for k in kids if k >= 0)
            # parent visits >= sum of children (parent counted when it was
            # itself the playout leaf)
            assert visit[n] >= kid_sum

    def test_virtual_loss_cleared(self, engine5, search5, rng):
        _, fn = search5
        t = fn(engine5.init_state(), rng).tree
        assert float(jnp.abs(t.vloss).sum()) == 0.0

    def test_values_bounded(self, engine5, search5, rng):
        _, fn = search5
        t = fn(engine5.init_state(), rng).tree
        v = np.asarray(t.value)
        n = np.asarray(t.visit)
        ok = n > 0
        assert (np.abs(v[ok]) <= n[ok] + 1e-6).all()

    def test_parent_child_consistency(self, engine5, search5, rng):
        _, fn = search5
        t = fn(engine5.init_state(), rng).tree
        size = int(t.size)
        children = np.asarray(t.children)
        parent = np.asarray(t.parent)
        action = np.asarray(t.action)
        for n in range(1, size):
            p, a = parent[n], action[n]
            assert p >= 0 and children[p, a] == n

    def test_capacity_respected(self, engine5, rng):
        cfg = dataclasses.replace(CFG5, max_nodes=8, sims_per_move=64)
        m = MCTS(engine5, cfg)
        t = jax.jit(lambda s, k: m._search(s, k))(
            engine5.init_state(), rng).tree
        assert int(t.size) <= 8

    def test_action_is_legal(self, engine5, search5, rng):
        _, fn = search5
        res = fn(engine5.init_state(), rng)
        legal = engine5.legal_moves(engine5.init_state())
        assert bool(legal[int(res.action)])


class TestVirtualLossDiversification:
    """The paper's reason for virtual loss: parallel threads must not all
    descend the same path.  With VL, one iteration's lanes spread over
    distinct root children; without, they pile onto one."""

    def _first_iteration_leaves(self, engine5, vl):
        cfg = dataclasses.replace(CFG5, lanes=8, virtual_loss=vl,
                                  sims_per_move=8)
        m = MCTS(engine5, cfg)
        t = tree_lib.init_tree(engine5, engine5.init_state(), cfg.max_nodes)

        def one_iter(t, key):
            return m._simulate(t, key)

        t = jax.jit(one_iter)(t, jax.random.PRNGKey(3))
        kids = np.asarray(t.children[0])
        return (kids >= 0).sum()

    def test_virtual_loss_spreads_lanes(self, engine5):
        spread_vl = self._first_iteration_leaves(engine5, 1.0)
        assert spread_vl >= 6  # 8 lanes explore >= 6 distinct root children

    def test_fpu_alone_also_spreads_but_vl_required_deeper(self, engine5):
        # with FPU, unvisited children already attract lanes at the root;
        # the invariant worth pinning: VL never *reduces* spread
        spread_no = self._first_iteration_leaves(engine5, 0.0)
        spread_vl = self._first_iteration_leaves(engine5, 1.0)
        assert spread_vl >= spread_no - 1


class TestParallelModes:
    def test_root_parallel_runs(self, engine5, rng):
        cfg = dataclasses.replace(CFG5, parallelism="root", root_trees=4,
                                  sims_per_move=64)
        m = MCTS(engine5, cfg)
        res = jax.jit(lambda s, k: m._search_root_parallel(s, k))(
            engine5.init_state(), rng)
        legal = engine5.legal_moves(engine5.init_state())
        assert bool(legal[int(res.action)])
        # merged visits are the sum over trees
        assert float(res.root_visits.sum()) > 0

    def test_leaf_parallel_counts(self, engine5, rng):
        cfg = dataclasses.replace(CFG5, lanes=1, leaf_playouts=4,
                                  sims_per_move=32)
        m = MCTS(engine5, cfg)
        res = jax.jit(lambda s, k: m._search(s, k))(engine5.init_state(), rng)
        expected = 1 + m.iterations * 1 * 4
        assert float(res.tree.visit[0]) == expected

    @pytest.mark.slow
    def test_more_sims_beat_fewer(self, engine5):
        """Sanity strength check (paper Fig. 4 direction): 8x sims should
        not lose a small match to 1x."""
        weak = dataclasses.replace(CFG5, lanes=1, sims_per_move=4,
                                   max_nodes=64)
        strong = dataclasses.replace(CFG5, lanes=4, sims_per_move=64,
                                     max_nodes=256)
        eng = GoEngine(5, komi=0.5)
        res = match(eng, strong, weak, games=6, seed=7)
        assert res.rate.rate >= 0.5


class TestSelfplayHarness:
    def test_double_resources(self):
        d = double_resources(CFG5)
        assert d.lanes == CFG5.lanes * 2
        assert d.sims_per_move == CFG5.sims_per_move * 2

    def test_play_game_terminates(self, engine5, rng):
        m = MCTS(engine5, dataclasses.replace(CFG5, sims_per_move=8))
        rec = jax.jit(lambda k: play_game(
            engine5, m, m, k, jnp.bool_(True)))(rng)
        assert int(rec.moves) > 0
        assert int(rec.winner) in (-1, 0, 1)

    @pytest.mark.slow  # covered in the fast tier by test_arena accounting
    def test_match_accounting(self, engine5):
        cfg = dataclasses.replace(CFG5, sims_per_move=8, max_nodes=64)
        res = match(engine5, cfg, cfg, games=4, seed=1)
        assert res.a_wins + res.b_wins + res.draws == 4
        assert res.rate.games == 4
        assert 0.0 <= res.rate.lo <= res.rate.rate <= res.rate.hi <= 1.0


class TestStats:
    def test_heinz_interval_matches_paper_formula(self):
        # w ± 1.96 sqrt(w(1-w)/n)
        r = stats.win_rate(58, 42)
        import math
        w = 0.58
        half = 1.96 * math.sqrt(w * (1 - w) / 100)
        assert abs(r.rate - w) < 1e-12
        assert abs(r.hi - (w + half)) < 1e-12
        assert abs(r.lo - (w - half)) < 1e-12

    def test_draws_count_half(self):
        r = stats.win_rate(0, 0, draws=10)
        assert r.rate == 0.5

    def test_clipping(self):
        r = stats.win_rate(10, 0)
        assert r.hi <= 1.0 and r.lo >= 0.0

    def test_games_for_margin(self):
        n = stats.games_for_margin(0.05)
        assert 380 <= n <= 390  # 1.96^2*0.25/0.0025 = 384.16


class TestAffinity:
    def test_compact_fills_first_devices(self):
        a = affinity.lane_to_device("compact", 8, devices=4,
                                    slots_per_device=4)
        assert list(a) == [0, 0, 0, 0, 1, 1, 1, 1]
        assert affinity.utilisation(a, 4) == 0.5

    def test_scatter_round_robin(self):
        a = affinity.lane_to_device("scatter", 8, devices=4)
        assert list(a) == [0, 1, 2, 3, 0, 1, 2, 3]
        assert affinity.utilisation(a, 4) == 1.0

    def test_balanced_even_blocks(self):
        a = affinity.lane_to_device("balanced", 8, devices=4)
        assert list(a) == [0, 0, 1, 1, 2, 2, 3, 3]

    def test_balanced_asymmetric_region(self):
        # the paper's 122..183-thread region: some devices get 2, some 3
        a = affinity.lane_to_device("balanced", 10, devices=4)
        load = affinity.device_load(a, 4)
        assert load.max() == 3 and load.min() >= 1
        assert affinity.imbalance(a, 4) > 1.0

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError):
            affinity.lane_to_device("weird", 8, 4)
