"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fma_stream.ops import fma_stream
from repro.kernels.fma_stream.ref import fma_stream_ref
from repro.kernels.uct_select.ops import uct_scores
from repro.kernels.uct_select.ref import uct_scores_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


class TestFmaStream:
    @pytest.mark.parametrize("n", [8192, 16384, 65536, 100000])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
    def test_matches_ref(self, n, dtype):
        key = jax.random.PRNGKey(n)
        ka, kb, kc = jax.random.split(key, 3)
        if dtype == jnp.int32:
            a = jax.random.randint(ka, (n,), -5, 5, dtype)
            b = jax.random.randint(kb, (n,), -5, 5, dtype)
            c = jax.random.randint(kc, (n,), -5, 5, dtype)
        else:
            a = jax.random.normal(ka, (n,), dtype)
            b = jax.random.normal(kb, (n,), dtype)
            c = jax.random.normal(kc, (n,), dtype)
        got = fma_stream(a, b, c, repeats=3, interpret=True)
        want = fma_stream_ref(a, b, c, repeats=3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_repeats_scale_intensity(self):
        a = jnp.ones(8192); b = jnp.ones(8192); c = jnp.zeros(8192)
        out = fma_stream(a, b, c, repeats=7, interpret=True)
        np.testing.assert_allclose(np.asarray(out), 7.0)

    def test_bf16(self):
        n = 16384
        a = jnp.full((n,), 1.5, jnp.bfloat16)
        b = jnp.full((n,), 2.0, jnp.bfloat16)
        c = jnp.zeros((n,), jnp.bfloat16)
        out = fma_stream(a, b, c, repeats=1, interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32), 3.0)


def _uct_inputs(key, b, a):
    ks = jax.random.split(key, 8)
    visit = jnp.floor(jax.random.uniform(ks[0], (b, a)) * 50)
    value = jax.random.normal(ks[1], (b, a)) * visit
    vloss = jnp.floor(jax.random.uniform(ks[2], (b, a)) * 3)
    prior = jax.nn.softmax(jax.random.normal(ks[3], (b, a)))
    legal = jax.random.bernoulli(ks[4], 0.8, (b, a))
    has_child = jax.random.bernoulli(ks[5], 0.6, (b, a)) & legal
    visit = jnp.where(has_child, jnp.maximum(visit, 1), 0)
    parent_n = 1 + jnp.floor(jax.random.uniform(ks[6], (b,)) * 200)
    player = jnp.where(jax.random.bernoulli(ks[7], 0.5, (b,)), 1.0, -1.0)
    return visit, value, vloss, prior, legal, has_child, parent_n, player


class TestUctSelect:
    @pytest.mark.parametrize("b,a", [(8, 82), (16, 128), (3, 26), (32, 362)])
    @pytest.mark.parametrize("use_puct", [False, True])
    def test_matches_ref(self, b, a, use_puct):
        args = _uct_inputs(jax.random.PRNGKey(b * a), b, a)
        got = uct_scores(*args, c_uct=0.9, vl_weight=1.0, use_puct=use_puct,
                         interpret=True)
        want = uct_scores_ref(*[x.astype(jnp.float32) for x in args],
                              c_uct=0.9, vl_weight=1.0, use_puct=use_puct)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-6, atol=2e-6)

    def test_argmax_agrees_with_search_math(self):
        """Kernel scores reproduce MCTS._edge_scores (minus the tiebreak)."""
        from repro.config import MCTSConfig
        from repro.core.mcts import MCTS
        from repro.core import tree as tree_lib
        from repro.go import GoEngine

        eng = GoEngine(5, komi=0.5)
        cfg = MCTSConfig(board_size=5, lanes=2, sims_per_move=16,
                         max_nodes=64)
        m = MCTS(eng, cfg)
        t = jax.jit(lambda s, k: m._search(s, k))(
            eng.init_state(), jax.random.PRNGKey(0)).tree

        node = 0
        kids = t.children[node]
        has_child = kids != -1
        cidx = jnp.maximum(kids, 0)
        player = tree_lib.node_state(t, node).to_play.astype(jnp.float32)
        args = (t.visit[cidx][None] * has_child[None],
                t.value[cidx][None] * has_child[None],
                t.vloss[cidx][None],
                t.prior[node][None],
                t.legal[node][None],
                has_child[None],
                (t.visit[node] + t.vloss[node])[None],
                player[None])
        kern = uct_scores(*args, c_uct=cfg.c_uct, vl_weight=cfg.virtual_loss,
                          use_puct=False, interpret=True)
        ref = m._edge_scores(t, node, player, jax.random.PRNGKey(1))
        # strip the stochastic tiebreak (<=1e-3) before comparing argmax sets
        np.testing.assert_allclose(np.asarray(kern[0]), np.asarray(ref),
                                   atol=2e-3)

    def test_virtual_loss_lowers_score(self):
        """With outcomes in [-1, 1] (as in Go), virtual loss can only make
        an edge less attractive — the decorrelation property the paper's
        tree parallelisation relies on."""
        b, a = 8, 128
        args = list(_uct_inputs(jax.random.PRNGKey(0), b, a))
        # bound mean values to the game-outcome range [-1, 1]
        args[1] = jnp.clip(args[1], -args[0], args[0])
        base = uct_scores(*args, interpret=True)
        args2 = list(args)
        args2[2] = args[2] + 5.0  # add virtual loss everywhere
        loaded = uct_scores(*args2, interpret=True)
        mask = np.asarray(args[5]) & np.asarray(args[4])
        assert (np.asarray(loaded)[mask] <= np.asarray(base)[mask] + 1e-5).all()


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
        (1, 2, 2, 128, 128, 64),
        (2, 4, 2, 256, 256, 64),      # GQA group=2
        (1, 8, 1, 128, 256, 128),     # MQA, decode-ish kv_offset
        (1, 2, 2, 96, 96, 32),        # non-multiple of block -> padding
    ])
    def test_causal_matches_ref(self, b, hq, hkv, sq, sk, d):
        key = jax.random.PRNGKey(hash((b, hq, sq)) % 2**31)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, hq, sq, d), jnp.float32)
        k = jax.random.normal(kk, (b, hkv, sk, d), jnp.float32)
        v = jax.random.normal(kv, (b, hkv, sk, d), jnp.float32)
        off = sk - sq
        got = flash_attention(q, k, v, causal=True, kv_offset=off,
                              bq=64, bk=64, interpret=True)
        want = attention_ref(q, k, v, causal=True, kv_offset=off)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 64])
    def test_sliding_window(self, window):
        key = jax.random.PRNGKey(7)
        kq, kk, kv = jax.random.split(key, 3)
        shape = (1, 2, 128, 64)
        q = jax.random.normal(kq, shape)
        k = jax.random.normal(kk, shape)
        v = jax.random.normal(kv, shape)
        got = flash_attention(q, k, v, causal=True, window=window,
                              bq=64, bk=64, interpret=True)
        want = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        key = jax.random.PRNGKey(9)
        kq, kk, kv = jax.random.split(key, 3)
        shape = (1, 2, 128, 64)
        q = jax.random.normal(kq, shape) * 3
        k = jax.random.normal(kk, shape) * 3
        v = jax.random.normal(kv, shape)
        got = flash_attention(q, k, v, causal=True, softcap=50.0,
                              bq=64, bk=64, interpret=True)
        want = attention_ref(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16_io(self):
        key = jax.random.PRNGKey(11)
        kq, kk, kv = jax.random.split(key, 3)
        shape = (1, 2, 128, 64)
        q = jax.random.normal(kq, shape).astype(jnp.bfloat16)
        k = jax.random.normal(kk, shape).astype(jnp.bfloat16)
        v = jax.random.normal(kv, shape).astype(jnp.bfloat16)
        got = flash_attention(q, k, v, causal=True, bq=64, bk=64,
                              interpret=True)
        want = attention_ref(q, k, v, causal=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2)

    def test_decode_single_query(self):
        """Sq=1 against a long cache — the serve_step shape."""
        key = jax.random.PRNGKey(13)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (2, 4, 1, 64))
        k = jax.random.normal(kk, (2, 2, 256, 64))
        v = jax.random.normal(kv, (2, 2, 256, 64))
        got = flash_attention(q, k, v, causal=True, kv_offset=255,
                              bq=8, bk=64, interpret=True)
        want = attention_ref(q, k, v, causal=True, kv_offset=255)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
