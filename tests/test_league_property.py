"""Property suite for the Bradley–Terry rating layer (core/tournament.py).

The league schedules on :func:`elo_estimate` — ratings with covariance —
so these properties pin the statistics against relabelings and scalings
that must not change the verdicts:

* **permutation equivariance** — renaming the configs permutes the
  ratings (and the covariance rows/columns) and nothing else;
* **transpose anti-symmetry** — flipping every result (``score -> Tᵀ``)
  negates the ratings;
* **symmetric table** — a cross table where every pairing is tied rates
  everyone equal (0 Elo, up to the mean-centring);
* **CI monotonicity** — scaling every pairing's games by ``k`` at the
  same win fractions shrinks every CI (more evidence, same fit), and
  separation never drops;
* **no-evidence floor** — an empty cross table separates nothing (the
  scheduling loop's "play everything first" base case).

Seeded sweeps always run; the hypothesis tier widens the same checks
when the package is installed (mirrors tests/test_go_property.py).
"""
import numpy as np
import pytest

from repro.core.tournament import elo_estimate, elo_ratings

try:                                    # property tier (CI installs .[test])
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings
    SETTINGS = dict(max_examples=25, deadline=None,
                    suppress_health_check=list(hypothesis.HealthCheck))
except ImportError:                     # seeded-sweep tier still runs
    hypothesis = None


def random_table(rng: np.random.Generator, players: int,
                 max_games: int = 12, sparsity: float = 0.2):
    """A valid (score, games) cross table: symmetric games, split points."""
    score = np.zeros((players, players))
    games = np.zeros((players, players))
    for i in range(players):
        for j in range(i + 1, players):
            if rng.random() < sparsity:
                continue
            n = int(rng.integers(1, max_games + 1))
            wins = int(rng.integers(0, n + 1))
            draws = int(rng.integers(0, n - wins + 1))
            score[i, j] = wins + 0.5 * draws
            score[j, i] = n - score[i, j]
            games[i, j] = games[j, i] = n
    return score, games


def assert_permutation_equivariant(score, games):
    """elo(P S Pᵀ) == P elo(S) for a random relabeling P."""
    P = score.shape[0]
    perm = np.random.default_rng(0).permutation(P)
    base = elo_ratings(score, games)
    permuted = elo_ratings(score[np.ix_(perm, perm)],
                           games[np.ix_(perm, perm)])
    np.testing.assert_allclose(permuted, base[perm], atol=1e-6)
    est, est_p = (elo_estimate(score, games),
                  elo_estimate(score[np.ix_(perm, perm)],
                               games[np.ix_(perm, perm)]))
    np.testing.assert_allclose(est_p.elo, est.elo[perm], atol=1e-6)
    np.testing.assert_allclose(est_p.cov, est.cov[np.ix_(perm, perm)],
                               atol=1e-5)
    np.testing.assert_allclose(est_p.ci, est.ci[perm], atol=1e-6)


def assert_transpose_antisymmetric(score, games):
    """Flipping every result negates the ratings."""
    np.testing.assert_allclose(elo_ratings(score.T, games.T),
                               -elo_ratings(score, games), atol=1e-5)


def assert_ci_monotone(score, games, k: int = 4):
    """k-fold evidence at the same win fractions: CIs shrink."""
    a = elo_estimate(score, games)
    b = elo_estimate(k * score, k * games)
    played = games.sum(axis=1) > 0
    assert (b.ci[played] <= a.ci[played] + 1e-9).all(), (a.ci, b.ci)
    for i in range(score.shape[0]):
        for j in range(i + 1, score.shape[0]):
            if games[i, j] > 0:
                assert (b.separation(i, j)
                        >= a.separation(i, j) - 1e-9), (i, j)


class TestSeededSweep:
    """Deterministic random tables: the tier that always runs."""

    @pytest.mark.parametrize("players", [2, 3, 5])
    def test_permutation_equivariance(self, players):
        rng = np.random.default_rng(players)
        for _ in range(10):
            assert_permutation_equivariant(*random_table(rng, players))

    @pytest.mark.parametrize("players", [2, 3, 5])
    def test_transpose_antisymmetry(self, players):
        rng = np.random.default_rng(10 + players)
        for _ in range(10):
            assert_transpose_antisymmetric(*random_table(rng, players))

    @pytest.mark.parametrize("players", [2, 3, 5])
    def test_ci_shrinks_with_games(self, players):
        rng = np.random.default_rng(20 + players)
        for _ in range(10):
            assert_ci_monotone(*random_table(rng, players))

    def test_symmetric_table_rates_equal(self):
        games = np.full((4, 4), 6.0)
        np.fill_diagonal(games, 0.0)
        score = games / 2.0                      # every pairing tied
        np.testing.assert_allclose(elo_ratings(score, games),
                                   np.zeros(4), atol=1e-6)
        est = elo_estimate(score, games)
        np.testing.assert_allclose(est.elo, np.zeros(4), atol=1e-6)
        # tied-and-played pairings are *unresolved*: gap 0, finite se
        assert not est.separated(0, 1)
        assert est.ci.min() > 0

    def test_empty_table_separates_nothing(self):
        est = elo_estimate(np.zeros((3, 3)), np.zeros((3, 3)))
        for i in range(3):
            for j in range(i + 1, 3):
                assert est.separation(i, j) == 0.0
                assert not est.separated(i, j)

    def test_decisive_pairing_separates(self):
        # 12-0 between two players: a gap of many standard errors
        score = np.array([[0.0, 12.0], [0.0, 0.0]])
        games = np.array([[0.0, 12.0], [12.0, 0.0]])
        est = elo_estimate(score, games)
        assert est.elo[0] > est.elo[1]
        assert est.separated(0, 1)

    def test_ratings_are_mean_centred(self):
        rng = np.random.default_rng(7)
        for _ in range(5):
            score, games = random_table(rng, 4, sparsity=0.0)
            assert abs(elo_ratings(score, games).mean()) < 1e-9


if hypothesis is not None:

    @st.composite
    def tables(draw, max_players: int = 5):
        players = draw(st.integers(min_value=2, max_value=max_players))
        seed = draw(st.integers(min_value=0, max_value=2 ** 31 - 1))
        sparsity = draw(st.floats(min_value=0.0, max_value=0.5))
        return random_table(np.random.default_rng(seed), players,
                            sparsity=sparsity)

    class TestHypothesis:
        """Generative tier: same invariants, wider input space."""

        @settings(**SETTINGS)
        @given(tables())
        def test_permutation_equivariance(self, table):
            assert_permutation_equivariant(*table)

        @settings(**SETTINGS)
        @given(tables())
        def test_transpose_antisymmetry(self, table):
            assert_transpose_antisymmetric(*table)

        @settings(**SETTINGS)
        @given(tables(), st.integers(min_value=2, max_value=8))
        def test_ci_shrinks_with_games(self, table, k):
            assert_ci_monotone(*table, k=k)
