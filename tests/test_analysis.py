"""Analysis-layer tests: roofline terms, wire-cost model, serving engine."""
import jax
import jax.numpy as jnp

from repro.analysis.hlo import _wire, analyze
from repro.analysis.roofline import model_flops, roofline_terms
from repro.config import SHAPES, get_model_config


class TestWireModel:
    def test_ring_allreduce(self):
        # 2*size*(n-1)/n
        assert _wire("all-reduce", 1000, 4) == 2 * 1000 * 3 / 4

    def test_allgather_reduce_scatter_duality(self):
        n, out = 8, 800
        ag = _wire("all-gather", out, n)          # out = gathered result
        rs = _wire("reduce-scatter", out / n, n)  # out = scattered result
        assert abs(ag - rs) < 1e-9

    def test_single_member_group_free(self):
        for k in ("all-reduce", "all-gather", "all-to-all"):
            assert _wire(k, 12345, 1) == 0.0


class TestRooflineTerms:
    def test_dominant_selection(self):
        cost = {"flops": 197e12, "hbm_bytes": 1.0}
        t = roofline_terms(cost, {"total": 0.0}, chips=1)
        assert t["dominant"] == "compute_s"
        assert abs(t["compute_s"] - 1.0) < 1e-9
        cost = {"flops": 1.0, "hbm_bytes": 819e9 * 2}
        t = roofline_terms(cost, {"total": 0.0}, chips=1)
        assert t["dominant"] == "memory_s"
        t = roofline_terms({"flops": 0, "hbm_bytes": 0},
                           {"total": 50e9 * 3}, chips=1)
        assert t["dominant"] == "collective_s"
        assert abs(t["collective_s"] - 3.0) < 1e-9

    def test_model_flops_scaling(self):
        cfg = get_model_config("yi-6b")
        tr = SHAPES["train_4k"]
        pf = SHAPES["prefill_32k"]
        de = SHAPES["decode_32k"]
        # train = 3x the forward cost per token (2 fwd + 4 bwd)
        per_tok_train = model_flops(cfg, tr) / (tr.global_batch * tr.seq_len)
        assert per_tok_train > 6 * cfg.num_params() * 0.9
        # decode touches every active param twice per generated token
        per_tok_dec = model_flops(cfg, de) / de.global_batch
        assert per_tok_dec > 2 * cfg.num_params() * 0.9

    def test_moe_uses_active_params(self):
        moe = get_model_config("moonshot-v1-16b-a3b")
        tr = SHAPES["train_4k"]
        f = model_flops(moe, tr)
        dense_equiv = 6 * moe.num_params() * tr.global_batch * tr.seq_len
        assert f < dense_equiv * 0.45   # only ~active/total of dense cost


class TestServeEngine:
    def test_generate_shapes_and_counts(self):
        from repro.configs.reduced import reduced
        from repro.models import build_model
        from repro.serving import ServeEngine
        cfg = reduced("yi-6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, batch=2, max_prompt=8, max_new=4,
                          eos_id=cfg.vocab_size + 5)   # never emitted
        outs = eng.generate([[5, 6, 7], [9, 10]], seed=0)
        assert len(outs) == 2
        assert all(1 <= len(o) <= 4 for o in outs)
        assert all(0 <= t < cfg.vocab_size for o in outs for t in o)

    def test_greedy_deterministic(self):
        from repro.configs.reduced import reduced
        from repro.models import build_model
        from repro.serving import ServeEngine
        cfg = reduced("yi-6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        eng = ServeEngine(model, params, batch=1, max_prompt=8, max_new=4,
                          eos_id=10 ** 6, temperature=0.0)
        a = eng.generate([[3, 4, 5]], seed=0)
        b = eng.generate([[3, 4, 5]], seed=99)   # greedy ignores seed
        assert a == b

    def test_temperature_is_traced_not_baked(self):
        """Changing temperature must reuse the compiled decode step (the
        seed baked it into the jit closure and recompiled per value)."""
        from repro.configs.reduced import reduced
        from repro.models import build_model
        from repro.serving import ServeEngine
        cfg = reduced("yi-6b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(2))
        eng = ServeEngine(model, params, batch=1, max_prompt=8, max_new=3,
                          eos_id=10 ** 6)
        for temp in (0.0, 0.7, 1.3):
            out = eng.generate([[3, 4, 5]], seed=0, temperature=temp)
            assert all(0 <= t < cfg.vocab_size for t in out[0])
        assert eng.decode._cache_size() == 1


class TestAnalyzeEndToEnd:
    def test_small_jit_flops(self):
        w = jnp.zeros((64, 64))
        comp = jax.jit(lambda x: x @ w).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        res = analyze(comp.as_text())
        assert res["flops"] == 2 * 64 ** 3
        assert res["hbm_bytes"] >= 3 * 64 * 64 * 4  # two reads + write
