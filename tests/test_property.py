"""Property-based tests (hypothesis) on system invariants."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install '.[test]')")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import stats
from repro.go import GoEngine

SETTINGS = dict(max_examples=15, deadline=None,
                suppress_health_check=list(hypothesis.HealthCheck))


@st.composite
def random_board(draw, size=5):
    cells = draw(st.lists(st.sampled_from([0, 1, -1]),
                          min_size=size * size, max_size=size * size))
    return jnp.asarray(np.array(cells, np.int8))


class TestGoProperties:
    @settings(**SETTINGS)
    @given(random_board())
    def test_group_info_partitions_stones(self, board):
        """Every stone belongs to exactly one group rooted at a stone of
        the same colour; empty cells have no group."""
        eng = GoEngine(5)
        ids, libs = eng.group_info(board)
        ids = np.asarray(ids)
        b = np.asarray(board)
        for p in range(25):
            if b[p] == 0:
                assert ids[p] == 25
            else:
                root = ids[p]
                assert 0 <= root < 25
                assert b[root] == b[p]          # root has the same colour
                assert ids[root] == root        # root is canonical

    @settings(**SETTINGS)
    @given(random_board())
    def test_liberties_bounded_and_consistent(self, board):
        eng = GoEngine(5)
        ids, libs = eng.group_info(board)
        libs = np.asarray(libs)
        b = np.asarray(board)
        empty = int((b == 0).sum())
        for p in range(25):
            if b[p] != 0:
                assert 0 <= libs[p] <= empty
                # same group => same liberty count
                same = np.asarray(ids) == np.asarray(ids)[p]
                assert (libs[same] == libs[p]).all()

    @settings(**SETTINGS)
    @given(random_board())
    def test_score_bounded(self, board):
        eng = GoEngine(5)
        s = float(eng.score(board))
        assert -25.0 <= s <= 25.0

    @settings(**SETTINGS)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_random_games_keep_invariants(self, seed):
        """A full random playout never leaves a zero-liberty group on the
        board and always terminates with a legal score."""
        eng = GoEngine(5, komi=0.5)
        final = eng.random_playout(eng.init_state(),
                                   jax.random.PRNGKey(seed))
        assert bool(final.done)
        _, libs = eng.group_info(final.board)
        stones = np.asarray(final.board) != 0
        assert (np.asarray(libs)[stones] > 0).all()
        assert int(final.move_count) <= eng.max_moves

    @settings(**SETTINGS)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 24))
    def test_play_flips_player_and_grows_or_keeps_stones(self, seed, moves):
        eng = GoEngine(5, komi=0.5)
        st_ = eng.init_state()
        key = jax.random.PRNGKey(seed)
        for _ in range(moves):
            if bool(st_.done):
                break
            prev_player = int(st_.to_play)
            key, sub = jax.random.split(key)
            st_ = eng.playout_step(st_, sub)
            assert int(st_.to_play) == -prev_player


class TestStatsProperties:
    @settings(**SETTINGS)
    @given(st.integers(0, 200), st.integers(0, 200))
    def test_ci_contains_point_and_shrinks(self, w, l):
        r = stats.win_rate(w, l)
        assert r.lo <= r.rate <= r.hi
        if w + l > 0:
            r2 = stats.win_rate(w * 4, l * 4)
            assert (r2.hi - r2.lo) <= (r.hi - r.lo) + 1e-12

    @settings(**SETTINGS)
    @given(st.integers(1, 100))
    def test_symmetry(self, n):
        a = stats.win_rate(n, n)
        assert abs(a.rate - 0.5) < 1e-12
        assert abs((a.hi - 0.5) - (0.5 - a.lo)) < 1e-12


class TestConfigProperties:
    @settings(**SETTINGS)
    @given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 8))
    def test_override_roundtrip(self, lanes, a, b):
        from repro.config import MCTSConfig, apply_overrides
        cfg = MCTSConfig()
        out = apply_overrides(cfg, {"lanes": str(lanes),
                                    "sims_per_move": str(a * b)})
        assert out.lanes == lanes and out.sims_per_move == a * b
        assert cfg.lanes == 8                 # original untouched (frozen)

    @settings(**SETTINGS)
    @given(st.sampled_from(["compact", "balanced", "scatter"]),
           st.integers(1, 256), st.integers(1, 64))
    def test_affinity_total_conservation(self, policy, lanes, devices):
        from repro.core import affinity
        a = affinity.lane_to_device(policy, lanes, devices)
        load = affinity.device_load(a, devices)
        assert load.sum() == lanes            # every lane placed exactly once
        assert (a >= 0).all() and (a < devices).all()


class TestHloCostProperties:
    @settings(**SETTINGS)
    @given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64),
           st.integers(1, 12))
    def test_dot_flops_formula(self, m, k, n, trips):
        """Synthetic HLO: scan of a [m,k]x[k,n] dot must cost 2mkn*trips."""
        from repro.analysis.hlo import analyze
        hlo = f"""
HloModule test

%body (p: (s32[], f32[{m},{k}])) -> (s32[], f32[{m},{k}]) {{
  %p = (s32[], f32[{m},{k}]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[{m},{k}] get-tuple-element(%p), index=1
  %w = f32[{k},{n}] constant(0)
  %d = f32[{m},{n}] dot(%x, %w), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
  ROOT %t = (s32[], f32[{m},{k}]) tuple(%i, %x)
}}

%cond (p: (s32[], f32[{m},{k}])) -> pred[] {{
  %p = (s32[], f32[{m},{k}]) parameter(0)
  ROOT %lt = pred[] constant(true)
}}

ENTRY %main (a: f32[{m},{k}]) -> (s32[], f32[{m},{k}]) {{
  %a = f32[{m},{k}] parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[{m},{k}]) tuple(%z, %a)
  ROOT %w0 = (s32[], f32[{m},{k}]) while(%t0), condition=%cond, body=%body, backend_config={{"known_trip_count":{{"n":"{trips}"}}}}
}}
"""
        res = analyze(hlo)
        assert res["flops"] == 2.0 * m * k * n * trips
