"""Multi-device tests (8 fake CPU devices via subprocess so the main test
process keeps its single-device view): sharded root-parallel MCTS, pipeline
parallelism numerics, PowerSGD cross-pod step, seq-sharded decode attention,
sharded train_step."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 8-fake-device subprocess compiles

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", "")).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestDistributedMCTS:
    def test_root_parallel_shard_map(self):
        run_sub("""
import jax, jax.numpy as jnp
from repro.config import MCTSConfig
from repro.core.distributed import distributed_best_move
from repro.go import GoEngine

assert jax.device_count() == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
eng = GoEngine(5, komi=0.5)
cfg = MCTSConfig(board_size=5, lanes=2, sims_per_move=32, max_nodes=64,
                 root_trees=4)
fn = distributed_best_move(eng, cfg, mesh, axis="data")
move = fn(eng.init_state(), jax.random.PRNGKey(0))
legal = eng.legal_moves(eng.init_state())
assert bool(legal[int(move)]), int(move)
print("OK", int(move))
""")

    def test_affinity_policies_change_device_busy_set(self):
        run_sub("""
import numpy as np
from repro.core import affinity
# 8 lanes on 8 devices: compact uses 2 devices, scatter uses all 8
c = affinity.lane_to_device("compact", 8, 8, slots_per_device=4)
s = affinity.lane_to_device("scatter", 8, 8)
assert affinity.utilisation(c, 8) == 0.25
assert affinity.utilisation(s, 8) == 1.0
print("OK")
""")


class TestPipelineParallel:
    def test_gpipe_matches_sequential(self):
        run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_forward

mesh = jax.make_mesh((4,), ("pod",))
S, M, MB, D = 4, 8, 2, 16   # stages, microbatches, microbatch size, width
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (S, D, D)) * 0.3

def layer_fn(p, x):
    return jnp.tanh(x @ p["w"])

fn = pipeline_forward(layer_fn, mesh, axis="pod")
xs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
got = fn({"w": w}, xs)

# sequential reference
ref = xs
for s in range(S):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print("OK pipeline matches sequential")
""")


class TestCompressedPodStep:
    def test_powersgd_cross_pod_mean(self):
        run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.parallel.compress import (init_powersgd,
                                     compressed_cross_pod_mean)

mesh = jax.make_mesh((2, 4), ("pod", "data"))
# per-pod gradients: low-rank + small per-pod noise
base = jnp.outer(jnp.arange(16.0), jnp.ones(16))
g_pods = jnp.stack([base * (1.0 + 0.1 * i) for i in range(2)])
state = init_powersgd({"w": base}, rank=4)

def f(gp, q, e):
    g = {"w": gp[0]}
    st = type(state)(q={"w": q[0]}, error={"w": e[0]})
    mean, st2 = compressed_cross_pod_mean(g, st, axis="pod")
    return mean["w"][None], st2.q["w"][None], st2.error["w"][None]

fn = shard_map(f, mesh=mesh,
               in_specs=(P("pod"), P("pod"), P("pod")),
               out_specs=(P("pod"), P("pod"), P("pod")),
               check_rep=False)
qs = jnp.stack([state.q["w"]] * 2)
es = jnp.stack([state.error["w"]] * 2)
mean, q2, e2 = fn(g_pods, qs, es)
want = np.asarray(g_pods.mean(0))
# rank-4 exactly captures the rank-1 mean
np.testing.assert_allclose(np.asarray(mean[0]), want, rtol=1e-3, atol=1e-3)
# error feedback holds the (tiny) residual
assert float(jnp.abs(e2).max()) < 1.0
print("OK compressed mean")
""")

    def test_train_step_with_pod_compression_lowers(self):
        run_sub("""
import dataclasses, jax, jax.numpy as jnp
from repro.config import TrainConfig
from repro.configs.reduced import reduced
from repro.models import build_model
from repro.models import sharding as shlib
from repro.training import init_train_state, make_train_step

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = reduced("yi-6b")
model = build_model(cfg)
tcfg = TrainConfig(steps=4, microbatches=1, lr=1e-3, warmup_steps=1,
                   compress_pod_grads=True, powersgd_rank=4)
with shlib.use_mesh(mesh):
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = make_train_step(model, tcfg, mesh=mesh)
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
             "labels": jnp.zeros((8, 16), jnp.int32)}
    state2, metrics = jax.jit(step)(state, batch)
print("OK loss", float(metrics["loss"]))
""")


class TestSeqShardedDecode:
    def test_lse_combine_matches_reference(self):
        run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.models.attention import (KVCache, decode_attention,
                                    decode_attention_seq_sharded)

mesh = jax.make_mesh((2, 4), ("data", "model"))
B, HQ, HKV, S, D = 4, 8, 2, 64, 32
key = jax.random.PRNGKey(0)
kq, kk, kv = jax.random.split(key, 3)
q = jax.random.normal(kq, (B, HQ, 1, D))
cache = KVCache(k=jax.random.normal(kk, (B, HKV, S, D)),
                v=jax.random.normal(kv, (B, HKV, S, D)),
                length=jnp.int32(49))
ref = decode_attention(q, cache)
got = jax.jit(lambda q, c: decode_attention_seq_sharded(q, c, mesh))(q, cache)
np.testing.assert_allclose(np.asarray(got, np.float32),
                           np.asarray(ref, np.float32), rtol=2e-5, atol=2e-5)
print("OK seq-sharded decode")
""")


class TestShardedTrainStep:
    def test_dense_train_step_on_mesh(self):
        run_sub("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import TrainConfig
from repro.configs.reduced import reduced
from repro.models import build_model, param_shardings
from repro.models import sharding as shlib
from repro.training import init_train_state, make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = reduced("glm4-9b")
model = build_model(cfg)
tcfg = TrainConfig(steps=2, microbatches=2, lr=1e-3, warmup_steps=1)
with shlib.use_mesh(mesh):
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tcfg, mesh=mesh))
    batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
             "labels": jnp.zeros((8, 32), jnp.int32)}
    batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    state, m = step(state, batch)
    state, m = step(state, batch)
import numpy as np
assert np.isfinite(float(m["loss"]))
print("OK sharded step, loss", float(m["loss"]))
""")
