"""SearchService dispatcher tests (core/service.py): device-side refill
bit-for-bit vs the host queue, mixed-lane ticket fairness, the serve-lane
RNG contract, the traced per-request sims knob, and the tournament
scheduler.  (The streaming-pipeline suite lives in tests/test_pipeline.py;
the PR 2 deprecation-shim tests left with the shims.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import MCTSConfig
from repro.core.arena import Arena
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources
from repro.core.service import (LANE_ARENA, LANE_SERVE,
                                SearchService)

CFG = MCTSConfig(board_size=5, lanes=2, sims_per_move=8, max_nodes=64)
CAP = 12


@pytest.fixture(scope="module")
def players(engine5):
    return MCTS(engine5, double_resources(CFG)), MCTS(engine5, CFG)


@pytest.fixture(scope="module")
def arena_pair(engine5, players):
    """One compiled (host-refill, device-refill) arena pair, shared."""
    a, b = players
    return (Arena(engine5, a, b, slots=2, max_moves=CAP, refill="host"),
            Arena(engine5, a, b, slots=2, max_moves=CAP, refill="device"))


@pytest.fixture(scope="module")
def svc4(engine5, players):
    """One compiled 4-slot mixed-lane pool, reset() between tests."""
    a, b = players
    return SearchService(engine5, a, b, slots=4, max_moves=CAP)


@pytest.fixture(scope="module")
def jit_search(players):
    """Shared jitted search_batch of the 1x player (2- and 3-arg traces)."""
    return jax.jit(players[1].search_batch)


@pytest.fixture(scope="module")
def mid_state(engine5):
    """A position a few moves into a game (serve-query root)."""
    st = engine5.init_state()
    for mv in (3, 7, 12, 16):
        st = engine5.jit_play(st, jnp.int32(mv))
    return st


class TestDeviceRefill:
    @pytest.mark.slow
    def test_device_matches_host_queue_bit_for_bit(self, arena_pair):
        """The tentpole invariant: the jitted admission (pending counter +
        ring buffer) refills slots exactly like the PR 1 host loop — every
        game's (winner, moves, nodes, colour) is identical."""
        host, device = arena_pair
        games = 5                       # > slots: refill path exercised
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(3), games))
        assert (device.play_games(games, game_keys=keys)
                == host.play_games(games, game_keys=keys))

    def test_seeded_key_chain_matches_host_queue(self, arena_pair):
        """Keyless submissions draw from the same host chain as the PR 1
        loop (slot keys first, then per-game keys in admission order) —
        so the two refill modes play bit-identical games."""
        host, device = arena_pair
        assert (device.play_games(3, seed=11)
                == host.play_games(3, seed=11))

    def test_fewer_host_syncs_than_host_queue(self, arena_pair):
        host, device = arena_pair
        host.play_games(3, seed=0)
        device.play_games(3, seed=0)
        assert device.host_syncs < host.host_syncs


class TestSingleSearchPerStep:
    def test_dispatch_traces_one_search_per_player(self, engine5):
        """Per dispatch step the traced search batches cover each slot
        exactly once — S searched slots for S moves (the PR 1 invariant,
        now inside the service)."""
        a2 = MCTS(engine5, double_resources(CFG))
        b2 = MCTS(engine5, CFG)
        searched = []

        def counting(player, tag):
            orig = player.search_batch

            def wrapped(roots, rngs, sims=None, params=None):
                searched.append((tag, int(rngs.shape[0])))
                return orig(roots, rngs, sims, params)
            player.search_batch = wrapped

        counting(a2, "A")
        counting(b2, "B")
        svc = SearchService(engine5, a2, b2, slots=4, max_moves=CAP)
        svc.dispatch(steps=1)
        assert sorted(searched) == [("A", 2), ("B", 2)]


class TestMixedLanes:
    def test_mixed_pool_runs_all_lanes(self, svc4, mid_state):
        svc4.reset(seed=0, colour_cap=1)
        gk = np.asarray(jax.random.split(jax.random.PRNGKey(9), 2))
        sk = np.asarray(jax.random.split(jax.random.PRNGKey(11), 3))
        gt = [svc4.submit_game(key=gk[i]) for i in range(2)]
        st = [svc4.submit_serve(mid_state, key=sk[i]) for i in range(3)]
        recs = {r.ticket: r for r in svc4.drain()}
        assert sorted(recs) == sorted(gt + st)
        for t in gt:
            assert recs[t].lane == LANE_ARENA
            assert recs[t].winner in (-1.0, 0.0, 1.0)
            assert 0 < recs[t].moves <= CAP
        for t in st:
            assert recs[t].lane == LANE_SERVE
            assert recs[t].moves == 1
        # colour balance across the game lane holds in the mixed pool
        blacks = [recs[t].a_is_black for t in gt]
        assert sorted(blacks) == [False, True]

    def test_serve_key_contract(self, players, svc4, mid_state):
        """A serve result is player A's search_batch with the request key
        — independent of slot placement and batch-mates (bit-for-bit)."""
        a, _ = players
        svc4.reset(seed=0)
        sk = np.asarray(jax.random.split(jax.random.PRNGKey(5), 2))
        svc4.submit_game()              # batch-mates in the pool
        tickets = [svc4.submit_serve(mid_state, key=sk[i], sims=s)
                   for i, s in enumerate((0, 4))]
        recs = {r.ticket: r for r in svc4.drain()}
        roots = jax.tree.map(lambda x: x[None], mid_state)
        want_fn = jax.jit(a.search_batch)
        for i, (t, s) in enumerate(zip(tickets, (0, 4))):
            want = want_fn(roots, jnp.asarray(sk[i])[None],
                           jnp.asarray([s], jnp.int32))
            assert recs[t].action == int(want.action[0])
            np.testing.assert_array_equal(
                recs[t].root_visits, np.asarray(want.root_visits[0]))

    def test_serve_tickets_resolve_fifo(self, engine5, players, mid_state):
        """Under contention (one A-cell per step) serve queries complete
        in submission order."""
        a, _ = players
        svc = SearchService(engine5, a, a, slots=2, max_moves=CAP)
        keys = np.asarray(jax.random.split(jax.random.PRNGKey(2), 5))
        tickets = [svc.submit_serve(mid_state, key=keys[i])
                   for i in range(5)]
        order = [r.ticket for r in svc.drain() if r.lane == LANE_SERVE]
        assert order == tickets

    def test_validation_and_queue_limits(self, engine5, players, mid_state):
        a, b = players
        with pytest.raises(ValueError):
            SearchService(engine5, a, b, slots=3)
        with pytest.raises(ValueError):
            SearchService(engine5, a, b, slots=2, superstep=0)
        svc = SearchService(engine5, a, b, slots=2)
        with pytest.raises(ValueError):
            svc.submit_game(lane=LANE_SERVE)
        svc.reset(serve_capacity=2, game_capacity=2)
        svc.submit_serve(mid_state)
        svc.submit_serve(mid_state)
        with pytest.raises(RuntimeError):
            svc.submit_serve(mid_state)


class TestSimsKnob:
    def test_full_budget_bit_identical_to_static_loop(self, engine5,
                                                      jit_search):
        """sims=0 and sims>=configured budget both reproduce the static
        loop exactly — the masked tail is a no-op select."""
        roots = jax.tree.map(lambda x: x[None], engine5.init_state())
        key = jax.random.PRNGKey(4)[None]
        base = jit_search(roots, key)
        for sims in (0, CFG.sims_per_move, CFG.sims_per_move * 10):
            res = jit_search(roots, key, jnp.asarray([sims], jnp.int32))
            assert int(res.action[0]) == int(base.action[0])
            np.testing.assert_array_equal(np.asarray(res.root_visits),
                                          np.asarray(base.root_visits))
            np.testing.assert_array_equal(np.asarray(res.tree.visit),
                                          np.asarray(base.tree.visit))

    def test_smaller_budget_masks_iterations(self, engine5, jit_search):
        """The root's visit count pins iterations = sims // lanes, and
        the reported tree size tracks the truncated budget (dead
        iterations allocate nothing visible)."""
        roots = jax.tree.map(lambda x: x[None], engine5.init_state())
        key = jax.random.PRNGKey(4)[None]
        sizes = {}
        for sims, iters in ((4, 2), (8, 4), (2, 1)):
            res = jit_search(roots, key, jnp.asarray([sims], jnp.int32))
            assert float(res.tree.visit[0, 0]) == 1.0 + iters * CFG.lanes
            sizes[sims] = int(res.tree.size[0])
        assert sizes[2] <= sizes[4] <= sizes[8]

    def test_sims_is_traced_not_static(self, engine5, players):
        """Changing the budget must not recompile (the ServeEngine
        temperature treatment applied to the search loop)."""
        _, b = players
        fn = jax.jit(b.search_batch)
        roots = jax.tree.map(lambda x: x[None], engine5.init_state())
        key = jax.random.PRNGKey(0)[None]
        for sims in (2, 4, 8):
            fn(roots, key, jnp.asarray([sims], jnp.int32))
        assert fn._cache_size() == 1


class TestTournament:
    @pytest.mark.slow
    def test_round_robin_through_one_pool(self, engine5):
        from repro.core.tournament import Tournament
        cfgs = [CFG, double_resources(CFG)]
        t = Tournament(engine5, cfgs, names=("1x", "2x"),
                       games_per_pair=3, slots=2, max_moves=CAP, seed=1)
        res = t.round_robin()
        assert res.games == 3
        pair = res.pairs[(0, 1)]
        assert pair.i_wins + pair.j_wins + pair.draws == 3
        assert res.points.sum() == pytest.approx(3.0)
        assert 0.0 <= pair.rate.lo <= pair.rate.rate <= pair.rate.hi <= 1.0
        assert "points" in res.table()
        assert t.host_syncs > 0

    def test_tournament_validation(self, engine5):
        from repro.core.tournament import Tournament
        with pytest.raises(ValueError):
            Tournament(engine5, [CFG])
        with pytest.raises(ValueError):
            Tournament(engine5, [CFG, CFG], names=("only-one",))


class TestGoService:
    @pytest.fixture(scope="class")
    def go_service(self):
        from repro.serving.go_service import GoService
        return GoService(board_size=5, komi=0.5, max_sims=8, lanes=2,
                         slots=4, seed=0)

    def test_best_move_deterministic_and_legal(self, go_service):
        board = np.zeros(25, np.int8)
        board[12] = 1
        key = np.asarray(jax.random.PRNGKey(8))
        m1 = go_service.best_move(board, to_play=-1, key=key)
        m2 = go_service.best_move(board, to_play=-1, key=key)
        assert m1.action == m2.action
        np.testing.assert_array_equal(m1.root_visits, m2.root_visits)
        assert 0 <= m1.action <= 25
        assert m1.is_pass == (m1.action == 25)
        assert (m1.coord is None) == m1.is_pass
        if not m1.is_pass:
            assert m1.action != 12      # occupied point is illegal

    def test_batch_and_tickets(self, go_service):
        boards = [np.zeros(25, np.int8) for _ in range(5)]
        res = go_service.best_move_batch(boards, sims=4)
        assert [r.ticket for r in res] == sorted(r.ticket for r in res)
        with pytest.raises(KeyError):
            go_service.result(10_000)
