"""Open-loop Poisson load bench for the HTTP front door -> BENCH_load.json.

The paper's threads-vs-performance figure, re-plotted for the serving
tier: instead of thread count on the x-axis, *offered load* (requests/s)
— and instead of raw throughput, the latency percentiles and shed rate a
client actually experiences.  The generator is **open-loop**: arrival
times are a Poisson process (pre-drawn exponential gaps) fired on
schedule regardless of completions, so queue buildup is visible instead
of being absorbed by closed-loop self-throttling — the standard
methodology for SLO benchmarks, and the honest one for the paper's
thesis that coordination (not compute) sets the knee.

Per offered-load point the bench reports client-side p50/p95/p99 over
successful requests, the explicit-shed split (HTTP 503 over-capacity /
504 deadline), and the server's own ``/metrics`` delta.  The smoke cell
(``--smoke``, the CI load gate) drives two komi buckets and asserts the
SLO contract end to end: **zero unshed losses** (every request answers
200, 503, or 504 — nothing hangs or errors), **no shedding** at the
bottom point, **explicit shedding** at the top (4x capacity) point, and
bottom-point p99 under ``--p99-budget-ms``.

``--buckets N`` (PR 10) adds the **multi-bucket cell**: skewed
Zipf-distributed komi traffic over N buckets, driven head-to-head
through the unified scheduler (one pool, one pump,
``GoService(unified=True)``) and the per-bucket baseline (one pool +
pipeline per komi, ``unified=False``) — same request stream, compiles
excluded.  The cell reports sims/sec, host syncs per move, and the
dispatch-trace count for each mode; the smoke gate requires the unified
scheduler to compile exactly ONE dispatch across all buckets and to win
>= 1.3x on sims/sec or >= 1.5x on host syncs.  This leg drives
GoService directly (no HTTP) so the comparison measures scheduling, not
socket parsing, and the sync counts stay deterministic.

    PYTHONPATH=src python benchmarks/bench_load.py --smoke --buckets 4
    PYTHONPATH=src python benchmarks/bench_load.py \
        --requests 200 --rates 0.25,0.75,4.0 [--url http://host:port]
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time

if __package__ in (None, ""):                    # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

SCHEMA = "bench_load/v1"
BOARD = 5
SIMS = 16
SLOTS = 8
LANES = 4


def _board(rng: np.random.Generator, n2: int) -> list:
    """A sparse random position (a few non-capturing stones)."""
    b = np.zeros(n2, np.int8)
    stones = rng.choice(n2, size=3, replace=False)
    b[stones[:2]] = 1
    b[stones[2:]] = -1
    return b.tolist()


async def _request(client, at_s: float, payload: dict) -> dict:
    """Fire one request at its scheduled time; never raise."""
    loop = asyncio.get_event_loop()
    await asyncio.sleep(max(0.0, at_s - loop.time()))
    t0 = time.perf_counter()
    try:
        status, body = await client(payload)
    except Exception as e:                       # a loss, not a shed
        return {"status": -1, "latency_s": time.perf_counter() - t0,
                "error": repr(e)}
    return {"status": status, "latency_s": time.perf_counter() - t0,
            "downgraded": bool(body.get("downgraded", False))}


async def run_point(client, metrics, rate_rps: float, n: int,
                    komis: list, deadline_ms: float,
                    rng: np.random.Generator, n2: int) -> dict:
    """One offered-load point: n Poisson arrivals at rate_rps."""
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    fire_at = np.cumsum(gaps)
    loop = asyncio.get_event_loop()
    t0 = loop.time() + 0.05                      # small scheduling margin
    before = await metrics()
    tasks = [asyncio.ensure_future(_request(
        client, t0 + fire_at[i],
        {"board": _board(rng, n2), "komi": komis[i % len(komis)],
         "deadline_ms": deadline_ms}))
        for i in range(n)]
    results = await asyncio.gather(*tasks)
    wall = loop.time() - t0
    after = await metrics()

    ok = [r for r in results if r["status"] == 200]
    lat_ms = np.array([r["latency_s"] for r in ok]) * 1e3
    by_status = {}
    for r in results:
        by_status[r["status"]] = by_status.get(r["status"], 0) + 1
    shed = by_status.get(503, 0) + by_status.get(504, 0) \
        + by_status.get(410, 0)
    losses = n - len(ok) - shed
    delta = {k: after["metrics"][k] - before["metrics"][k]
             for k in ("completed", "downgraded", "shed_overload",
                       "shed_deadline", "deadline_miss")}
    point = {
        "offered_rps": rate_rps,
        "requests": n,
        "wall_s": wall,
        "achieved_rps": len(ok) / wall if wall > 0 else 0.0,
        "ok": len(ok),
        "shed": shed,
        "shed_rate": shed / n,
        "losses": losses,
        "by_status": {str(k): v for k, v in sorted(by_status.items())},
        "downgraded": sum(1 for r in ok if r.get("downgraded")),
        "server_delta": delta,
    }
    if len(ok):
        point.update(
            p50_ms=float(np.percentile(lat_ms, 50)),
            p95_ms=float(np.percentile(lat_ms, 95)),
            p99_ms=float(np.percentile(lat_ms, 99)),
            mean_ms=float(lat_ms.mean()),
            max_ms=float(lat_ms.max()),
        )
    return point


async def calibrate(client, komis: list, slots: int,
                    rng: np.random.Generator, n2: int,
                    waves: int = 3) -> dict:
    """Warm every komi bucket, then measure closed-loop capacity.

    Warmup pays each bucket's one-time jit compile (excluded from every
    timing) and seeds the server's deadline-policy calibration; capacity
    is ``slots`` concurrent blocking queries per wave — the pool's
    closed-loop ceiling the open-loop rates are scaled from.
    """
    for komi in komis:                           # compile, serially
        status, _ = await client({"board": [0] * n2, "komi": komi})
        if status != 200:
            raise RuntimeError(f"warmup query failed with HTTP {status}")
    t0 = time.perf_counter()
    n = 0
    for _ in range(waves):
        batch = [client({"board": _board(rng, n2),
                         "komi": komis[i % len(komis)]})
                 for i in range(slots)]
        for status, _ in await asyncio.gather(*batch):
            if status == 200:
                n += 1
    wall = time.perf_counter() - t0
    if n == 0:
        raise RuntimeError("calibration produced no successful queries")
    lat_ms = wall / waves * 1e3                  # one wave ~ one pool pass
    return {"capacity_rps": n / wall, "wave_ms": lat_ms,
            "warm_queries": n}


def run_multi_bucket(args: argparse.Namespace) -> dict:
    """The multi-bucket cell: unified scheduler vs per-bucket pools.

    One skewed request stream (komi drawn Zipf over ``--buckets``
    values, hot bucket first) is pushed through both scheduling modes
    with identical seeds and budgets: submit as admission allows, poll
    continuously, stop when every move answers.  Compiles are paid
    before the clock starts (one warm query per komi), so the cell
    measures steady-state scheduling cost — exactly where the
    per-bucket path burns one pump + reconcile per komi per round while
    the unified path spends one total.
    """
    from repro.serving.go_service import GoService, OverCapacityError

    rng = np.random.default_rng(args.seed)
    n2 = args.board * args.board
    nb = int(args.buckets)
    komis = [round(5.5 + 0.5 * i, 1) for i in range(nb)]
    # Zipf-skewed traffic: bucket rank r carries weight (r+1)^-s
    weights = np.array([(r + 1.0) ** -args.zipf_s for r in range(nb)])
    weights /= weights.sum()
    n = int(args.mb_requests)
    picks = rng.choice(nb, size=n, p=weights)
    boards = [_board(rng, n2) for _ in range(n)]

    def drive(unified: bool) -> dict:
        svc = GoService(board_size=args.board, komi=komis[0],
                        max_sims=args.sims, lanes=args.lanes,
                        slots=args.slots, seed=args.seed,
                        pipeline_depth=args.pipeline_depth,
                        queue_capacity=4 * args.slots * nb,
                        admission_limit=2 * args.slots,
                        unified=unified)
        for k in komis:                  # pay every compile up front
            svc.best_move(boards[0], komi=k)
        syncs0 = svc.host_syncs
        t0 = time.perf_counter()
        i = done = 0
        while done < n:
            while i < n:
                try:
                    svc.submit(boards[i], komi=komis[picks[i]])
                except OverCapacityError:
                    break                # bucket full: poll, then retry
                i += 1
            for t in svc.poll():
                svc.result(t, wait=False)
                done += 1
        wall = time.perf_counter() - t0
        syncs = svc.host_syncs - syncs0
        if unified:
            traces = svc._buckets[komis[0]]._dispatch._cache_size()
        else:
            traces = sum(b._dispatch._cache_size()
                         for b in svc._buckets.values())
        return {"sims_per_sec": n * args.sims / wall, "wall_s": wall,
                "host_syncs": syncs, "host_syncs_per_move": syncs / n,
                "dispatch_traces": traces, "moves": n}

    uni = drive(True)
    per = drive(False)
    return {
        "buckets": nb, "komis": komis, "zipf_s": args.zipf_s,
        "requests": n, "sims": args.sims,
        "traffic_share": [float(w) for w in weights],
        "unified": uni, "per_bucket": per,
        "speedup_sims_per_sec": uni["sims_per_sec"] / per["sims_per_sec"],
        "host_syncs_ratio": per["host_syncs"] / max(uni["host_syncs"], 1),
    }


def smoke_verdict(payload: dict, p99_budget_ms: float) -> list:
    """The CI load gate's assertions; returns failure messages."""
    fails = []
    points = payload["points"]
    total = sum(p["requests"] for p in points)
    losses = sum(p["losses"] for p in points)
    if losses:
        fails.append(f"{losses}/{total} requests lost without an "
                     "explicit shed (not 200/503/504)")
    bottom, top = points[0], points[-1]
    if bottom["shed"] != 0:
        fails.append(f"bottom point ({bottom['offered_rps']:.1f} rps) "
                     f"shed {bottom['shed']} requests; must shed none")
    if top["shed"] == 0:
        fails.append(f"top point ({top['offered_rps']:.1f} rps, "
                     f"{top['requests']} reqs) shed nothing; over-"
                     "capacity load must shed explicitly")
    p99 = bottom.get("p99_ms", float("inf"))
    if p99 > p99_budget_ms:
        fails.append(f"bottom-point p99 {p99:.1f}ms over the "
                     f"{p99_budget_ms:.0f}ms budget")
    mb = payload.get("multi_bucket")
    if mb is not None:
        if mb["unified"]["dispatch_traces"] != 1:
            fails.append(
                f"unified scheduler compiled "
                f"{mb['unified']['dispatch_traces']} dispatch traces for "
                f"{mb['buckets']} buckets; the traced-komi contract pins 1")
        if (mb["speedup_sims_per_sec"] < 1.3
                and mb["host_syncs_ratio"] < 1.5):
            fails.append(
                f"unified scheduler won neither axis vs per-bucket: "
                f"{mb['speedup_sims_per_sec']:.2f}x sims/sec (< 1.3) and "
                f"{mb['host_syncs_ratio']:.2f}x fewer host syncs (< 1.5)")
    return fails


async def run(args: argparse.Namespace) -> dict:
    """Stand up (or attach to) a server and sweep the offered loads."""
    rng = np.random.default_rng(args.seed)
    komis = [float(k) for k in args.komis.split(",")]
    n2 = args.board * args.board
    server = None
    if args.url:
        host, port = args.url.split("//")[-1].rsplit(":", 1)
        port = int(port)
    else:
        from repro.serving.go_service import GoService
        from repro.serving.server import GoMoveServer
        service = GoService(board_size=args.board, komi=komis[0],
                            max_sims=args.sims, lanes=args.lanes,
                            slots=args.slots, seed=args.seed,
                            pipeline_depth=args.pipeline_depth,
                            admission_limit=args.admission_limit)
        server = GoMoveServer(service)
        host, port = "127.0.0.1", await server.start()

    from repro.serving.server import http_json

    def client(payload):
        return http_json(host, port, "POST", "/v1/best_move", payload,
                         timeout_s=args.request_timeout_s)

    async def metrics():
        status, body = await http_json(host, port, "GET", "/metrics")
        if status != 200:
            raise RuntimeError(f"/metrics failed with HTTP {status}")
        return body

    try:
        cal = await calibrate(client, komis, args.slots, rng, n2)
        deadline_ms = args.deadline_ms or max(
            250.0, args.deadline_factor * cal["wave_ms"])
        rates = [float(r) for r in args.rates.split(",")]
        points = []
        for x in rates:
            rate = max(0.5, x * cal["capacity_rps"])
            print(f"point {x:.2f}x capacity: {rate:.1f} rps x "
                  f"{args.requests} requests ...", flush=True)
            points.append(await run_point(
                client, metrics, rate, args.requests, komis,
                deadline_ms, rng, n2))
            p = points[-1]
            print(f"  ok {p['ok']}/{p['requests']} shed {p['shed']} "
                  f"lost {p['losses']} p99 "
                  f"{p.get('p99_ms', float('nan')):.1f}ms", flush=True)
        return {
            "schema": SCHEMA,
            "smoke": bool(args.smoke),
            "config": {"board": args.board, "sims": args.sims,
                       "slots": args.slots, "lanes": args.lanes,
                       "komis": komis, "requests": args.requests,
                       "admission_limit": args.admission_limit,
                       "rates_x": rates, "deadline_ms": deadline_ms,
                       "seed": args.seed, "url": args.url or None},
            "calibration": cal,
            "points": points,
        }
    finally:
        if server is not None:
            await server.stop()


def main() -> int:
    """CLI entry point; returns the process exit code."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_load.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI cell: 2 komi buckets, short bursts, "
                         "assert the SLO contract")
    ap.add_argument("--board", type=int, default=BOARD)
    ap.add_argument("--sims", type=int, default=SIMS)
    ap.add_argument("--slots", type=int, default=SLOTS)
    ap.add_argument("--lanes", type=int, default=LANES)
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument("--admission-limit", type=int, default=12,
                    help="per-bucket outstanding-request cap; the top "
                         "offered-load point must overflow it")
    ap.add_argument("--komis", default="6.0,7.5",
                    help="comma list; each value is one service bucket")
    ap.add_argument("--buckets", type=int, default=0,
                    help="run the multi-bucket cell over this many komi "
                         "buckets (0 = skip): unified scheduler vs "
                         "per-bucket pools under skewed Zipf traffic")
    ap.add_argument("--zipf-s", type=float, default=1.2,
                    help="multi-bucket traffic skew exponent")
    ap.add_argument("--mb-requests", type=int, default=48,
                    help="requests in the multi-bucket cell")
    ap.add_argument("--requests", type=int, default=150,
                    help="Poisson arrivals per offered-load point")
    ap.add_argument("--rates", default="0.25,0.75,4.0",
                    help="offered loads as fractions of measured capacity"
                         " (>= 3 points; last one should be > 1)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request SLO (0 = auto from calibration)")
    ap.add_argument("--deadline-factor", type=float, default=30.0,
                    help="auto deadline = factor * calibrated wave time")
    ap.add_argument("--request-timeout-s", type=float, default=120.0)
    ap.add_argument("--p99-budget-ms", type=float, default=5000.0,
                    help="smoke gate on the bottom point's p99 (generous:"
                         " shared CI hosts, not a perf target)")
    ap.add_argument("--url", default="",
                    help="attach to a running server instead of "
                         "starting one in-process")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.smoke:
        args.requests = min(args.requests, 60)

    payload = asyncio.run(run(args))
    if args.buckets > 0:
        print(f"multi-bucket cell: {args.buckets} buckets x "
              f"{args.mb_requests} requests (zipf {args.zipf_s}) ...",
              flush=True)
        mb = run_multi_bucket(args)
        payload["multi_bucket"] = mb
        print(f"  unified {mb['unified']['sims_per_sec']:.0f} sims/s "
              f"({mb['unified']['host_syncs_per_move']:.1f} syncs/move, "
              f"{mb['unified']['dispatch_traces']} trace) vs per-bucket "
              f"{mb['per_bucket']['sims_per_sec']:.0f} sims/s "
              f"({mb['per_bucket']['host_syncs_per_move']:.1f} syncs/move)"
              f" -> {mb['speedup_sims_per_sec']:.2f}x throughput, "
              f"{mb['host_syncs_ratio']:.2f}x fewer syncs", flush=True)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")

    for p in payload["points"]:
        print(f"  {p['offered_rps']:8.1f} rps -> p50 "
              f"{p.get('p50_ms', float('nan')):7.1f}ms  p99 "
              f"{p.get('p99_ms', float('nan')):7.1f}ms  shed_rate "
              f"{p['shed_rate']:.2f}")
    if args.smoke:
        fails = smoke_verdict(payload, args.p99_budget_ms)
        for msg in fails:
            print(f"SMOKE FAIL: {msg}")
        if fails:
            return 1
        print("smoke: SLO contract holds (no losses; sheds only over "
              "capacity; p99 in budget)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
