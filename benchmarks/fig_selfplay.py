"""Figs. 4/5/11: self-play effective speedup — 2n lanes vs n lanes.

Paper: win-rate of the double-resourced player vs thread count; CPU shows
a smooth slightly-decreasing line (search overhead), Phi at 1 s/move shows
a ragged hump that normalises at 10 s/move (problem size).

Here: ``lanes`` is the thread analogue, ``sims_per_move`` the time-per-move
analogue (small budget = the Phi's starved 1 s/move regime; larger = the
10 s/move regime).  Budgets are CPU-scaled (5x5 board, few games) — the
methodology (alternating colours, Heinz 95% CI) is the paper's exactly.
"""
from __future__ import annotations

import time

from benchmarks.common import csv_row
from repro.config import MCTSConfig
from repro.core.selfplay import effective_speedup_point
from repro.go import GoEngine

BOARD = 5
GAMES = 6
MOVE_CAP = 30


def run(lanes_points=(1, 2), budgets=(8, 32)) -> None:
    print("# fig4/5/11: 2n-vs-n self-play win rate (Heinz 95% CI)")
    print(f"# CPU-scaled: {BOARD}x{BOARD}, {GAMES} games/point, "
          f"move cap {MOVE_CAP}")
    eng = GoEngine(BOARD, komi=0.5)
    for sims in budgets:          # sims/move = the 1s vs 10s analogue
        for lanes in lanes_points:
            cfg = MCTSConfig(board_size=BOARD, lanes=lanes,
                             sims_per_move=sims, max_nodes=128)
            t0 = time.time()
            res = effective_speedup_point(eng, cfg, games=GAMES,
                                          seed=lanes * 100 + sims,
                                          max_moves=MOVE_CAP)
            dt = time.time() - t0
            csv_row(f"selfplay_b{sims}_n{lanes}", dt / GAMES,
                    f"winrate={res.rate.rate:.3f};"
                    f"ci=[{res.rate.lo:.3f},{res.rate.hi:.3f}];"
                    f"tree={res.mean_tree_nodes:.0f}")


if __name__ == "__main__":
    run()
