"""Figs. 6-8: the FMA micro-benchmark ``c[j] = a[j]*b[j] + c[j]``.

Paper: double-precision and integer arithmetic throughput (Figs. 6/7) and
memory bandwidth (Fig. 8) vs thread count per affinity mode on the Phi.

Here: the ``fma_stream`` op swept over dtype (f32 / int32 — the TPU VPU
analogues of the Phi's double/int lanes; f64 runs via the CPU oracle) and
arithmetic intensity (``repeats``: 1 = bandwidth-bound Fig. 8 regime, 64 =
compute-bound Figs. 6/7 regime).  The thread-count axis maps to the array
length (more parallel lanes of work).  Wall time is XLA-CPU on this
container; the TPU-projected columns use the kernel's exact FLOP/byte
counts against v5e peaks (the dry-run's roofline constants).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.kernels.fma_stream.ops import fma_stream
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def run() -> None:
    print("# fig6/7/8: fma_stream throughput + bandwidth")
    print("# paper: Phi throughput has per-affinity plateaus; here the")
    print("# analogue sweep is lanes(n) x intensity(repeats) x dtype")
    for dtype, tag in ((jnp.float32, "f32"), (jnp.int32, "i32")):
        for n in (1 << 16, 1 << 20, 1 << 22):
            for repeats in (1, 16, 64):
                key = jax.random.PRNGKey(0)
                if dtype == jnp.int32:
                    a = jnp.ones((n,), dtype)
                    b = jnp.ones((n,), dtype)
                    c = jnp.zeros((n,), dtype)
                else:
                    a = jax.random.normal(key, (n,), dtype)
                    b = a + 1.0
                    c = a * 0.5
                sec, _ = time_fn(fma_stream, a, b, c, repeats=repeats)
                flops = 2.0 * n * repeats
                bytes_moved = 4 * n * 4  # 3 reads + 1 write
                gflops = flops / sec / 1e9
                gbps = bytes_moved / sec / 1e9
                # structural TPU projection from the kernel's exact counts
                tpu_bound = max(flops / PEAK_FLOPS_BF16,
                                bytes_moved / HBM_BW)
                csv_row(f"fma_{tag}_n{n}_r{repeats}", sec,
                        f"{gflops:.2f}GFLOP/s cpu;{gbps:.2f}GB/s cpu;"
                        f"tpu_roofline={tpu_bound * 1e6:.2f}us")


if __name__ == "__main__":
    run()
