"""Roofline table (deliverable (g)): read the dry-run cache and emit the
per-(arch x shape x mesh) terms as CSV.  The dry-run itself is the
measurement; this figure just renders it for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os

from benchmarks.common import csv_row

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def run(path: str = RESULTS) -> None:
    print("# roofline terms per dry-run cell (seconds; dominant term)")
    if not os.path.exists(path):
        print(f"# no dry-run cache at {path}; run: "
              "python -m repro.launch.dryrun --all --both-meshes")
        return
    with open(path) as f:
        results = json.load(f)
    for key in sorted(results):
        rec = results[key]
        if rec.get("status") != "ok":
            csv_row(key.replace("|", "_"), 0.0,
                    f"status={rec.get('status')}")
            continue
        r = rec["roofline"]
        uf = rec.get("useful_fraction")
        csv_row(
            key.replace("|", "_"), r["roofline_step_s"],
            f"compute={r['compute_s']:.4f};memory={r['memory_s']:.4f};"
            f"collective={r['collective_s']:.4f};dom={r['dominant']};"
            + (f"useful={uf:.3f}" if uf is not None else ""))


if __name__ == "__main__":
    run()
