"""Fig. 9: scheduling-policy (affinity) sensitivity.

Paper: FUEGO strength vs KMP_AFFINITY in {compact, balanced, scatter};
*balanced* is most stable, *compact* best at 4 threads/core, and the
asymmetric thread-per-core regions degrade sharply.

Here (DESIGN.md §2): the policies place MCTS work units on mesh devices.
Structural metrics reproduce the paper's mechanism: device utilisation
(compact leaves devices idle = Phi cores idle), imbalance (the paper's
2-vs-3-threads/core regions => max/mean load > 1 — the step-time tax of a
synchronous SPMD machine), plus a strength point per policy at equal lane
count (lane placement changes which lanes share a virtual-loss view).
"""
from __future__ import annotations

import time


from benchmarks.common import csv_row
from repro.config import MCTSConfig
from repro.core import affinity
from repro.core.selfplay import match
from repro.go import GoEngine

DEVICES = 16


def run(lane_sweep=(8, 16, 24, 40, 64), strength_games=4) -> None:
    print("# fig9: affinity policies — structural placement metrics")
    for policy in affinity.POLICIES:
        for lanes in lane_sweep:
            a = affinity.lane_to_device(policy, lanes, DEVICES)
            util = affinity.utilisation(a, DEVICES)
            imb = affinity.imbalance(a, DEVICES)
            # a synchronous step runs at the busiest device's pace
            slowdown = imb
            csv_row(f"affinity_{policy}_n{lanes}", 0.0,
                    f"util={util:.2f};imbalance={imb:.2f};"
                    f"sync_slowdown={slowdown:.2f}")

    print("# fig9b: strength at equal lanes across policies (CPU-scaled)")
    eng = GoEngine(5, komi=0.5)
    base = MCTSConfig(board_size=5, lanes=2, sims_per_move=16,
                      max_nodes=128, affinity="compact")
    for policy in affinity.POLICIES:
        import dataclasses
        cfg = dataclasses.replace(base, affinity=policy)
        t0 = time.time()
        res = match(eng, cfg, base, games=strength_games, seed=7,
                    max_moves=30)
        csv_row(f"affinity_match_{policy}",
                (time.time() - t0) / strength_games,
                f"winrate_vs_compact={res.rate.rate:.3f}")


if __name__ == "__main__":
    run()
