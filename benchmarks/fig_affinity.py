"""Fig. 9: scheduling-policy (affinity) sensitivity.

Paper: FUEGO strength vs KMP_AFFINITY in {compact, balanced, scatter};
*balanced* is most stable, *compact* best at 4 threads/core, and the
asymmetric thread-per-core regions degrade sharply.

Here (DESIGN.md §2): the policies place MCTS work units on mesh devices.
Structural metrics reproduce the paper's mechanism: device utilisation
(compact leaves devices idle = Phi cores idle), imbalance (the paper's
2-vs-3-threads/core regions => max/mean load > 1 — the step-time tax of a
synchronous SPMD machine), plus a strength point per policy at equal lane
count (lane placement changes which lanes share a virtual-loss view).

fig9c lifts the same axis to the *request* level (the ROADMAP's
real-device-sweep prep): when more than one jax device exists (real, or
faked via ``benchmarks.run --devices N``), a sharded SearchService pool
plays a fixed mixed-config tournament workload under every
``core.placement`` policy, reporting measured per-shard occupancy,
utilisation, and imbalance — the paper's Fig. 9 mechanism on live
shards rather than a structural model.  The ``fill_first`` knee row runs
twice — multi-hop (doubling ppermute distance, PR 5) vs the PR 3 one-hop
rebalance — so the O(log shards) backlog drain shows up as an occupancy/
imbalance delta on the deliberately-bad compact placement.
"""
from __future__ import annotations

import dataclasses
import time


from benchmarks.common import csv_row
from repro.config import MCTSConfig
from repro.core import affinity
from repro.core.selfplay import match
from repro.go import GoEngine

DEVICES = 16


def run(lane_sweep=(8, 16, 24, 40, 64), strength_games=4) -> None:
    print("# fig9: affinity policies — structural placement metrics")
    for policy in affinity.POLICIES:
        for lanes in lane_sweep:
            a = affinity.lane_to_device(policy, lanes, DEVICES)
            util = affinity.utilisation(a, DEVICES)
            imb = affinity.imbalance(a, DEVICES)
            # a synchronous step runs at the busiest device's pace
            slowdown = imb
            csv_row(f"affinity_{policy}_n{lanes}", 0.0,
                    f"util={util:.2f};imbalance={imb:.2f};"
                    f"sync_slowdown={slowdown:.2f}")

    print("# fig9b: strength at equal lanes across policies (CPU-scaled)")
    eng = GoEngine(5, komi=0.5)
    base = MCTSConfig(board_size=5, lanes=2, sims_per_move=16,
                      max_nodes=128, affinity="compact")
    for policy in affinity.POLICIES:
        cfg = dataclasses.replace(base, affinity=policy)
        t0 = time.time()
        res = match(eng, cfg, base, games=strength_games, seed=7,
                    max_moves=30)
        csv_row(f"affinity_match_{policy}",
                (time.time() - t0) / strength_games,
                f"winrate_vs_compact={res.rate.rate:.3f}")

    run_request_level()


def run_request_level(games_per_pair: int = 2) -> None:
    """fig9c: measured request->shard placement on a sharded service.

    A mixed-config all-play-all workload (three trace-compatible configs,
    per-slot traced params — one compiled dispatch per policy sweep cell)
    drains through a pool sharded over every visible device, once per
    placement policy.  Occupancy is the dispatcher's own per-shard
    counter; ``imbalance`` (max/mean occupancy) is the paper's
    2-vs-3-threads/core step-time tax at the request level.
    """
    import jax

    from repro.compat import make_service_mesh
    from repro.core import placement
    from repro.core.tournament import Tournament

    n_dev = jax.device_count()
    if n_dev < 2:
        print("# fig9c: request-level placement needs >1 device — skipped "
              "(run via `benchmarks.run --devices 8` to fake shards)")
        return
    print(f"# fig9c: request-level placement over {n_dev} shards "
          "(measured occupancy)")
    eng = GoEngine(5, komi=0.5)
    base = MCTSConfig(board_size=5, lanes=2, sims_per_move=16,
                      max_nodes=128)
    cfgs = [base, dataclasses.replace(base, c_uct=1.6),
            dataclasses.replace(base, virtual_loss=2.0)]
    mesh = make_service_mesh(n_dev)
    sweep = [(policy, True) for policy in placement.POLICIES]
    sweep.append(("fill_first", False))     # the PR 3 one-hop knee row
    for policy, multihop in sweep:
        t = Tournament(eng, cfgs, games_per_pair=games_per_pair,
                       slots=2 * n_dev, max_moves=20, seed=9, mesh=mesh,
                       placement=policy, multihop=multihop)
        t0 = time.time()
        res = t.round_robin()
        wall = time.time() - t0
        occ = t.service.shard_occupancy()
        util = float((occ > 0).mean())
        imb = float(occ.max() / max(occ.mean(), 1e-9))
        hops = "multi" if multihop else "single"
        csv_row(f"affinity_request_{policy}_{hops}hop", wall / res.games,
                f"util={util:.2f};imbalance={imb:.2f};"
                f"occ_mean={occ.mean():.2f}")


if __name__ == "__main__":
    run()
