"""CI perf gate: compare fresh bench artifacts against a baseline.

The bench artifacts became machine-checkable in PR 1/2; this gate is
their first consumer.  CI runs ``bench_service.py`` on the smoke cell
and ``bench_load.py --smoke`` on the serving tier, then:

    python benchmarks/check_regression.py BENCH_service.json \\
        --load BENCH_load.json --eval BENCH_eval.json \\
        --league BENCH_league.json \\
        --baseline benchmarks/baselines/ci_cpu.json

Metrics are **direction-aware**: throughput (``*_sims_per_sec``) fails
when it drops below the band; latency (``load.*_ms``, gated on the
bottom offered-load point, the uncontended-path SLO) and bytes-moved
(``kernels.*_bytes_per_sim``, PR 8 — the fused superstep's hot-loop
traffic) fail when they rise above it — the paper's lesson is that
scheduling regressions show up as throughput collapse *and* latency
growth, and a gate watching only one of them misses half the knee.
Runs on the good side of the band only
warn (faster CI hardware is not a bug) with a hint to refresh the
baseline via ``--update``, which rewrites it from every artifact passed.

Any artifact may be omitted; its metrics report ``skip`` instead of
failing, so the service gate, the load gate, and the eval-lane gate
(``--eval BENCH_eval.json``, PR 7) can run in separate CI jobs against
the one combined baseline.

Only single-device metrics are gated: the sharded sweep's faked devices
share one physical CPU, so its wall clock measures host contention, not
code regressions — those rows ride along as artifacts instead.
"""

from __future__ import annotations

import argparse
import json
import sys


def _overlap_row(d: dict, superstep: int, depth: int) -> dict:
    rows = [
        r
        for r in d["overlap"]["rows"]
        if r["superstep"] == superstep and r["pipeline_depth"] == depth
    ]
    return rows[0]


def _load_point(d: dict, which: int) -> dict:
    """One offered-load point of a BENCH_load.json payload (0 = bottom)."""
    return d["points"][which]


# gated metrics: name -> extractor over the BENCH_service.json payload.
# All are throughputs (higher is better).
METRICS = {
    "reference.arena_sims_per_sec": lambda d: d["reference"]["arena_sims_per_sec"],
    "reference.service_sims_per_sec": lambda d: d["reference"]["service_sims_per_sec"],
    "mixed.sims_per_sec": lambda d: d["mixed"]["sims_per_sec"],
    # v4 overlap cell: pipelined throughput at the reference superstep
    "overlap.pipelined_sims_per_sec": lambda d: _overlap_row(d, 2, 4)["sims_per_sec"],
}

# gated serving-tier metrics over BENCH_load.json: client-observed latency
# at the *bottom* (uncontended) offered-load point (lower is better — the
# gate direction flips relative to the throughput metrics), plus the PR 10
# multi-bucket cell: unified-scheduler throughput under skewed Zipf komi
# traffic (fails downward) and its host syncs per move (a deterministic
# count, not a wall time — fails upward: a scheduling change that pumps
# per bucket again shows up here first).
LOAD_METRICS = {
    "load.p50_ms": lambda d: _load_point(d, 0)["p50_ms"],
    "load.p99_ms": lambda d: _load_point(d, 0)["p99_ms"],
    "load.multi_bucket_sims_per_sec": lambda d: d["multi_bucket"]["unified"]["sims_per_sec"],
    "load.host_syncs_per_move": lambda d: d["multi_bucket"]["unified"]["host_syncs_per_move"],
}


def _sweep_default(d: dict) -> dict:
    """The batch-sweep cell at the default (gated) eval batch size."""
    slots = d["batch_sweep"]["default_slots"]
    return next(r for r in d["batch_sweep"]["sweep"] if r["slots"] == slots)


# gated evaluation-lane metrics over BENCH_eval.json (PR 7): guided
# throughput is a throughput (fails downward); occupancy is taken from
# the oversubscribed default sweep cell (the steady-state number the
# bench hard-gates at >= 0.5 — the reference cell runs games == slots
# and mostly measures the tail drain), so the band here only watches
# for drift.
EVAL_METRICS = {
    "eval.guided_sims_per_sec": lambda d: d["reference"]["guided_sims_per_sec"],
    "eval.occupancy": lambda d: _sweep_default(d)["eval_occupancy"],
}


# gated kernel-lane metrics over BENCH_kernels.json (PR 8): full-search
# throughput for both superstep variants (fail downward), plus the
# hot-loop bytes moved per simulation (fail upward) — the unfused
# number is HLO-measured, the fused one is the Pallas block-transfer
# contract, so a kernel change that adds an operand stream or a
# superstep change that re-streams the tree slabs trips this gate.
KERNEL_METRICS = {
    "kernels.fused_sims_per_sec": lambda d: d["search"]["fused"]["sims_per_sec"],
    "kernels.unfused_sims_per_sec": lambda d: d["search"]["unfused"]["sims_per_sec"],
    "kernels.fused_bytes_per_sim": lambda d: d["hotloop"]["fused"]["bytes_per_sim"],
    "kernels.unfused_bytes_per_sim": lambda d: d["hotloop"]["unfused"]["bytes_per_sim"],
}


# gated league metrics over BENCH_league.json (PR 9): games the adaptive
# scheduler needs to separate the reference cross table at confidence Z.
# Lower is better — a scheduling regression (funding already-resolved
# pairings, or a CI estimate gone loose) shows up as more games burned
# for the same verdict.  The bench itself hard-fails unless adaptive
# beats round-robin and the kill/resume cross table is bit-identical,
# so the band here only watches for drift in the margin.
LEAGUE_METRICS = {
    "league.adaptive_games": lambda d: d["adaptive"]["games_to_separation"],
}


def lower_is_better(name: str) -> bool:
    """Gate direction by metric name: latencies, bytes moved, games
    burned, and host syncs per move all fail upward."""
    return (
        name.endswith("_ms")
        or name.endswith("_bytes_per_sim")
        or name.endswith("_games")
        or name.endswith("_per_move")
    )


def extract(payload: dict, metrics: dict) -> dict:
    """Pull one artifact's gated metric values.

    A metric whose cell is absent from the artifact (an older schema, or
    a run that skipped that leg — e.g. ``bench_load.py`` without
    ``--buckets``) is simply not extracted; the gate then reports it as
    ``skip`` instead of crashing, matching the omitted-artifact rule.
    """
    out = {}
    for name, fn in metrics.items():
        try:
            out[name] = float(fn(payload))
        except (KeyError, IndexError, TypeError):
            pass
    return out


def check(current: dict, baseline: dict, tolerance: float) -> int:
    """Print a verdict table; return the number of regressions."""
    failures = 0
    rows = []
    for name, base in sorted(baseline["metrics"].items()):
        if name not in current:
            rows.append(("skip", name, None, base, "artifact not provided"))
            continue
        cur = current[name]
        ratio = cur / base
        lo, hi = 1.0 - tolerance, 1.0 + tolerance
        if lower_is_better(name):
            bad, good = ratio > hi, ratio < lo
            note_bad = f"{ratio:.2f}x > {hi:.2f}x (lower-is-better metric grew)"
            note_good = "below the band; refresh with --update"
        else:
            bad, good = ratio < lo, ratio > hi
            note_bad = f"{ratio:.2f}x < {lo:.2f}x (throughput fell)"
            note_good = "above the band; refresh with --update"
        if bad:
            rows.append(("FAIL", name, cur, base, note_bad))
            failures += 1
        elif good:
            rows.append(("WARN", name, cur, base, note_good))
        else:
            rows.append(("ok", name, cur, base, f"{ratio:.2f}x"))
    width = max(len(r[1]) for r in rows) if rows else 0
    for verdict, name, cur, base, note in rows:
        cur_s = f"{cur:10.1f}" if cur is not None else " " * 10
        print(f"{verdict:<4} {name:<{width}} {cur_s} vs {base:10.1f}  {note}")
    return failures


def main() -> int:
    """CLI entry point; exit 1 on any gated regression."""
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", nargs="?", default=None, help="BENCH_service.json (optional)")
    ap.add_argument("--load", default=None, help="BENCH_load.json from this run (optional)")
    ap.add_argument(
        "--eval",
        dest="eval_bench",
        default=None,
        help="BENCH_eval.json from this run (optional)",
    )
    ap.add_argument(
        "--kernels",
        default=None,
        help="BENCH_kernels.json from this run (optional)",
    )
    ap.add_argument(
        "--league",
        default=None,
        help="BENCH_league.json from this run (optional)",
    )
    ap.add_argument("--baseline", default="benchmarks/baselines/ci_cpu.json")
    ap.add_argument("--tolerance", type=float, default=None, help="override the baseline's band")
    ap.add_argument("--update", action="store_true", help="rewrite the baseline from this run")
    args = ap.parse_args()
    if (args.bench is None and args.load is None
            and args.eval_bench is None and args.kernels is None
            and args.league is None):
        ap.error("pass BENCH_service.json, --load BENCH_load.json, "
                 "--eval BENCH_eval.json, --kernels BENCH_kernels.json, "
                 "and/or --league BENCH_league.json")

    current = {}
    source_schemas = []
    if args.bench is not None:
        with open(args.bench) as f:
            payload = json.load(f)
        current.update(extract(payload, METRICS))
        source_schemas.append(payload.get("schema"))
    if args.load is not None:
        with open(args.load) as f:
            load_payload = json.load(f)
        current.update(extract(load_payload, LOAD_METRICS))
        source_schemas.append(load_payload.get("schema"))
    if args.eval_bench is not None:
        with open(args.eval_bench) as f:
            eval_payload = json.load(f)
        current.update(extract(eval_payload, EVAL_METRICS))
        source_schemas.append(eval_payload.get("schema"))
    if args.kernels is not None:
        with open(args.kernels) as f:
            kernels_payload = json.load(f)
        current.update(extract(kernels_payload, KERNEL_METRICS))
        source_schemas.append(kernels_payload.get("schema"))
    if args.league is not None:
        with open(args.league) as f:
            league_payload = json.load(f)
        current.update(extract(league_payload, LEAGUE_METRICS))
        source_schemas.append(league_payload.get("schema"))

    if args.update:
        try:
            with open(args.baseline) as f:
                merged = dict(json.load(f).get("metrics", {}))
        except FileNotFoundError:
            merged = {}
        merged.update(current)  # keep metrics this run did not produce
        baseline = {
            "schema": "bench_baseline/v1",
            "source_schema": ", ".join(s for s in source_schemas if s),
            "tolerance": args.tolerance if args.tolerance is not None else 0.3,
            "metrics": merged,
        }
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.tolerance is not None:
        tolerance = args.tolerance
    else:
        tolerance = float(baseline["tolerance"])
    failures = check(current, baseline, tolerance)
    if failures:
        print(f"{failures} metric(s) regressed beyond the +-{tolerance:.0%} band")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
