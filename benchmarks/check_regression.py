"""CI perf gate: compare a fresh BENCH_service.json against a baseline.

The bench artifacts became machine-checkable in PR 1/2; this gate is their
first consumer.  CI runs ``bench_service.py`` on the smoke cell, then:

    python benchmarks/check_regression.py BENCH_service.json \\
        --baseline benchmarks/baselines/ci_cpu.json

A metric *fails* when it drops more than ``tolerance`` (default from the
baseline file, +-30%) below the checked-in value — the paper's lesson is
that scheduling regressions show up as throughput collapse, so the gate
watches sims/sec.  Runs *above* the band only warn (faster CI hardware is
not a bug) with a hint to refresh the baseline via ``--update``.

Only single-device metrics are gated: the sharded sweep's faked devices
share one physical CPU, so its wall clock measures host contention, not
code regressions — those rows ride along as artifacts instead.
"""
from __future__ import annotations

import argparse
import json
import sys

def _overlap_row(d: dict, superstep: int, depth: int) -> dict:
    rows = [r for r in d["overlap"]["rows"]
            if r["superstep"] == superstep and r["pipeline_depth"] == depth]
    return rows[0]


# gated metrics: name -> extractor over the BENCH_service.json payload
METRICS = {
    "reference.arena_sims_per_sec": lambda d: d["reference"]["arena_sims_per_sec"],
    "reference.service_sims_per_sec": lambda d: d["reference"]["service_sims_per_sec"],
    "mixed.sims_per_sec": lambda d: d["mixed"]["sims_per_sec"],
    # v4 overlap cell: pipelined throughput at the reference superstep
    "overlap.pipelined_sims_per_sec": lambda d: _overlap_row(d, 2, 4)["sims_per_sec"],
}


def extract(payload: dict) -> dict:
    return {name: float(fn(payload)) for name, fn in METRICS.items()}


def check(current: dict, baseline: dict, tolerance: float) -> int:
    """Print a verdict per metric; return the number of regressions."""
    failures = 0
    for name, base in baseline["metrics"].items():
        if name not in current:
            print(f"FAIL {name}: metric missing from current run")
            failures += 1
            continue
        cur = current[name]
        ratio = cur / base
        lo, hi = 1.0 - tolerance, 1.0 + tolerance
        if ratio < lo:
            print(f"FAIL {name}: {cur:.0f} vs baseline {base:.0f} ({ratio:.2f}x < {lo:.2f}x)")
            failures += 1
        elif ratio > hi:
            print(f"WARN {name}: {cur:.0f} vs baseline {base:.0f} ({ratio:.2f}x > {hi:.2f}x)")
            print("     faster than the baseline band; refresh it with --update")
        else:
            print(f"ok   {name}: {cur:.0f} vs baseline {base:.0f} ({ratio:.2f}x)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", help="BENCH_service.json from this run")
    ap.add_argument("--baseline", default="benchmarks/baselines/ci_cpu.json")
    ap.add_argument("--tolerance", type=float, default=None, help="override the baseline's band")
    ap.add_argument("--update", action="store_true", help="rewrite the baseline from this run")
    args = ap.parse_args()

    with open(args.bench) as f:
        payload = json.load(f)
    current = extract(payload)

    if args.update:
        baseline = {
            "schema": "bench_baseline/v1",
            "source_schema": payload.get("schema"),
            "tolerance": args.tolerance if args.tolerance is not None else 0.3,
            "metrics": current,
        }
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.baseline}")
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    tolerance = args.tolerance if args.tolerance is not None else float(baseline["tolerance"])
    failures = check(current, baseline, tolerance)
    if failures:
        print(f"{failures} metric(s) regressed beyond -{tolerance:.0%}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
