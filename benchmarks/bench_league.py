"""League scheduling benchmark -> BENCH_league.json.

Measures what adaptive (Elo-CI-driven) scheduling buys over round-robin
on the tiny 5x5 reference league: three trace-compatible configs whose
strength ordering is real (playout budgets 8/4/2), both arms run to the
same stop test (every pairing separated at ``Z`` standard errors of the
rating difference, or ``BUDGET`` games), and the reported metric is
**games to separation** — the adaptive arm stops funding pairings the
moment their CIs detach, so it should resolve the table in strictly
fewer games (``league.adaptive_games`` gates lower-is-better in
``check_regression.py --league``).

The payload also carries a **kill/resume identity** cell: the adaptive
arm is re-run with a preemption trigger after wave 2, resumed from the
wave-boundary snapshot, and the final cross table (win matrix, game
counts, colour ledger) must be bit-identical to the uninterrupted arm —
the league's crash/resume contract, exercised on every CI run.

    PYTHONPATH=src python benchmarks/bench_league.py [--out BENCH_league.json]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

if __package__ in (None, ""):                    # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import dataclasses

import numpy as np

from benchmarks.common import csv_row
from repro.config import MCTSConfig
from repro.core.league import League
from repro.go import GoEngine

BOARD = 5
KOMI = 0.5
MOVE_CAP = 30
BASE = MCTSConfig(board_size=BOARD, komi=KOMI, lanes=2, sims_per_move=8,
                  max_nodes=64)
CONFIGS = (BASE,
           dataclasses.replace(BASE, sims_per_move=4, c_uct=0.8),
           dataclasses.replace(BASE, sims_per_move=2, c_uct=2.0))
Z = 1.0
BUDGET = 60
GAMES_PER_WAVE = 2
SEED = 3
INTERRUPT_WAVE = 2
SCHEMA = "bench_league/v1"


def _league(engine: GoEngine, schedule: str, **kw) -> League:
    return League(engine, CONFIGS, z=Z, budget=BUDGET,
                  games_per_wave=GAMES_PER_WAVE, schedule=schedule,
                  seed=SEED, max_moves=MOVE_CAP, **kw)


def run_arm(engine: GoEngine, schedule: str) -> dict:
    """One scheduling arm to separation (or budget); timed."""
    lg = _league(engine, schedule)
    t0 = time.perf_counter()
    res = lg.run()
    wall = time.perf_counter() - t0
    return {
        "schedule": schedule, "games_to_separation": res.games_played,
        "waves": res.waves, "converged": res.converged, "wall_s": wall,
        "per_wave_games": [r["games"] for r in lg.history],
        "result": res,
    }


def run_resume(engine: GoEngine, reference) -> dict:
    """Kill after INTERRUPT_WAVE waves, resume, compare cross tables."""
    state_dir = tempfile.mkdtemp(prefix="bench_league_")
    try:
        lg = _league(engine, "adaptive", state_dir=state_dir)
        lg.on_wave = lambda rec: (rec["wave"] >= INTERRUPT_WAVE
                                  and lg.preemption.trigger())
        part = lg.run()
        if not part.stopped or part.waves != INTERRUPT_WAVE:
            raise RuntimeError(
                f"preemption did not stop the league at wave "
                f"{INTERRUPT_WAVE} (waves={part.waves})")
        resumed = _league(engine, "adaptive", state_dir=state_dir,
                          resume=True).run()
        identical = (
            np.array_equal(resumed.win_matrix, reference.win_matrix)
            and np.array_equal(resumed.games, reference.games)
            and np.array_equal(resumed.blacks, reference.blacks))
        if not identical:
            raise RuntimeError(
                "resumed league diverged from the uninterrupted run:\n"
                f"win {resumed.win_matrix} vs {reference.win_matrix}\n"
                f"games {resumed.games} vs {reference.games}\n"
                f"blacks {resumed.blacks} vs {reference.blacks}")
        return {"interrupt_wave": INTERRUPT_WAVE,
                "resumed_waves": resumed.waves,
                "resumed_games": resumed.games_played,
                "identical": identical}
    finally:
        shutil.rmtree(state_dir, ignore_errors=True)


def _payload(adaptive: dict, rr: dict, resume: dict) -> dict:
    res = adaptive.pop("result")
    rr.pop("result")
    return {
        "schema": SCHEMA, "board": BOARD, "komi": KOMI,
        "move_cap": MOVE_CAP, "z": Z, "budget": BUDGET,
        "games_per_wave": GAMES_PER_WAVE, "seed": SEED,
        "configs": [{"sims_per_move": c.sims_per_move, "c_uct": c.c_uct}
                    for c in CONFIGS],
        "adaptive": adaptive, "round_robin": rr, "resume": resume,
        "elo": [round(e, 1) for e in res.elo.elo],
        "ci": [round(c, 1) for c in res.elo.ci],
    }


def bench() -> dict:
    """Both arms + the resume identity cell; asserts adaptive wins."""
    engine = GoEngine(BOARD, KOMI)
    adaptive = run_arm(engine, "adaptive")
    rr = run_arm(engine, "round_robin")
    if not adaptive["converged"]:
        raise RuntimeError(
            f"adaptive arm failed to separate within {BUDGET} games")
    if adaptive["games_to_separation"] >= rr["games_to_separation"]:
        raise RuntimeError(
            f"adaptive scheduling ({adaptive['games_to_separation']} "
            f"games) did not beat round-robin "
            f"({rr['games_to_separation']} games)")
    resume = run_resume(engine, adaptive["result"])
    return _payload(adaptive, rr, resume)


def run() -> None:
    """benchmarks.run entry: both arms + resume cell, default output."""
    payload = bench()
    csv_row("league_adaptive", payload["adaptive"]["wall_s"],
            f"games={payload['adaptive']['games_to_separation']};"
            f"rr={payload['round_robin']['games_to_separation']};"
            f"resume_ok={payload['resume']['identical']}")
    with open("BENCH_league.json", "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)


def main() -> None:
    """CLI entry point: arms + resume cell, printed + JSON."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_league.json")
    args = ap.parse_args()

    print(f"# league scheduling ({BOARD}x{BOARD}, z={Z}, "
          f"budget {BUDGET}, {len(CONFIGS)} configs)")
    payload = bench()
    a, r = payload["adaptive"], payload["round_robin"]
    print(f"adaptive:    {a['games_to_separation']:3d} games over "
          f"{a['waves']} waves (converged={a['converged']}, "
          f"{a['wall_s']:.1f}s)")
    print(f"round_robin: {r['games_to_separation']:3d} games over "
          f"{r['waves']} waves (converged={r['converged']}, "
          f"{r['wall_s']:.1f}s)")
    print(f"resume: interrupted at wave "
          f"{payload['resume']['interrupt_wave']}, cross table identical="
          f"{payload['resume']['identical']}")
    csv_row("league_adaptive", a["wall_s"],
            f"games={a['games_to_separation']};"
            f"rr={r['games_to_separation']};"
            f"resume_ok={payload['resume']['identical']}")
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
