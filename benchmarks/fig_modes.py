"""Parallelisation-mode comparison: tree vs root vs leaf (related work).

The paper's related-work section ranks the three classical MCTS
parallelisations (Chaslot et al.): *tree* parallelisation (FUEGO's choice,
shared tree + virtual loss) > *root* (independent trees, vote merge) >
*leaf* (one selection, many playouts) at equal playout budget, because
leaf wastes budget on one path and root never shares deep discoveries.

Here: equal-total-playout matches of each mode against the same
single-lane sequential baseline (CPU-scaled), plus the structural
signature of each mode (tree growth per playout).
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import csv_row
from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core.selfplay import match
from repro.go import GoEngine

BOARD = 5
GAMES = 6
BUDGET = 32   # total playouts/move for every contestant


def run() -> None:
    print("# modes: tree vs root vs leaf at equal playout budget")
    eng = GoEngine(BOARD, komi=0.5)
    base = MCTSConfig(board_size=BOARD, lanes=1, sims_per_move=BUDGET,
                      max_nodes=256, parallelism="tree")
    contenders = {
        "tree4": dataclasses.replace(base, lanes=4),
        "root4": dataclasses.replace(base, parallelism="root",
                                     root_trees=4, lanes=1),
        "leaf4": dataclasses.replace(base, parallelism="leaf",
                                     lanes=1, leaf_playouts=4),
    }
    # structural: nodes grown per playout budget
    for name, cfg in contenders.items():
        m = MCTS(eng, cfg)
        res = jax.jit(m.search_batch)(
            jax.tree.map(lambda x: x[None], eng.init_state()),
            jax.random.PRNGKey(0)[None])
        csv_row(f"mode_tree_growth_{name}", 0.0,
                f"nodes={int(res.tree.size[0])};iters={m.iterations}")

    # strength vs the same sequential baseline
    for name, cfg in contenders.items():
        t0 = time.time()
        res = match(eng, cfg, base, games=GAMES, seed=11, max_moves=30)
        csv_row(f"mode_match_{name}", (time.time() - t0) / GAMES,
                f"winrate_vs_seq={res.rate.rate:.3f};"
                f"ci=[{res.rate.lo:.2f},{res.rate.hi:.2f}]")


if __name__ == "__main__":
    run()
