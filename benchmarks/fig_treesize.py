"""Fig. 12: search-tree size when making a move vs time budget.

Paper: node count of FUEGO's tree at the second move — 10 s/move on the
Phi builds a tree the size of 1 s/move on the CPU; tree size, not seconds,
is the operative variable.  Here: nodes vs ``sims_per_move`` and lanes.
"""
from __future__ import annotations

import jax

from benchmarks.common import csv_row, time_fn
from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.go import GoEngine

BOARD = 5


def run(budgets=(8, 16, 32, 64), lanes_points=(1, 4)) -> None:
    print("# fig12: tree size vs playout budget (the 1s-vs-10s variable)")
    eng = GoEngine(BOARD, komi=0.5)
    st1 = eng.play(eng.init_state(), 12)   # measure at the second move
    for lanes in lanes_points:
        for sims in budgets:
            cfg = MCTSConfig(board_size=BOARD, lanes=lanes,
                             sims_per_move=sims, max_nodes=512)
            m = MCTS(eng, cfg)
            root = jax.tree.map(lambda x: x[None], st1)
            fn = jax.jit(
                lambda k: m.search_batch(root, k[None]).tree.size[0])
            sec, size = time_fn(fn, jax.random.PRNGKey(1), warmup=1,
                                iters=2)
            csv_row(f"treesize_n{lanes}_b{sims}", sec,
                    f"nodes={int(size)}")


if __name__ == "__main__":
    run()
