"""SearchService dispatcher benchmark -> BENCH_service.json.

Measures the unified dispatcher (core/service.py) against the PR 1 arena
path (host-queue refill, one host sync per step) on the 5x5 reference
config, a mixed workload (arena games + serve queries sharing one slot
pool), a ``shards x placement`` sweep of the mesh-sharded pool (the same
slot count split over 1..N devices; ``--devices`` fakes them on CPU),
a **mixed-config sweep** (v3): N distinct ``(c_uct, virtual_loss)``
tournament configurations multiplexed through one pool as per-slot
traced params, pinned to exactly one compiled dispatch (the compile
count is asserted) and compared against the PR 2 baseline of one
statically-configured pool per pairing, and — schema
``bench_service/v4`` — an **overlap cell**: the streaming dispatch
pipeline (core/streaming.py) against the synchronous path at supersteps
1/2/4, reporting host-blocked time per move, realised in-flight depth,
and sims/sec (the Phi offload studies' host<->device transfer-overlap
lever made machine-checkable: a deeper pipeline must spend strictly
less time blocked on the device per move).  The device-side refill
moves admission and result collection into the jitted dispatch, so the
host only flushes submissions and polls the result ring once per
``superstep`` moves — ``host_syncs_per_move`` makes that reduction
machine-checkable (the paper's scheduling thesis: the loop shape, not
the lane count, sets throughput; the sweeps are its slot-placement and
config-residency analogues).  The sharded sweep's ``fill_first`` knee
row now runs under both the multi-hop (doubling) and the PR 3 one-hop
rebalance so the O(log shards) drain shows up as a measured delta.

Both refill paths are warmed (compile excluded) and play bit-identical
games; "useful" sims are the mover's, as in benchmarks/bench_arena.py.

    PYTHONPATH=src python benchmarks/bench_service.py \
        [--out BENCH_service.json] [--devices 8]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                    # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

# --devices N / --devices=N fakes N CPU devices for the sharded sweep; the
# flag must land before jax initialises its backend (launch/mesh.py rule)
_dev = None
for _i, _arg in enumerate(sys.argv):
    if _arg == "--devices" and _i + 1 < len(sys.argv):
        _dev = sys.argv[_i + 1]
    elif _arg.startswith("--devices="):
        _dev = _arg.split("=", 1)[1]
if _dev is not None and int(_dev) > 1:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            f"{_flags} --xla_force_host_platform_device_count={_dev}"
            .strip())

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.compat import make_service_mesh
from repro.config import MCTSConfig
from repro.core.arena import Arena
from repro.core.mcts import MCTS
from repro.core.placement import POLICIES
from repro.core.selfplay import double_resources
from repro.core.service import LANE_SERVE, SearchService
from repro.go import GoEngine

BOARD = 5
KOMI = 0.5
MOVE_CAP = 30
MAX_NODES = 128
SERVE_SIMS = 16
SCHEMA = "bench_service/v4"


def _useful_sims(total_moves: float, sims_a: int, sims_b: int) -> float:
    """Movers alternate, so each path charges the same per-move average."""
    return total_moves * (sims_a + sims_b) / 2.0


def time_refill_path(engine: GoEngine, cfg_a: MCTSConfig, cfg_b: MCTSConfig,
                     games: int, seed: int, refill: str,
                     slots: int = 0, repeats: int = 3) -> dict:
    """Arena throughput under one refill mode (host = the PR 1 path).

    The same seeded run is timed ``repeats`` times (bit-identical games,
    warm jit) and the *minimum* wall clock is reported — the standard
    guard against scheduler noise on a shared host, which at this scale
    is ~+-10% per single run.
    """
    player_a = MCTS(engine, cfg_a)
    player_b = MCTS(engine, cfg_b)
    slots = slots or games
    slots = max(2, slots + (slots % 2))
    arena = Arena(engine, player_a, player_b, slots=slots,
                  max_moves=MOVE_CAP, refill=refill)
    arena.play_games(games, seed=seed + 1000)    # warm / compile
    wall = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        recs = arena.play_games(games, seed=seed)
        wall = min(wall, time.perf_counter() - t0)
    moves = float(sum(r.moves for r in recs))
    return {"wall_s": wall, "moves": moves, "games": len(recs),
            "sims": _useful_sims(moves, cfg_a.sims_per_move,
                                 cfg_b.sims_per_move),
            "host_syncs": arena.host_syncs,
            "host_syncs_per_move": arena.host_syncs / moves}


def time_mixed_workload(engine: GoEngine, cfg_a: MCTSConfig,
                        cfg_b: MCTSConfig, games: int, queries: int,
                        seed: int, slots: int = 0) -> dict:
    """Arena slots + serve queries through one pool (the tentpole mix)."""
    player_a = MCTS(engine, cfg_a)
    player_b = MCTS(engine, cfg_b)
    slots = slots or games
    slots = max(2, slots + (slots % 2))
    svc = SearchService(engine, player_a, player_b, slots=slots,
                        max_moves=MOVE_CAP)

    # queried positions: a few random moves into a game
    rng = np.random.default_rng(seed)
    boards = []
    for _ in range(queries):
        st = engine.init_state()
        for _ in range(4):
            legal = np.asarray(engine.jit_legal(st))[: engine.n2]
            st = engine.jit_play(
                st, jax.numpy.int32(rng.choice(np.where(legal)[0])))
        boards.append(st)

    def run(s):
        svc.reset(seed=s, colour_cap=(games + 1) // 2,
                  game_capacity=games, serve_capacity=queries)
        for _ in range(games):
            svc.submit_game()
        for q in range(queries):
            svc.submit_serve(boards[q], sims=SERVE_SIMS)
        return svc.drain()

    run(seed + 1000)                             # warm / compile
    wall = float("inf")
    for _ in range(3):                           # min-of-3 vs host noise
        t0 = time.perf_counter()
        recs = run(seed)
        wall = min(wall, time.perf_counter() - t0)
    game_moves = float(sum(r.moves for r in recs if r.lane != LANE_SERVE))
    n_serve = sum(1 for r in recs if r.lane == LANE_SERVE)
    sims = (_useful_sims(game_moves, cfg_a.sims_per_move,
                         cfg_b.sims_per_move) + n_serve * SERVE_SIMS)
    moves = game_moves + n_serve
    return {"shards": 1, "wall_s": wall, "games": games,
            "serve_queries": n_serve,
            "serve_sims": SERVE_SIMS, "moves": moves, "sims": sims,
            "sims_per_sec": sims / wall, "moves_per_sec": moves / wall,
            "host_syncs": svc.host_syncs,
            "host_syncs_per_move": svc.host_syncs / moves}


def time_sharded_cell(svc: SearchService, games: int, seed: int,
                      repeats: int = 2) -> dict:
    """One (shards, placement) sweep cell through a prepared service."""

    def run(s):
        svc.reset(seed=s, colour_cap=(games + 1) // 2,
                  game_capacity=games, ring_capacity=games + svc.slots)
        for _ in range(games):
            svc.submit_game()
        return svc.drain()

    run(seed + 1000)                             # warm / compile
    wall = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        recs = run(seed)
        wall = min(wall, time.perf_counter() - t0)
    moves = float(sum(r.moves for r in recs))
    sims = _useful_sims(moves, svc.player_a.cfg.sims_per_move,
                        svc.player_b.cfg.sims_per_move)
    return {
        "shards": svc.n_shard,
        "placement": svc.placement if svc.mesh is not None else None,
        "games": len(recs), "slots": svc.slots, "wall_s": wall,
        "moves": moves, "sims": sims,
        "sims_per_sec": sims / wall, "moves_per_sec": moves / wall,
        "host_syncs_per_move": svc.host_syncs / moves,
        "shard_occupancy": [round(float(o), 4)
                            for o in svc.shard_occupancy()],
    }


def time_overlap_cell(svc, boards, games: int, seed: int, depth: int,
                      repeats: int = 5) -> dict:
    """One (superstep, pipeline_depth) cell of the overlap sweep.

    The workload *streams*: games beyond the first slot-full and every
    serve query are submitted from inside the loop as earlier requests
    complete — so each superstep the host packs fresh request chunks,
    flushes them, and unpacks results.  That host-side I/O is exactly
    what ``pipeline_depth > 1`` overlaps with device compute (at depth 1
    it all happens while the device idles between supersteps).

    ``pipeline_depth`` is a host-side knob — the same service (and the
    same compiled dispatch) runs every depth; only when the host reads
    the device changes.  Wall clock and host-blocked time are each
    min-of-``repeats`` against scheduler noise.
    """
    from repro.core.streaming import DispatchPipeline

    svc.pipeline_depth = depth
    queries = len(boards)

    def run(s):
        svc.reset(seed=s, colour_cap=2 ** 30,
                  game_capacity=max(2, games),
                  serve_capacity=max(2, queries))
        pipe = DispatchPipeline(svc)
        n_games = 0
        while n_games < min(games, svc.slots):   # seed the pool
            svc.submit_game()
            n_games += 1
        n_serve = 0
        recs = []
        while len(recs) < games + queries:
            # trickle the remaining workload in: the host-write half of
            # the double buffer, overlapped by the in-flight supersteps
            for _ in range(2):
                if n_serve < queries:
                    svc.submit_serve(boards[n_serve], sims=SERVE_SIMS)
                    n_serve += 1
            pipe.pump()
            done = pipe.reconcile(block=True)
            for r in done:
                if r.lane != LANE_SERVE and n_games < games:
                    svc.submit_game()            # refill the finished slot
                    n_games += 1
            recs.extend(done)
        while pipe.in_flight_supersteps:         # drain the window so the
            pipe.reconcile(block=True)           # next repeat starts clean
        return recs, pipe.stats()

    run(seed + 1000)                             # warm / compile
    wall = blocked = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        recs, stats = run(seed)
        wall = min(wall, time.perf_counter() - t0)
        blocked = min(blocked, svc.host_blocked_s)
    game_moves = float(sum(r.moves for r in recs if r.lane != LANE_SERVE))
    n_serve = sum(1 for r in recs if r.lane == LANE_SERVE)
    moves = game_moves + n_serve
    sims = (_useful_sims(game_moves, svc.player_a.cfg.sims_per_move,
                         svc.player_b.cfg.sims_per_move)
            + n_serve * SERVE_SIMS)
    return {
        "superstep": svc.superstep, "pipeline_depth": depth,
        "slots": svc.slots, "games": games, "serve_queries": n_serve,
        "wall_s": wall, "moves": moves, "sims": sims,
        "sims_per_sec": sims / wall,
        "host_blocked_s": blocked,
        "host_blocked_per_move": blocked / moves,
        "host_syncs_per_move": svc.host_syncs / moves,
        "in_flight_depth": stats["max_in_flight"],
        "steps_issued": stats["steps_issued"],
    }


def run_overlap(games: int, queries: int, seed: int,
                depths=(1, 4)) -> dict:
    """The v4 overlap cell: streaming pipeline vs synchronous dispatch.

    A mixed workload (the reference 2n-vs-n games plus serve queries, so
    every superstep produces results for the host to unpack) drains at
    supersteps 1/2/4 under each pipeline depth.  ``pipeline_depth``
    never retraces (asserted); the pipelined rows must spend strictly
    less host-blocked time per move than the synchronous ones — the
    overlap is exactly the host-side packing/unpacking/placement work
    that now runs while the device computes.
    """
    engine = GoEngine(BOARD, komi=KOMI)
    base = MCTSConfig(board_size=BOARD, lanes=2, sims_per_move=16,
                      max_nodes=MAX_NODES)
    cfg_a, cfg_b = double_resources(base), base
    player_a, player_b = MCTS(engine, cfg_a), MCTS(engine, cfg_b)

    rng = np.random.default_rng(seed)
    boards = []
    for _ in range(queries):
        st = engine.init_state()
        for _ in range(4):
            legal = np.asarray(engine.jit_legal(st))[: engine.n2]
            st = engine.jit_play(
                st, jax.numpy.int32(rng.choice(np.where(legal)[0])))
        boards.append(st)

    # fewer slots than games: the tail of the workload streams in as
    # slots free up, so every superstep has host packing to overlap
    slots = max(2, 2 * (games // 3))
    rows, summary = [], {}
    for superstep in (1, 2, 4):
        svc = SearchService(engine, player_a, player_b, slots=slots,
                            max_moves=MOVE_CAP, superstep=superstep)
        cells = {d: time_overlap_cell(svc, boards, games, seed, d)
                 for d in depths}
        if svc._dispatch._cache_size() != 1:
            raise RuntimeError(
                f"pipeline_depth retraced the dispatch "
                f"({svc._dispatch._cache_size()} compiles) — it must be "
                "a host-side knob")
        rows.extend(cells[d] for d in depths)
        deep = depths[-1]
        summary[f"superstep{superstep}"] = {
            "host_blocked_per_move_sync":
                cells[1]["host_blocked_per_move"],
            "host_blocked_per_move_pipelined":
                cells[deep]["host_blocked_per_move"],
            "host_blocked_reduction":
                cells[1]["host_blocked_per_move"]
                / max(cells[deep]["host_blocked_per_move"], 1e-12),
            "overlap_win": bool(cells[deep]["host_blocked_per_move"]
                                < cells[1]["host_blocked_per_move"]),
            "speedup_vs_sync": (cells[deep]["sims_per_sec"]
                                / cells[1]["sims_per_sec"]),
        }
    return {"games": games, "queries": queries, "serve_sims": SERVE_SIMS,
            "depths": list(depths), "rows": rows, "summary": summary}


def run_sharded_sweep(games: int, seed: int, devices: int) -> dict:
    """shards x placement over a fixed total slot count (weak shards,
    constant work): splitting the same pool over more devices isolates
    the dispatch-partitioning cost — the paper's thread-placement axis.

    Placement rows share one compiled service (placement is host-side
    routing, so changing it must not retrace — reusing the service also
    proves that).  ``speedup_vs_1shard`` is each row's sims/sec over the
    one-shard (mesh-free) dispatcher on the identical workload.
    """
    engine = GoEngine(BOARD, komi=KOMI)
    base = MCTSConfig(board_size=BOARD, lanes=2, sims_per_move=16,
                      max_nodes=MAX_NODES)
    player_a, player_b = (MCTS(engine, double_resources(base)),
                          MCTS(engine, base))
    slots = 2 * devices
    # each shard needs an even slot share: slots % (2s) == 0 <=> devices % s
    shard_counts = [s for s in (1, 2, 4, 8, 16)
                    if s <= devices and devices % s == 0]
    rows = []
    for shards in shard_counts:
        mesh = None if shards == 1 else make_service_mesh(shards)
        svc = SearchService(engine, player_a, player_b, slots,
                            max_moves=MOVE_CAP, mesh=mesh)
        pols = POLICIES if shards == shard_counts[-1] and shards > 1 \
            else ("round_robin",)
        for pol in pols:
            svc.placement = pol            # re-read by reset(); no retrace
            row = time_sharded_cell(svc, games, seed)
            row["rebalance_hops"] = "multi" if shards > 1 else None
            rows.append(row)
        if shards == shard_counts[-1] and shards > 1:
            # the PR 3 one-hop ring on the knee policy: the multi-hop
            # schedule's O(log shards) backlog drain, measured
            single = SearchService(engine, player_a, player_b, slots,
                                   max_moves=MOVE_CAP, mesh=mesh,
                                   multihop=False,
                                   placement="fill_first")
            row = time_sharded_cell(single, games, seed)
            row["rebalance_hops"] = "single"
            rows.append(row)
    base_rate = rows[0]["sims_per_sec"]
    for row in rows:
        row["speedup_vs_1shard"] = row["sims_per_sec"] / base_rate
    return {"devices": devices, "slots": slots, "sweep": rows}


def run_multiconfig(games_per_pair: int, seed: int) -> dict:
    """N configs, 1 trace: the per-slot traced (c_uct, virtual_loss) cell.

    Plays every pairing of three configs twice over: once multiplexed
    through **one** pool (per-slot traced params; the compile count of
    the dispatch is asserted to be exactly 1), once through the PR 2
    baseline of a statically-configured pool per pairing (one compile
    each, sized exactly like the legacy ``Tournament`` fallback:
    ``min(games_per_pair, 8)`` slots).  Both paths are warmed, min-of-2
    timed, and play the same number of games at the same budget.
    ``setup_s`` is each path's first (cold) run — the per-pair baseline
    pays one dispatch compile *per pairing* where the multiplexed pool
    pays exactly one, which is the retrace cost the traced params
    remove; the warm ``speedup`` isolates steady-state throughput (on
    one CPU expect ~parity — cross-pairing concurrency only pays on
    parallel hardware).
    """
    import dataclasses
    import itertools

    engine = GoEngine(BOARD, komi=KOMI)
    base = MCTSConfig(board_size=BOARD, lanes=2, sims_per_move=16,
                      max_nodes=MAX_NODES)
    cfgs = [base,
            dataclasses.replace(base, c_uct=1.6),
            dataclasses.replace(base, virtual_loss=2.0)]
    pair_list = list(itertools.combinations(range(len(cfgs)), 2))
    g = games_per_pair
    slots = max(2, min(g * len(pair_list), 8))   # the one-pool path
    pair_slots = max(2, min(g, 8))               # legacy per-pair sizing
    total = g * len(pair_list)

    # --- multiplexed: every pairing through one pool, one trace
    player = MCTS(engine, base)
    svc = SearchService(engine, player, player, slots, max_moves=MOVE_CAP)

    def run_mixed(s):
        svc.reset(seed=s, colour_cap=(total + 1) // 2, game_capacity=total,
                  ring_capacity=total + slots)
        for wave in range(g):
            for (i, j) in pair_list:
                a, b = (i, j) if wave % 2 == 0 else (j, i)
                svc.submit_game(
                    c_uct=(cfgs[a].c_uct, cfgs[b].c_uct),
                    virtual_loss=(cfgs[a].virtual_loss,
                                  cfgs[b].virtual_loss))
        return svc.drain()

    t0 = time.perf_counter()
    run_mixed(seed + 1000)                       # warm / compile
    mixed_setup = time.perf_counter() - t0
    mixed_wall = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        recs = run_mixed(seed)
        mixed_wall = min(mixed_wall, time.perf_counter() - t0)
    compiles = svc._dispatch._cache_size()
    if compiles != 1:
        raise RuntimeError(
            f"mixed-config dispatch compiled {compiles}x; the per-slot "
            "traced (c_uct, virtual_loss) contract requires exactly 1")
    mixed_moves = float(sum(r.moves for r in recs))
    mixed_sims = _useful_sims(mixed_moves, base.sims_per_move,
                              base.sims_per_move)

    # --- PR 2 baseline: one statically-configured pool per pairing
    per_wall = 0.0
    per_setup = 0.0
    per_moves = 0.0
    for (i, j) in pair_list:
        pi, pj = MCTS(engine, cfgs[i]), MCTS(engine, cfgs[j])
        psvc = SearchService(engine, pi, pj, pair_slots,
                             max_moves=MOVE_CAP)

        def run_pair(s):
            psvc.reset(seed=s, colour_cap=(g + 1) // 2, game_capacity=g,
                       ring_capacity=g + pair_slots)
            for _ in range(g):
                psvc.submit_game()
            return psvc.drain()

        t0 = time.perf_counter()
        run_pair(seed + 1000)                    # warm / compile (per pair)
        per_setup += time.perf_counter() - t0
        wall = float("inf")
        for _ in range(2):
            t0 = time.perf_counter()
            precs = run_pair(seed)
            wall = min(wall, time.perf_counter() - t0)
        per_wall += wall
        per_moves += float(sum(r.moves for r in precs))
    per_sims = _useful_sims(per_moves, base.sims_per_move,
                            base.sims_per_move)

    return {
        "configs": len(cfgs), "pairings": len(pair_list),
        "games_per_pair": g, "games": total, "slots": slots,
        "pair_slots": pair_slots,
        "sims_per_move": base.sims_per_move,
        "dispatch_compiles": compiles,
        "mixed_setup_s": mixed_setup,
        "mixed_wall_s": mixed_wall,
        "mixed_sims_per_sec": mixed_sims / mixed_wall,
        "per_pair_setup_s": per_setup,
        "per_pair_wall_s": per_wall,
        "per_pair_sims_per_sec": per_sims / per_wall,
        "setup_reduction": per_setup / mixed_setup,
        "speedup_vs_per_pair_pools": (mixed_sims / mixed_wall)
                                     / (per_sims / per_wall),
    }


def run_reference(games: int, seed: int) -> dict:
    """The acceptance cell: 2n-vs-n on the 5x5 reference config."""
    engine = GoEngine(BOARD, komi=KOMI)
    base = MCTSConfig(board_size=BOARD, lanes=2, sims_per_move=16,
                      max_nodes=MAX_NODES)
    cfg_a, cfg_b = double_resources(base), base
    host = time_refill_path(engine, cfg_a, cfg_b, games, seed, "host")
    dev = time_refill_path(engine, cfg_a, cfg_b, games, seed, "device")
    out = {
        "board": BOARD, "games": games, "lanes": base.lanes,
        "sims_per_move": base.sims_per_move, "move_cap": MOVE_CAP,
        "shards": 1,
        "arena_wall_s": host["wall_s"],
        "arena_sims_per_sec": host["sims"] / host["wall_s"],
        "arena_host_syncs_per_move": host["host_syncs_per_move"],
        "service_wall_s": dev["wall_s"],
        "service_sims_per_sec": dev["sims"] / dev["wall_s"],
        "service_host_syncs_per_move": dev["host_syncs_per_move"],
    }
    out["speedup"] = out["service_sims_per_sec"] / out["arena_sims_per_sec"]
    out["host_sync_reduction"] = (out["arena_host_syncs_per_move"]
                                  / out["service_host_syncs_per_move"])
    return out


def run_mixed(games: int, queries: int, seed: int) -> dict:
    engine = GoEngine(BOARD, komi=KOMI)
    base = MCTSConfig(board_size=BOARD, lanes=2, sims_per_move=16,
                      max_nodes=MAX_NODES)
    return time_mixed_workload(engine, double_resources(base), base,
                               games, queries, seed)


def _payload(ref: dict, mixed: dict, sharded: dict,
             multi: dict, overlap: dict) -> dict:
    return {"schema": SCHEMA, "board": BOARD, "komi": KOMI,
            "move_cap": MOVE_CAP, "max_nodes": MAX_NODES,
            "reference": ref, "mixed": mixed, "sharded": sharded,
            "multi_config": multi, "overlap": overlap}


def _overlap_csv(overlap: dict) -> None:
    s2 = overlap["summary"]["superstep2"]
    csv_row("service_overlap_pipeline",
            s2["host_blocked_per_move_pipelined"],
            f"blocked_cut={s2['host_blocked_reduction']:.2f};"
            f"win={int(s2['overlap_win'])};"
            f"speedup={s2['speedup_vs_sync']:.2f}")


def run() -> None:
    """benchmarks.run entry: reference cell + mixed row, default output."""
    ref = run_reference(games=8, seed=0)
    csv_row("service_reference_speedup", ref["service_wall_s"] / 8,
            f"speedup={ref['speedup']:.2f};"
            f"sync_cut={ref['host_sync_reduction']:.1f}x")
    mixed = run_mixed(games=8, queries=8, seed=0)
    csv_row("service_mixed_pool", mixed["wall_s"],
            f"sims/s={mixed['sims_per_sec']:.0f}")
    sharded = run_sharded_sweep(games=8, seed=0, devices=jax.device_count())
    multi = run_multiconfig(games_per_pair=4, seed=0)
    csv_row("service_multi_config", multi["mixed_wall_s"],
            f"configs={multi['configs']};compiles=1;"
            f"setup_cut={multi['setup_reduction']:.1f};"
            f"speedup={multi['speedup_vs_per_pair_pools']:.2f}")
    overlap = run_overlap(games=8, queries=16, seed=0)
    _overlap_csv(overlap)
    with open("BENCH_service.json", "w") as f:
        json.dump(_payload(ref, mixed, sharded, multi, overlap), f,
                  indent=2, sort_keys=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--games", type=int, default=8)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=1,
                    help="fake this many CPU devices for the sharded sweep "
                         "(must be the first jax initialisation)")
    ap.add_argument("--overlap-queries", type=int, default=16,
                    help="serve queries mixed into the overlap cell "
                         "(host-side result unpacking is the overlapped "
                         "work)")
    args = ap.parse_args()
    devices = min(args.devices, jax.device_count()) if args.devices > 1 \
        else jax.device_count()

    print("# service dispatcher vs PR 1 arena path "
          f"({BOARD}x{BOARD}, move cap {MOVE_CAP})")
    ref = run_reference(args.games, args.seed)
    print(f"reference 2n-vs-n: arena {ref['arena_sims_per_sec']:.0f} sims/s "
          f"({ref['arena_host_syncs_per_move']:.2f} syncs/move)  "
          f"service {ref['service_sims_per_sec']:.0f} sims/s "
          f"({ref['service_host_syncs_per_move']:.2f} syncs/move)  "
          f"speedup {ref['speedup']:.2f}x")
    csv_row("service_reference_speedup", ref["service_wall_s"] / args.games,
            f"speedup={ref['speedup']:.2f};"
            f"sync_cut={ref['host_sync_reduction']:.1f}x")

    mixed = run_mixed(args.games, args.queries, args.seed)
    print(f"mixed pool: {mixed['games']} games + {mixed['serve_queries']} "
          f"queries -> {mixed['sims_per_sec']:.0f} sims/s "
          f"({mixed['host_syncs_per_move']:.2f} syncs/move)")

    sharded = run_sharded_sweep(args.games, args.seed, devices)
    for row in sharded["sweep"]:
        occ = " ".join(f"{o:.2f}" for o in row["shard_occupancy"])
        hops = f", {row['rebalance_hops']}-hop" if row["rebalance_hops"] \
            else ""
        print(f"sharded {row['shards']}x{row['slots'] // row['shards']} "
              f"slots ({row['placement'] or 'single'}{hops}): "
              f"{row['sims_per_sec']:.0f} sims/s "
              f"({row['speedup_vs_1shard']:.2f}x vs 1 shard)  occ [{occ}]")
    csv_row("service_sharded_sweep", sharded["sweep"][-1]["wall_s"],
            f"shards={sharded['sweep'][-1]['shards']};"
            f"scale={sharded['sweep'][-1]['speedup_vs_1shard']:.2f}")

    multi = run_multiconfig(games_per_pair=4, seed=args.seed)
    print(f"multi-config: {multi['configs']} configs x "
          f"{multi['games_per_pair']} games/pair through one pool -> "
          f"{multi['mixed_sims_per_sec']:.0f} sims/s, "
          f"{multi['dispatch_compiles']} compile "
          f"({multi['speedup_vs_per_pair_pools']:.2f}x warm, "
          f"{multi['setup_reduction']:.1f}x less setup vs per-pair pools "
          f"at {multi['per_pair_sims_per_sec']:.0f} sims/s)")
    csv_row("service_multi_config", multi["mixed_wall_s"],
            f"configs={multi['configs']};compiles=1;"
            f"setup_cut={multi['setup_reduction']:.1f};"
            f"speedup={multi['speedup_vs_per_pair_pools']:.2f}")

    overlap = run_overlap(args.games, args.overlap_queries, args.seed)
    for row in overlap["rows"]:
        print(f"overlap superstep {row['superstep']} depth "
              f"{row['pipeline_depth']}: "
              f"{row['host_blocked_per_move'] * 1e3:.2f} ms blocked/move, "
              f"{row['sims_per_sec']:.0f} sims/s "
              f"(in-flight {row['in_flight_depth']})")
    for name, s in overlap["summary"].items():
        print(f"overlap {name}: blocked/move cut "
              f"{s['host_blocked_reduction']:.2f}x "
              f"({'win' if s['overlap_win'] else 'NO WIN'}), "
              f"{s['speedup_vs_sync']:.2f}x sims/s vs sync")
    _overlap_csv(overlap)

    with open(args.out, "w") as f:
        json.dump(_payload(ref, mixed, sharded, multi, overlap), f,
                  indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
