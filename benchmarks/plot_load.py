"""Plot BENCH_load.json: latency percentiles and shed rate vs offered load.

The serving-tier analogue of the paper's threads-vs-performance figure:
x = offered requests/s (log), left y = client p50/p95/p99 latency (log),
right y = explicit shed rate.  Requires matplotlib (the bench-nightly CI
job installs it; the bench itself never needs it).

    PYTHONPATH=src python benchmarks/plot_load.py BENCH_load.json \
        [--out BENCH_load.png]
"""
from __future__ import annotations

import argparse
import json
import sys


def main() -> int:
    """CLI entry point."""
    ap = argparse.ArgumentParser()
    ap.add_argument("bench", help="BENCH_load.json to plot")
    ap.add_argument("--out", default="BENCH_load.png")
    args = ap.parse_args()
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("matplotlib not available; skipping the figure",
              file=sys.stderr)
        return 0

    with open(args.bench) as f:
        payload = json.load(f)
    pts = [p for p in payload["points"] if "p50_ms" in p]
    rps = [p["offered_rps"] for p in pts]

    fig, ax = plt.subplots(figsize=(7, 4.5))
    for q, style in (("p50_ms", "o-"), ("p95_ms", "s--"), ("p99_ms", "^:")):
        ax.plot(rps, [p[q] for p in pts], style, label=q.replace("_ms", ""))
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel("offered load (requests/s)")
    ax.set_ylabel("client latency (ms)")
    ax.grid(True, which="both", alpha=0.3)

    ax2 = ax.twinx()
    all_pts = payload["points"]
    ax2.plot([p["offered_rps"] for p in all_pts],
             [p["shed_rate"] for p in all_pts],
             "x-", color="tab:red", alpha=0.6, label="shed rate")
    ax2.set_ylabel("shed rate", color="tab:red")
    ax2.set_ylim(0, 1)

    cap = payload.get("calibration", {}).get("capacity_rps")
    if cap:
        ax.axvline(cap, color="gray", linestyle=":", alpha=0.7)
        ax.annotate(f"capacity ~{cap:.1f} rps", (cap, ax.get_ylim()[1]),
                    fontsize=8, ha="right", va="top", rotation=90)
    ax.legend(loc="upper left", fontsize=9)
    ax.set_title("HTTP front door: latency vs offered load "
                 f"({payload['config']['board']}x"
                 f"{payload['config']['board']}, "
                 f"sims {payload['config']['sims']})")
    fig.tight_layout()
    fig.savefig(args.out, dpi=120)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
