"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Scaled for a single-CPU
container (see each module's docstring for the paper mapping and
EXPERIMENTS.md for the recorded results).

    PYTHONPATH=src python -m benchmarks.run [only] [--devices N]

``--devices N`` fakes N CPU devices (or, on a real multi-device backend,
is capped by what exists) so the sharded-service sweep in
``bench_service`` and the request-level placement section of
``fig_affinity`` exercise real shards — the ROADMAP's real-device-sweep
prep: on TPU/GPU the same flag-free invocation picks up every physical
device automatically.
"""
from __future__ import annotations

import os
import sys
import time


def _parse_args(argv):
    only, devices = None, None
    i = 1
    while i < len(argv):
        a = argv[i]
        if a == "--devices" and i + 1 < len(argv):
            devices, i = int(argv[i + 1]), i + 2
        elif a.startswith("--devices="):
            devices, i = int(a.split("=", 1)[1]), i + 1
        else:
            only, i = a, i + 1
    return only, devices


def main() -> None:
    only, devices = _parse_args(sys.argv)
    if devices is not None and devices > 1:
        # must land before any figure module initialises the jax backend
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={devices}"
                .strip())
    figures = [
        ("fig_microbench", "Figs 6-8: FMA throughput + bandwidth"),
        ("fig_throughput", "Fig 10: playouts/sec vs lanes"),
        ("fig_treesize", "Fig 12: tree size vs budget"),
        ("fig_affinity", "Fig 9: affinity policies"),
        ("fig_selfplay", "Figs 4/5/11: effective speedup"),
        ("fig_modes", "Related work: tree vs root vs leaf parallelism"),
        ("fig_roofline", "Roofline table from the dry-run"),
        ("bench_arena", "Arena self-play throughput (BENCH_selfplay.json)"),
        ("bench_service", "Service dispatcher throughput (BENCH_service.json)"),
        ("bench_eval", "Evaluation-lane throughput (BENCH_eval.json)"),
        ("bench_kernels", "Fused superstep kernels (BENCH_kernels.json)"),
        ("bench_league", "League scheduling (BENCH_league.json)"),
    ]
    print("name,us_per_call,derived")
    for mod_name, desc in figures:
        if only and only not in mod_name:
            continue
        print(f"# === {mod_name}: {desc} ===", flush=True)
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        mod.run()
        print(f"# {mod_name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
