"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Scaled for a single-CPU
container (see each module's docstring for the paper mapping and
EXPERIMENTS.md for the recorded results).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    figures = [
        ("fig_microbench", "Figs 6-8: FMA throughput + bandwidth"),
        ("fig_throughput", "Fig 10: playouts/sec vs lanes"),
        ("fig_treesize", "Fig 12: tree size vs budget"),
        ("fig_affinity", "Fig 9: affinity policies"),
        ("fig_selfplay", "Figs 4/5/11: effective speedup"),
        ("fig_modes", "Related work: tree vs root vs leaf parallelism"),
        ("fig_roofline", "Roofline table from the dry-run"),
        ("bench_arena", "Arena self-play throughput (BENCH_selfplay.json)"),
        ("bench_service", "Service dispatcher throughput (BENCH_service.json)"),
    ]
    print("name,us_per_call,derived")
    for mod_name, desc in figures:
        if only and only not in mod_name:
            continue
        print(f"# === {mod_name}: {desc} ===", flush=True)
        t0 = time.time()
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        mod.run()
        print(f"# {mod_name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
