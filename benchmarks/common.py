"""Benchmark helpers: timing, CSV emission, CPU-budget scaling.

Every figure module prints ``name,us_per_call,derived`` CSV rows (harness
contract) plus a human-readable block.  This container is a single CPU
core, so game counts / playout budgets are scaled down (the *methodology*
is the paper's; EXPERIMENTS.md records the mapping).
"""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> Tuple[float, object]:
    """Median wall time (s) of a jitted callable; blocks on results."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2], out


def csv_row(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")
