"""Arena self-play throughput benchmark -> BENCH_selfplay.json.

Measures steady-state self-play throughput (sims/sec, moves/sec,
games/sec) of the batched arena (core/arena.py) against the seed match
loop (vmapped double-search ``play_game``, rebuilt in ``time_seed_path``
below) on the 5x5 reference config, then sweeps ``(games, lanes,
parallelism)``.  Both paths are warmed (compile excluded) — the metric is
sustained match throughput, what the scaling experiments actually spend.

"Useful" sims are the mover's: per recorded move, the player to move
spent ``sims_per_move`` playouts.  The seed path *computes* both players'
searches per move but only the mover's counts — that discarded half is
exactly what the arena reclaims.

    PYTHONPATH=src python benchmarks/bench_arena.py [--out BENCH_selfplay.json]
"""
from __future__ import annotations

import argparse
import json
import time

if __package__ in (None, ""):                    # `python benchmarks/...`
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.config import MCTSConfig
from repro.core.arena import Arena
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources, play_game
from repro.go import GoEngine

BOARD = 5
KOMI = 0.5
MOVE_CAP = 30
MAX_NODES = 128
SCHEMA = "bench_selfplay/v1"


def _useful_sims(total_moves: float, sims_a: int, sims_b: int) -> float:
    """Movers alternate, so each path charges the same per-move average."""
    return total_moves * (sims_a + sims_b) / 2.0


def time_seed_path(engine: GoEngine, cfg_a: MCTSConfig, cfg_b: MCTSConfig,
                   games: int, seed: int) -> dict:
    """Seed ``match`` loop with a persistent jit so compile is excluded."""
    player_a = MCTS(engine, cfg_a)
    player_b = MCTS(engine, cfg_b)

    @jax.jit
    def run_batch(keys, a_black):
        return jax.vmap(lambda k, ab: play_game(
            engine, player_a, player_b, k, ab, MOVE_CAP))(keys, a_black)

    def one_match(s):
        keys = jax.random.split(jax.random.PRNGKey(s), games)
        a_black = (jnp.arange(games) % 2) == 0
        rec = run_batch(keys, a_black)
        jax.block_until_ready(rec)
        return rec

    one_match(seed + 1000)                       # warm / compile
    t0 = time.perf_counter()
    rec = one_match(seed)
    wall = time.perf_counter() - t0
    moves = float(rec.moves.sum())
    return {"wall_s": wall, "moves": moves,
            "sims": _useful_sims(moves, cfg_a.sims_per_move,
                                 cfg_b.sims_per_move)}


def time_arena_path(engine: GoEngine, cfg_a: MCTSConfig, cfg_b: MCTSConfig,
                    games: int, seed: int, slots: int = 0) -> dict:
    player_a = MCTS(engine, cfg_a)
    player_b = MCTS(engine, cfg_b)
    slots = slots or games
    slots = max(2, slots + (slots % 2))          # arena needs an even count
    arena = Arena(engine, player_a, player_b, slots=slots,
                  max_moves=MOVE_CAP)
    arena.play_games(games, seed=seed + 1000)    # warm / compile
    t0 = time.perf_counter()
    recs = arena.play_games(games, seed=seed)
    wall = time.perf_counter() - t0
    moves = float(sum(r.moves for r in recs))
    return {"wall_s": wall, "moves": moves, "games": len(recs),
            "sims": _useful_sims(moves, cfg_a.sims_per_move,
                                 cfg_b.sims_per_move)}


def run_reference(games: int, seed: int) -> dict:
    """The acceptance cell: 2n-vs-n on the 5x5 reference config."""
    engine = GoEngine(BOARD, komi=KOMI)
    base = MCTSConfig(board_size=BOARD, lanes=2, sims_per_move=16,
                      max_nodes=MAX_NODES)
    cfg_a, cfg_b = double_resources(base), base
    ref = time_seed_path(engine, cfg_a, cfg_b, games, seed)
    arena = time_arena_path(engine, cfg_a, cfg_b, games, seed)
    out = {
        "board": BOARD, "games": games, "lanes": base.lanes,
        "sims_per_move": base.sims_per_move, "move_cap": MOVE_CAP,
        "seed_wall_s": ref["wall_s"],
        "seed_sims_per_sec": ref["sims"] / ref["wall_s"],
        "arena_wall_s": arena["wall_s"],
        "arena_sims_per_sec": arena["sims"] / arena["wall_s"],
        "arena_moves_per_sec": arena["moves"] / arena["wall_s"],
        "arena_games_per_sec": arena["games"] / arena["wall_s"],
    }
    out["speedup"] = out["arena_sims_per_sec"] / out["seed_sims_per_sec"]
    return out


def run_sweep(games_points, lanes_points, modes, seed: int) -> list:
    engine = GoEngine(BOARD, komi=KOMI)
    rows = []
    for games in games_points:
        for lanes in lanes_points:
            for mode in modes:
                cfg = MCTSConfig(board_size=BOARD, lanes=lanes,
                                 sims_per_move=8 * lanes,
                                 max_nodes=MAX_NODES, parallelism=mode)
                r = time_arena_path(engine, cfg, cfg, games, seed)
                row = {
                    "games": games, "lanes": lanes, "parallelism": mode,
                    "sims_per_move": cfg.sims_per_move,
                    "wall_s": r["wall_s"],
                    "sims_per_sec": r["sims"] / r["wall_s"],
                    "moves_per_sec": r["moves"] / r["wall_s"],
                    "games_per_sec": r["games"] / r["wall_s"],
                }
                rows.append(row)
                csv_row(f"arena_g{games}_n{lanes}_{mode}",
                        r["wall_s"] / games,
                        f"sims/s={row['sims_per_sec']:.0f};"
                        f"moves/s={row['moves_per_sec']:.1f}")
    return rows


def run() -> None:
    """benchmarks.run entry: reference cell + small sweep, default output."""
    ref = run_reference(games=8, seed=0)
    csv_row("arena_reference_speedup", ref["arena_wall_s"] / 8,
            f"speedup={ref['speedup']:.2f}")
    sweep = run_sweep((8,), (1, 2), ("tree",), seed=0)
    payload = {"schema": SCHEMA, "board": BOARD, "komi": KOMI,
               "move_cap": MOVE_CAP, "max_nodes": MAX_NODES,
               "reference": ref, "sweep": sweep}
    with open("BENCH_selfplay.json", "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_selfplay.json")
    ap.add_argument("--games", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="bigger (games, lanes, mode) sweep")
    args = ap.parse_args()

    print("# arena vs seed self-play throughput "
          f"({BOARD}x{BOARD}, move cap {MOVE_CAP})")
    ref = run_reference(args.games, args.seed)
    print(f"reference 2n-vs-n: seed {ref['seed_sims_per_sec']:.0f} sims/s  "
          f"arena {ref['arena_sims_per_sec']:.0f} sims/s  "
          f"speedup {ref['speedup']:.2f}x")
    csv_row("arena_reference_speedup", ref["arena_wall_s"] / args.games,
            f"speedup={ref['speedup']:.2f}")

    if args.full:
        sweep = run_sweep((4, 8, 16), (1, 2, 4), ("tree", "leaf"), args.seed)
    else:
        sweep = run_sweep((args.games,), (1, 2, 4), ("tree",), args.seed)

    payload = {"schema": SCHEMA, "board": BOARD, "komi": KOMI,
               "move_cap": MOVE_CAP, "max_nodes": MAX_NODES,
               "reference": ref, "sweep": sweep}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
