"""Evaluation-lane benchmark -> BENCH_eval.json.

Measures the PR 7 neural evaluation lane (core/evaluator.py) on the 5x5
reference config:

* **reference cell** — one guided pool (traced ``prior_weight = 1``)
  against the same pool running unguided (``prior_weight = 0``, bit-
  identical to the no-eval program): guided sims/sec, the overhead
  ratio of running the net inside every superstep, and the compile
  count (one dispatch serves both, asserted);
* **batch sweep** — guided sims/sec and **eval batch occupancy**
  (``SearchService.eval_occupancy``: the fraction of net-forward rows
  doing useful work, since every slot contributes a fixed
  ``lanes``-row stripe to the superstep's eval batch) against the eval
  batch size, i.e. the slot count.  The acceptance gate: occupancy at
  the default batch size must be >= 0.5 — the device-refill admission
  keeps the batch mostly full, which is what makes superstep-batched
  evaluation viable at all.

    PYTHONPATH=src python benchmarks/bench_eval.py [--out BENCH_eval.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                    # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import csv_row
from repro.config import MCTSConfig
from repro.core.evaluator import EvalConfig, EvalService
from repro.core.mcts import MCTS
from repro.core.service import SearchService
from repro.go import GoEngine

BOARD = 5
KOMI = 0.5
MOVE_CAP = 30
MAX_NODES = 128
SIMS = 16
LANES = 2
DEFAULT_SLOTS = 8
SLOT_SWEEP = (4, 8, 16)
MIN_OCCUPANCY = 0.5
SCHEMA = "bench_eval/v1"

ECFG = EvalConfig(board_size=BOARD, d_model=16, num_layers=1, num_heads=2,
                  d_ff=32)


def _pool(engine: GoEngine, slots: int) -> SearchService:
    cfg = MCTSConfig(board_size=BOARD, komi=KOMI, lanes=LANES,
                     sims_per_move=SIMS, max_nodes=MAX_NODES)
    player = MCTS(engine, cfg, evaluator=EvalService(ECFG))
    return SearchService(engine, player, player, slots,
                         max_moves=MOVE_CAP)


def time_cell(svc: SearchService, games: int, seed: int,
              prior_weight: float, repeats: int = 2) -> dict:
    """One (slots, prior_weight) cell: seeded games, min-of-N wall."""

    def _run(s):
        svc.reset(seed=s, colour_cap=(games + 1) // 2, game_capacity=games,
                  ring_capacity=games + svc.slots)
        for _ in range(games):
            svc.submit_game(prior_weight=prior_weight)
        return svc.drain()

    _run(seed + 1000)                            # warm / compile
    wall = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        recs = _run(seed)
        wall = min(wall, time.perf_counter() - t0)
    moves = float(sum(r.moves for r in recs))
    sims = moves * SIMS                          # both sides share SIMS
    occ = svc.eval_occupancy()
    return {
        "slots": svc.slots, "lanes": LANES,
        "eval_batch_rows": svc.slots * LANES,
        "prior_weight": prior_weight,
        "games": len(recs), "moves": moves, "wall_s": wall,
        "sims": sims, "sims_per_sec": sims / wall,
        "eval_occupancy": round(float(np.mean(occ)), 4),
    }


def run_reference(games: int, seed: int) -> dict:
    """Guided vs unguided through ONE pool (and one compiled dispatch)."""
    engine = GoEngine(BOARD, komi=KOMI)
    svc = _pool(engine, DEFAULT_SLOTS)
    guided = time_cell(svc, games, seed, prior_weight=1.0)
    unguided = time_cell(svc, games, seed, prior_weight=0.0)
    compiles = svc._dispatch._cache_size()
    if compiles != 1:
        raise RuntimeError(
            f"eval-lane dispatch compiled {compiles}x; traced prior_weight "
            "requires exactly 1 across guided and unguided runs")
    return {
        "slots": DEFAULT_SLOTS, "games": games,
        "sims_per_move": SIMS, "move_cap": MOVE_CAP,
        "dispatch_compiles": compiles,
        "guided_sims_per_sec": guided["sims_per_sec"],
        "unguided_sims_per_sec": unguided["sims_per_sec"],
        "eval_overhead": (unguided["sims_per_sec"]
                          / guided["sims_per_sec"]),
        "eval_occupancy": guided["eval_occupancy"],
    }


def run_batch_sweep(seed: int, slot_counts=SLOT_SWEEP) -> dict:
    """Guided throughput + eval batch occupancy vs eval batch size."""
    engine = GoEngine(BOARD, komi=KOMI)
    rows = []
    for slots in slot_counts:
        svc = _pool(engine, slots)
        # 2x oversubscription: device-refill admission keeps the batch
        # full until the workload tail, which is what occupancy measures
        rows.append(time_cell(svc, 2 * slots, seed, prior_weight=1.0))
    default = next(r for r in rows if r["slots"] == DEFAULT_SLOTS)
    if default["eval_occupancy"] < MIN_OCCUPANCY:
        raise RuntimeError(
            f"eval batch occupancy {default['eval_occupancy']:.2f} < "
            f"{MIN_OCCUPANCY} at the default batch size "
            f"({DEFAULT_SLOTS} slots) — the superstep batcher is running "
            "mostly-empty net forwards")
    return {"default_slots": DEFAULT_SLOTS, "min_occupancy": MIN_OCCUPANCY,
            "sweep": rows}


def _payload(ref: dict, sweep: dict) -> dict:
    return {"schema": SCHEMA, "board": BOARD, "komi": KOMI,
            "move_cap": MOVE_CAP, "max_nodes": MAX_NODES,
            "eval_config": {"d_model": ECFG.d_model,
                            "num_layers": ECFG.num_layers,
                            "num_heads": ECFG.num_heads, "d_ff": ECFG.d_ff},
            "reference": ref, "batch_sweep": sweep}


def run() -> None:
    """benchmarks.run entry: reference cell + sweep, default output."""
    ref = run_reference(games=8, seed=0)
    csv_row("eval_guided_throughput", 1.0 / ref["guided_sims_per_sec"],
            f"sims/s={ref['guided_sims_per_sec']:.0f};"
            f"overhead={ref['eval_overhead']:.2f};"
            f"occ={ref['eval_occupancy']:.2f}")
    sweep = run_batch_sweep(seed=0)
    with open("BENCH_eval.json", "w") as f:
        json.dump(_payload(ref, sweep), f, indent=2, sort_keys=True)


def main() -> None:
    """CLI entry point: reference cell + batch sweep, printed + JSON."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_eval.json")
    ap.add_argument("--games", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"# evaluation lane ({BOARD}x{BOARD}, move cap {MOVE_CAP}, "
          f"net d{ECFG.d_model}x{ECFG.num_layers})")
    ref = run_reference(args.games, args.seed)
    print(f"reference: guided {ref['guided_sims_per_sec']:.0f} sims/s vs "
          f"unguided {ref['unguided_sims_per_sec']:.0f} sims/s "
          f"(overhead {ref['eval_overhead']:.2f}x, "
          f"occupancy {ref['eval_occupancy']:.2f}, "
          f"{ref['dispatch_compiles']} compile)")
    csv_row("eval_guided_throughput", 1.0 / ref["guided_sims_per_sec"],
            f"sims/s={ref['guided_sims_per_sec']:.0f};"
            f"overhead={ref['eval_overhead']:.2f};"
            f"occ={ref['eval_occupancy']:.2f}")

    sweep = run_batch_sweep(args.seed)
    for row in sweep["sweep"]:
        print(f"batch {row['eval_batch_rows']:3d} rows ({row['slots']} "
              f"slots): {row['sims_per_sec']:.0f} sims/s, "
              f"occupancy {row['eval_occupancy']:.2f}")

    with open(args.out, "w") as f:
        json.dump(_payload(ref, sweep), f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
