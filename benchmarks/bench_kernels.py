"""Fused-superstep kernel benchmark -> BENCH_kernels.json.

Measures the PR 8 fused MCTS hot loop (kernels/mcts_step/) against the
unfused per-lane program on the 5x5 reference cell, at two scopes:

* **search** (measured wall clock) — sims/sec through the full
  ``MCTS.search_batch`` with ``fused=True`` vs ``fused=False`` (the
  flagless PR 7 program): same roots, same seeds, min-of-N.
* **hotloop** (bytes moved) — one select/expand/backup superstep with
  playouts stubbed (``value_fn``), the phases the fusion restructures:

  - *unfused*: trip-count-aware HLO traffic (analysis/hlo.py) of the
    compiled per-lane superstep — the XLA program re-streams child-stat
    rows from the ``[N]``/``[N, A]`` tree slabs per (lane, level) with
    no residency guarantee;
  - *fused*: the Pallas kernel's **block-transfer contract**: with
    ``grid=(G,)`` and per-game BlockSpecs every operand crosses
    HBM<->VMEM exactly once per superstep, so bytes moved = sum of the
    (action-padded) operand + result sizes of ``mcts_select`` +
    ``mcts_backup``.  That sum *is* the VMEM-residency claim, stated in
    bytes — the CPU interpret path runs the oracle, so the kernel's
    traffic is a shape-derived estimate, not an HLO measurement.

  FLOPs for the fused kernel are the one-hot MXU gathers (2*N*A per
  child-stat row, 6 rows per lane-level) plus the backup's path-count
  matmuls; the unfused program's gathers are dynamic-slices, which the
  MODEL_FLOPS convention (dots only) counts as zero.  Arithmetic
  intensity / ``ridge`` (``PEAK_FLOPS_BF16 / HBM_BW``, TPU v5e model
  constants) gives each variant's roofline fraction: both stay
  memory-bound, but the fused superstep's roofline step time drops by
  the bytes-moved reduction — the headline number.

``check_regression.py --kernels`` gates both throughputs (fail
downward) and both bytes/sim numbers (fail upward — the direction-aware
``*_bytes_per_sim`` rule), so a kernel change that adds an operand
stream or a superstep change that re-streams slabs trips CI.

    PYTHONPATH=src python benchmarks/bench_kernels.py [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):                    # `python benchmarks/...`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.analysis.hlo import analyze
from repro.analysis.roofline import roofline_terms
from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.go import GoEngine
from repro.kernels.common import round_up
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

BOARD = 5
KOMI = 0.5
GAMES = 4
LANES = 4
SIMS = 32
MAX_NODES = 256
MAX_DEPTH = 16
REPEATS = 3
SCHEMA = "bench_kernels/v1"
RIDGE = PEAK_FLOPS_BF16 / HBM_BW                 # FLOPs/byte at the roof
LANE = 128                                       # kernel action-axis pad


def _mcts(engine: GoEngine, fused: bool, value_fn=None) -> MCTS:
    cfg = MCTSConfig(board_size=BOARD, komi=KOMI, lanes=LANES,
                     sims_per_move=SIMS, max_nodes=MAX_NODES)
    return MCTS(engine, cfg, max_depth=MAX_DEPTH, fused=fused,
                value_fn=value_fn)


def _roots(engine: GoEngine):
    roots = jax.vmap(lambda _: engine.init_state())(jnp.arange(GAMES))
    rngs = jax.vmap(jax.random.PRNGKey)(jnp.arange(GAMES))
    return roots, rngs


def _wall(fn, *args) -> float:
    """Min-of-N wall seconds for one jitted call (compiles first)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ------------------------------------------------------------------ search

def run_search() -> dict:
    """Measured sims/sec of the full search, fused vs unfused."""
    engine = GoEngine(BOARD, komi=KOMI)
    roots, rngs = _roots(engine)
    sims = float(GAMES * SIMS)
    out = {}
    for name, fused in (("unfused", False), ("fused", True)):
        player = _mcts(engine, fused)
        wall = _wall(jax.jit(lambda r, k, p=player: p.search_batch(r, k)),
                     roots, rngs)
        out[name] = {"wall_s": wall, "sims_per_sec": sims / wall}
    out["speedup"] = (out["fused"]["sims_per_sec"]
                      / out["unfused"]["sims_per_sec"])
    return out


# ----------------------------------------------------------------- hotloop

def _kernel_bytes(g: int, n: int, a: int, lanes: int, depth: int) -> float:
    """Block-transfer bytes of one fused superstep (select + backup).

    ``grid=(G,)`` with per-game BlockSpecs: every operand and result
    crosses HBM<->VMEM exactly once, so traffic = array sizes after the
    action-axis pad to the kernel's LANE width (ops.py).
    """
    ap = round_up(a, LANE)
    vec = g * n * 4                              # one [G, N] f32/i32 slab
    slab = g * n * ap * 4                        # one [G, N, Ap] slab
    paths = g * lanes * depth * 4
    lane_vec = g * lanes * 4
    # select: visit/value/vloss/expanded/terminal/player in, prior/legal/
    # children slabs in; paths + depth/leaf/act/can_expand + vloss out
    select = (6 * vec + 3 * slab) + (paths + 4 * lane_vec + vec)
    # backup: visit/value/paths/val_sum in; visit/value out
    backup = (2 * vec + paths + lane_vec) + 2 * vec
    return float(select + backup)


def _kernel_flops(g: int, n: int, a: int, lanes: int, depth: int) -> float:
    """One-hot matmul FLOPs of one fused superstep.

    Per (lane, level) the select kernel gathers six per-node rows
    (visit/value/vloss/prior/legal/children) as ``[N] one-hot x [N, Ap]``
    MXU products; the backup kernel forms per-lane ``[D, N]`` path
    counts for the visit and value scatters.
    """
    ap = round_up(a, LANE)
    sel = g * lanes * (depth - 1) * 6 * 2.0 * n * ap
    bk = g * lanes * 2 * 2.0 * depth * n
    return sel + bk


def run_hotloop() -> dict:
    """Bytes/FLOPs of one superstep: measured HLO (unfused) vs the
    kernel's block-transfer contract (fused), + roofline terms."""
    engine = GoEngine(BOARD, komi=KOMI)
    roots, rngs = _roots(engine)
    stub = lambda _st: jnp.float32(0.0)          # noqa: E731 — drop playouts
    m0 = _mcts(engine, False, value_fn=stub)
    m1 = _mcts(engine, True, value_fn=stub)
    t = m1.init_tree_batch(roots)
    c, vlw, pw = m1._resolve_params(None)
    sims = float(GAMES * LANES)                  # sims per superstep

    step0 = jax.jit(lambda t, k: jax.vmap(m0._simulate)(t, k))
    step1 = jax.jit(lambda t, k: m1._simulate_fused(t, k, c, vlw, pw))

    cost0 = analyze(step0.lower(t, rngs).compile().as_text())
    n, a = MAX_NODES, engine.num_actions
    cells = {
        "unfused": {"flops": float(cost0["flops"]),
                    "hbm_bytes": float(cost0["hbm_bytes"]),
                    "source": "hlo_measured",
                    "wall_s": _wall(step0, t, rngs)},
        "fused": {"flops": _kernel_flops(GAMES, n, a, LANES, MAX_DEPTH),
                  "hbm_bytes": _kernel_bytes(GAMES, n, a, LANES, MAX_DEPTH),
                  "source": "block_transfer_contract",
                  "wall_s": _wall(step1, t, rngs)},
    }
    for cell in cells.values():
        terms = roofline_terms(cell, {"total": 0.0}, chips=1)
        intensity = (cell["flops"] / cell["hbm_bytes"]
                     if cell["hbm_bytes"] else 0.0)
        cell.update(
            bytes_per_sim=cell["hbm_bytes"] / sims,
            flops_per_byte=intensity,
            roofline_fraction=intensity / RIDGE,
            roofline={k: terms[k] for k in
                      ("compute_s", "memory_s", "dominant",
                       "roofline_step_s")})
    u, f = cells["unfused"], cells["fused"]
    cells["bytes_reduction"] = (u["bytes_per_sim"] / f["bytes_per_sim"]
                                if f["bytes_per_sim"] else 0.0)
    cells["roofline_step_reduction"] = (
        u["roofline"]["roofline_step_s"] / f["roofline"]["roofline_step_s"]
        if f["roofline"]["roofline_step_s"] else 0.0)
    return cells


# ------------------------------------------------------------------ output

def _payload(search: dict, hotloop: dict) -> dict:
    return {"schema": SCHEMA, "board": BOARD, "komi": KOMI,
            "games": GAMES, "lanes": LANES, "sims_per_move": SIMS,
            "max_nodes": MAX_NODES, "max_depth": MAX_DEPTH,
            "backend": jax.default_backend(),
            "ridge_flops_per_byte": RIDGE,
            "search": search, "hotloop": hotloop}


def _print(search: dict, hotloop: dict) -> None:
    for name in ("unfused", "fused"):
        s, h = search[name], hotloop[name]
        print(f"{name:8s}: {s['sims_per_sec']:8.0f} sims/s  "
              f"hotloop {h['bytes_per_sim'] / 1e3:8.1f} KB/sim "
              f"({h['source']})  AI {h['flops_per_byte']:.3f} FLOP/B  "
              f"roofline frac {h['roofline_fraction']:.4f}")
    print(f"fused/unfused: {search['speedup']:.2f}x sims/s, "
          f"{hotloop['bytes_reduction']:.2f}x fewer hot-loop bytes/sim, "
          f"{hotloop['roofline_step_reduction']:.2f}x lower roofline "
          f"step time")


def run() -> None:
    """benchmarks.run entry: both scopes, CSV + default JSON output."""
    search, hotloop = run_search(), run_hotloop()
    csv_row("kernels_fused_search", search["fused"]["wall_s"],
            f"sims/s={search['fused']['sims_per_sec']:.0f};"
            f"bytes_red={hotloop['bytes_reduction']:.2f}x;"
            f"speedup={search['speedup']:.2f}x")
    _print(search, hotloop)
    with open("BENCH_kernels.json", "w") as f:
        json.dump(_payload(search, hotloop), f, indent=2, sort_keys=True)


def main() -> None:
    """CLI entry point: both scopes, printed + JSON artifact."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    print(f"# fused superstep ({BOARD}x{BOARD}, {GAMES} games x "
          f"{LANES} lanes x {SIMS} sims, backend={jax.default_backend()})")
    search, hotloop = run_search(), run_hotloop()
    _print(search, hotloop)
    with open(args.out, "w") as f:
        json.dump(_payload(search, hotloop), f, indent=2, sort_keys=True)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
