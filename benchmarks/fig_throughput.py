"""Fig. 10: games (playouts) per second while making a move.

Paper: FUEGO's games/sec vs thread count on CPU vs Phi — the raw
*efficiency* measure that hides search overhead.  Here: playouts/sec of
one search call vs lane count (single CPU device; the lane axis shows the
vectorisation win of batched playouts, the TPU analogue of SMT filling).
"""
from __future__ import annotations

import jax

from benchmarks.common import csv_row, time_fn
from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.go import GoEngine

BOARD = 5


def run(lanes_points=(1, 2, 4, 8)) -> None:
    print("# fig10: playouts/sec vs lanes (one move's search)")
    eng = GoEngine(BOARD, komi=0.5)
    for lanes in lanes_points:
        cfg = MCTSConfig(board_size=BOARD, lanes=lanes,
                         sims_per_move=8 * lanes, max_nodes=256)
        m = MCTS(eng, cfg)
        root = jax.tree.map(lambda x: x[None], eng.init_state())
        fn = jax.jit(lambda k: m.search_batch(root, k[None]).tree.size[0])
        sec, _ = time_fn(fn, jax.random.PRNGKey(0), warmup=1, iters=2)
        sims = m.iterations * lanes
        csv_row(f"games_per_sec_n{lanes}", sec,
                f"playouts_per_s={sims / sec:.1f};sims={sims}")


if __name__ == "__main__":
    run()
