"""Quickstart: tree-parallel MCTS picks a Go move (the paper's workload).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.go import GoEngine

BOARD = 5   # CPU-friendly; use 9 for the paper's board


def main() -> None:
    engine = GoEngine(BOARD, komi=0.5)
    cfg = MCTSConfig(board_size=BOARD, lanes=8, sims_per_move=128,
                     max_nodes=1024, virtual_loss=1.0)
    mcts = MCTS(engine, cfg)

    state = engine.init_state()
    print(f"{BOARD}x{BOARD} board, {cfg.lanes} parallel lanes "
          f"('threads'), {cfg.sims_per_move} playouts/move\n")

    t0 = time.time()
    # search_batch is the public surface; a single root is a [1]-batch
    roots = jax.tree.map(lambda x: x[None], state)
    res = jax.jit(mcts.search_batch)(roots, jax.random.PRNGKey(0)[None])
    move = int(res.action[0])
    print(f"search: {int(res.tree.size[0])} tree nodes in "
          f"{time.time() - t0:.1f}s (compile included)")
    visits = res.root_visits[0]
    top = sorted(range(engine.num_actions),
                 key=lambda a: -float(visits[a]))[:5]
    for a in top:
        name = "pass" if a == engine.pass_action else \
            f"({a // BOARD},{a % BOARD})"
        print(f"  move {name:8s} visits={float(visits[a]):5.0f} "
              f"value={float(res.root_values[0, a]):+.3f}")

    state = engine.play(state, move)
    print("\nboard after the chosen move:")
    print(engine.render(state.board))


if __name__ == "__main__":
    main()
