"""Batched serving: prefill + decode over a shared KV cache with the
ServeEngine (greedy / temperature sampling, EOS handling, fixed buckets).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.reduced import reduced
from repro.models import build_model
from repro.serving import ServeEngine


def main() -> None:
    cfg = reduced("yi-6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch=4, max_prompt=16,
                         max_new=12, temperature=0.8)

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(3, cfg.vocab_size, n))
               for n in (5, 9, 12, 7)]
    t0 = time.time()
    outs = engine.generate(prompts, seed=42)
    dt = time.time() - t0
    for i, (p, o) in enumerate(zip(prompts, outs)):
        print(f"request {i}: {len(p)} prompt toks -> {len(o)} generated: "
              f"{o}")
    total = sum(len(o) for o in outs)
    print(f"\n{total} tokens in {dt:.1f}s (compile included) — "
          f"{total / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
