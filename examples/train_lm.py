"""End-to-end LM training: a ~100M-param dense model for a few hundred
steps on the synthetic pipeline, with checkpointing and resume.

Full scale (default ~100M params) is sized for a real accelerator; pass
``--tiny`` on this CPU container to watch the loss fall in ~a minute.

    PYTHONPATH=src python examples/train_lm.py --tiny --steps 60
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.config import AttnConfig, ModelConfig, TrainConfig
from repro.data import SyntheticLM
from repro.models import build_model
from repro.training import init_train_state, make_train_step
from repro.ckpt import AsyncCheckpointer


def lm_100m() -> ModelConfig:
    # ~100M params: 12L, d=768, llama-style
    return ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=768,
        d_ff=2048, vocab_size=32000,
        attn=AttnConfig(num_heads=12, num_kv_heads=4, head_dim=64),
        act="swiglu", dtype="float32")


def lm_tiny() -> ModelConfig:
    return dataclasses.replace(
        lm_100m(), num_layers=2, d_model=128, d_ff=256, vocab_size=512,
        attn=AttnConfig(num_heads=4, num_kv_heads=2, head_dim=32))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    cfg = lm_tiny() if args.tiny else lm_100m()
    model = build_model(cfg)
    print(f"{cfg.name}: {cfg.num_params() / 1e6:.1f}M params")

    tcfg = TrainConfig(steps=args.steps, microbatches=1, lr=args.lr,
                       warmup_steps=max(10, args.steps // 20),
                       optimizer="adamw")
    state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
    data = SyntheticLM(cfg, args.seq, args.batch, seed=1)
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    t0 = time.time()
    first = last = None
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i % 8).items()}
        state, m = step_fn(state, batch)
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if (i + 1) % max(1, args.steps // 10) == 0:
            print(f"step {i + 1:4d}  loss {loss:7.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{(time.time() - t0) / (i + 1):.2f}s/step", flush=True)
        if (i + 1) % 100 == 0:
            ckpt.save(i + 1, state._asdict())
    ckpt.wait()
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({time.time() - t0:.0f}s); checkpoints in {args.ckpt_dir}")
    assert last < first, "training failed to reduce the loss"


if __name__ == "__main__":
    main()
