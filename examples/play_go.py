"""End-to-end driver for the paper's application: a complete game of Go
played by two tree-parallel MCTS players (the 2n-vs-n matchup of the
paper's self-play methodology), rendered move by move.

    PYTHONPATH=src python examples/play_go.py [--board 5] [--moves 20]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core.selfplay import double_resources
from repro.go import GoEngine, BLACK


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--board", type=int, default=5)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--sims", type=int, default=32)
    ap.add_argument("--moves", type=int, default=20)
    args = ap.parse_args()

    eng = GoEngine(args.board, komi=0.5)
    weak_cfg = MCTSConfig(board_size=args.board, lanes=args.lanes,
                          sims_per_move=args.sims, max_nodes=512)
    strong_cfg = double_resources(weak_cfg)   # the paper's 2x player
    strong = MCTS(eng, strong_cfg)            # plays black
    weak = MCTS(eng, weak_cfg)                # plays white

    def one(player):        # single root as a [1]-batch of search_batch
        return jax.jit(lambda s, k: player.search_batch(
            jax.tree.map(lambda x: x[None], s), k[None]).action[0])

    s_move, w_move = one(strong), one(weak)

    st = eng.init_state()
    key = jax.random.PRNGKey(0)
    print(f"black: {strong_cfg.lanes} lanes x {strong_cfg.sims_per_move} "
          f"sims | white: {weak_cfg.lanes} x {weak_cfg.sims_per_move}\n")
    for mv in range(args.moves):
        if bool(st.done):
            break
        key, sub = jax.random.split(key)
        t0 = time.time()
        fn = s_move if int(st.to_play) == BLACK else w_move
        action = int(fn(st, sub))
        st = eng.play(st, jnp.int32(action))
        who = "black" if mv % 2 == 0 else "white"
        name = "pass" if action == eng.pass_action else \
            f"({action // args.board},{action % args.board})"
        print(f"move {mv + 1:2d} {who}: {name}  ({time.time() - t0:.1f}s)")
    print("\nfinal position:")
    print(eng.render(st.board))
    score = float(eng.score(st.board)) - eng.komi
    print(f"\nscore (black - white - komi): {score:+.1f}  "
          f"winner: {'black' if score > 0 else 'white'}")


if __name__ == "__main__":
    main()
