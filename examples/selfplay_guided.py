"""Guided self-play -> training records: the evaluation lane end to end.

The full AlphaZero-shaped loop in miniature, on the PR 7 evaluation lane
(core/evaluator.py): an :class:`EvalService` net guides batched MCTS
self-play, every move emits a ``(state tokens, visit-count policy, game
outcome)`` record, and the records feed ``training/step.py`` — the
evaluator doubles as the trainable model, so ``make_train_step`` closes
the loop without glue.  The net starts from its deterministic random
init; the point is the dataflow, not the strength.

Because jitted searches bake the evaluator params in as constants, the
improved net only takes effect by *rebuilding* the player with
``EvalService(cfg, params=...)`` — shown at the end.

    PYTHONPATH=src python examples/selfplay_guided.py
"""
import time

import jax
import jax.numpy as jnp

from repro.config import MCTSConfig, TrainConfig
from repro.core.evaluator import EvalConfig, EvalService
from repro.core.mcts import MCTS
from repro.go import GoEngine
from repro.training.step import init_train_state, make_train_step

BOARD = 5
GAMES = 4          # parallel self-play games (one search_batch per move)
SIMS = 32
MAX_MOVES = 2 * BOARD * BOARD


def selfplay_records(engine: GoEngine, mcts: MCTS, games: int, seed: int):
    """Play ``games`` guided self-play games; return stacked records.

    Records are shaped for ``EvalService.loss``: ``tokens i32[B, S]``,
    ``legal bool[B, A]``, ``policy f32[B, A]`` (root visit distribution),
    ``value f32[B]`` (final game outcome, black perspective, broadcast
    over every position of that game).
    """
    ev = mcts.evaluator
    step_play = jax.jit(jax.vmap(engine.play))
    step_legal = jax.jit(jax.vmap(engine.legal_moves))
    search = jax.jit(mcts.search_batch)

    roots = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (games,) + x.shape),
        engine.init_state())
    rngs = jax.random.split(jax.random.PRNGKey(seed),
                            MAX_MOVES * games).reshape(MAX_MOVES, games, 2)
    toks, legals, pols, lives = [], [], [], []
    for move in range(MAX_MOVES):
        live = ~roots.done                         # bool[G]
        if not bool(live.any()):
            break
        res = search(roots, rngs[move])
        visits = res.root_visits                   # f32[G, A]
        toks.append(ev.tokens(roots))
        legals.append(step_legal(roots))
        pols.append(visits / jnp.maximum(visits.sum(-1, keepdims=True), 1.0))
        lives.append(live)
        roots = step_play(roots, res.action)
    outcome = jax.vmap(engine.result)(roots)       # f32[G] black perspective

    live = jnp.concatenate(lives)                  # [M*G]
    batch = {
        "tokens": jnp.concatenate(toks)[live],
        "legal": jnp.concatenate(legals)[live],
        "policy": jnp.concatenate(pols)[live],
        "value": jnp.tile(outcome, len(toks))[live].astype(jnp.float32),
    }
    return batch, outcome


def main() -> None:
    engine = GoEngine(BOARD, komi=0.5)
    ecfg = EvalConfig(board_size=BOARD, d_model=16, num_layers=1,
                      num_heads=2, d_ff=32)
    evaluator = EvalService(ecfg)
    cfg = MCTSConfig(board_size=BOARD, komi=0.5, lanes=4,
                     sims_per_move=SIMS, max_nodes=4 * SIMS)
    mcts = MCTS(engine, cfg, evaluator=evaluator)

    t0 = time.time()
    batch, outcome = selfplay_records(engine, mcts, GAMES, seed=0)
    n = int(batch["tokens"].shape[0])
    print(f"self-play: {GAMES} games, {n} records in {time.time() - t0:.1f}s "
          f"(outcomes {[int(o) for o in outcome]})")

    tcfg = TrainConfig(steps=30, lr=3e-3, warmup_steps=3, weight_decay=0.0,
                       z_loss=0.0, remat=False)
    tstate = init_train_state(evaluator, tcfg, jax.random.PRNGKey(1))
    train_step = make_train_step(evaluator, tcfg)
    first = last = None
    for step in range(tcfg.steps):
        tstate, metrics = train_step(tstate, batch)
        loss = float(metrics["loss"])
        first = loss if first is None else first
        last = loss
    print(f"training: loss {first:.3f} -> {last:.3f} over {tcfg.steps} steps "
          f"(final ce {float(metrics['ce']):.3f})")

    # Next generation: params are compile-time constants inside a jitted
    # search, so the stronger net rides in via a *rebuilt* player.
    improved = MCTS(engine, cfg,
                    evaluator=EvalService(ecfg, params=tstate.params))
    print(f"rebuilt guided player with trained params: "
          f"{type(improved.evaluator).__name__} ready")


if __name__ == "__main__":
    main()
