"""Beyond-paper composition: PUCT-guided MCTS with a transformer policy.

The MCTS core exposes ``prior_fn``/``value_fn`` hooks; here a small
decoder from the model zoo reads the board as a token sequence and its
logits become the move priors (AlphaZero-style).  This is the place the
paper's search layer and the LM substrate meaningfully compose — the same
tree parallelisation (lanes + virtual loss) now amortises policy batches.

    PYTHONPATH=src python examples/policy_mcts.py
"""
import time

import jax
import jax.numpy as jnp

from repro.config import AttnConfig, MCTSConfig, ModelConfig
from repro.core.mcts import MCTS
from repro.go import GoEngine

BOARD = 5


def tiny_policy_model():
    cfg = ModelConfig(
        name="go-policy", family="dense", num_layers=2, d_model=64,
        d_ff=128, vocab_size=8,                 # cells: empty/black/white...
        attn=AttnConfig(num_heads=4, num_kv_heads=4, head_dim=16,
                        causal=False),
        act="swiglu", dtype="float32")
    from repro.models import build_model
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return model, params


def main() -> None:
    eng = GoEngine(BOARD, komi=0.5)
    model, params = tiny_policy_model()
    # move head: per-point transformer features [V] -> a score per point
    w_point = jax.random.normal(jax.random.PRNGKey(3),
                                (model.cfg.vocab_size,)) * 0.1

    def prior_fn(state, legal):
        """Board -> move prior via the transformer (untrained here; the
        hook is the point — a trained net drops straight in)."""
        tokens = (state.board.astype(jnp.int32) + 1)[None]  # [1, n2]
        logits, _ = model.forward(params, tokens)           # [1, n2, V]
        point_scores = logits[0] @ w_point                  # [n2]
        move_logits = jnp.concatenate(
            [point_scores, jnp.zeros((1,))])                # + pass
        return jax.nn.softmax(jnp.where(legal, move_logits, -1e9))

    cfg = MCTSConfig(board_size=BOARD, lanes=4, sims_per_move=64,
                     max_nodes=512, c_uct=1.5)
    mcts = MCTS(eng, cfg, prior_fn=prior_fn, use_puct=True)

    roots = jax.tree.map(lambda x: x[None], eng.init_state())
    keys = jax.random.PRNGKey(0)[None]
    t0 = time.time()
    res = jax.jit(mcts.search_batch)(roots, keys)
    print(f"PUCT search with policy priors: move {int(res.action[0])}, "
          f"{int(res.tree.size[0])} nodes, {time.time() - t0:.1f}s")

    plain = MCTS(eng, cfg)
    res2 = jax.jit(plain.search_batch)(roots, keys)
    print(f"uniform-prior UCT baseline:    move {int(res2.action[0])}, "
          f"{int(res2.tree.size[0])} nodes")


if __name__ == "__main__":
    main()
