"""Mini reproduction of the paper's Fig. 5: effective speedup vs lanes.

Sweeps the 2n-vs-n self-play win rate over lane counts at a fixed playout
budget per lane — the paper's thread-scaling curve, CPU-budget scaled.

    PYTHONPATH=src python examples/selfplay_scaling.py [--games 6]
"""
import argparse
import time

from repro.config import MCTSConfig
from repro.core.selfplay import effective_speedup_point
from repro.go import GoEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--board", type=int, default=5)
    ap.add_argument("--games", type=int, default=6)
    ap.add_argument("--sims-per-lane", type=int, default=8)
    ap.add_argument("--lanes", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--slots", type=int, default=0,
                    help="concurrent arena games (0 = one slot per game)")
    args = ap.parse_args()

    eng = GoEngine(args.board, komi=0.5)
    print(f"# {args.board}x{args.board}, {args.games} games/point "
          f"(paper: 300), {args.sims_per_lane} sims/lane")
    print("lanes  2x-win-rate  95% CI           mean tree  s/game")
    for n in args.lanes:
        cfg = MCTSConfig(board_size=args.board, lanes=n,
                         sims_per_move=args.sims_per_lane * n,
                         max_nodes=256)
        t0 = time.time()
        res = effective_speedup_point(eng, cfg, games=args.games,
                                      seed=n, max_moves=30,
                                      batch=args.slots)
        dt = (time.time() - t0) / args.games
        r = res.rate
        print(f"{n:5d}  {r.rate * 100:10.1f}%  "
              f"[{r.lo * 100:5.1f}, {r.hi * 100:5.1f}]  "
              f"{res.mean_tree_nodes:9.0f}  {dt:6.1f}")
    print("\npaper expectation: > 50% everywhere, gently decreasing with n"
          "\n(search overhead); sharp drops past the hardware knee.")


if __name__ == "__main__":
    main()
