"""Config system for the repro framework.

Plain dataclasses (no external deps), a registry keyed by ``--arch`` id, and
key=value override parsing for CLI launchers.  Every assigned architecture has a
module in ``repro.configs`` that registers itself here.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0              # 0 => dense FFN
    top_k: int = 2
    shared_experts: int = 0           # DeepSeek/Moonlight-style always-on experts
    first_dense: int = 0              # leading dense layers (Moonlight/K2: 1)
    dense_ff: int = 0                 # d_ff of those dense layers (0 = d_ff)
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    # expert FFN hidden size lives in ModelConfig.d_ff (per expert)


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD parameters."""
    d_state: int = 128
    head_dim: int = 64                # P
    expand: int = 2                   # d_inner = expand * d_model
    chunk: int = 128                  # SSD chunk length
    conv_kernel: int = 4
    n_groups: int = 1


@dataclass(frozen=True)
class AttnConfig:
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # 0 => d_model // num_heads
    rope_theta: float = 10000.0
    window: int = 0                   # 0 => full attention; else sliding window
    # gemma2: alternate local(window)/global layers
    alt_local_global: bool = False
    logit_softcap: float = 0.0        # gemma2: 50.0 on attn logits
    causal: bool = True               # False for encoder-only (hubert)
    # decode-time: shard KV cache sequence over 'model' axis (shard_map LSE combine)
    kv_seq_shard: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"             # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int = 2
    d_model: int = 128
    d_ff: int = 512
    vocab_size: int = 256
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    act: str = "swiglu"               # swiglu | geglu | gelu
    tie_embeddings: bool = False
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    norm_eps: float = 1e-6
    post_block_norm: bool = False     # gemma2 pre+post norms
    # hybrid (hymba): parallel attention + SSM heads in each block
    hybrid_global_layers: Tuple[int, ...] = ()
    meta_tokens: int = 0              # hymba learnable prefix tokens
    # vlm/audio stub frontend: inputs arrive as embeddings for part of the seq
    frontend_tokens: int = 0          # patches/frames occupying seq positions
    max_seq_len: int = 8192
    dtype: str = "bfloat16"

    # -- derived ------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        a = self.attn
        if a.head_dim:
            return a.head_dim
        return self.d_model // max(a.num_heads, 1)

    def num_params(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        n = V * d                                    # token embedding
        if not self.tie_embeddings:
            n += V * d                               # lm head
        n += d                                       # final norm
        n += self.meta_tokens * d
        if self.frontend_tokens or self.family == "audio":
            n += 1024 * d + d * d                    # stub modality projector
        if self.family == "audio":
            n += d                                   # [MASK] embedding
        per_layer = 0
        extra = 0
        if self.family == "ssm":
            per_layer = _ssm_params(self)
        else:
            if self.attn.num_heads:
                per_layer += _attn_params(self)
            if self.family == "hybrid":
                per_layer += _ssm_params(self)
            if self.moe.num_experts:
                e = self.moe.num_experts + self.moe.shared_experts
                per_layer += 3 * d * self.d_ff * e + d * self.moe.num_experts
                # leading dense layers use a dense FFN instead of experts
                fd = self.moe.first_dense
                dff = self.moe.dense_ff or self.d_ff
                extra += fd * (3 * d * dff
                               - (3 * d * self.d_ff * e
                                  + d * self.moe.num_experts))
            elif self.d_ff:
                mult = 3 if self.act in ("swiglu", "geglu") else 2
                per_layer += mult * d * self.d_ff
            per_layer += 2 * d                       # norms
        return n + per_layer * L + extra

    def active_params(self) -> int:
        """Params touched per token (MoE: routed top_k + shared only)."""
        if not self.moe.num_experts:
            return self.num_params()
        d, L = self.d_model, self.num_layers
        e_all = self.moe.num_experts + self.moe.shared_experts
        e_act = self.moe.top_k + self.moe.shared_experts
        dead = 3 * d * self.d_ff * (e_all - e_act) * L
        return self.num_params() - dead


def _attn_params(cfg: ModelConfig) -> int:
    a, d, hd = cfg.attn, cfg.d_model, cfg.head_dim
    return d * a.num_heads * hd + 2 * d * a.num_kv_heads * hd + a.num_heads * hd * d


def _ssm_params(cfg: ModelConfig) -> int:
    s, d = cfg.ssm, cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    proj_in = d * (2 * d_in + 2 * s.n_groups * s.d_state + H)
    conv = s.conv_kernel * (d_in + 2 * s.n_groups * s.d_state)
    return proj_in + conv + H + H + d_in + d_in * d  # A, D, gate-norm, out_proj


# ---------------------------------------------------------------------------
# Shapes (the 4 assigned input shapes) and run configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1                     # >1 => leading 'pod' axis

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.pods > 1:
            return (self.pods, self.data, self.model)
        return (self.data, self.model)


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1             # grad-accumulation chunks per step
    optimizer: str = "adamw"          # adamw | adafactor | sgdm
    lr: float = 3e-4
    warmup_steps: int = 100
    schedule: str = "cosine"
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    seed: int = 0
    remat: bool = True
    z_loss: float = 1e-4
    # fault tolerance / distributed opt
    ckpt_every: int = 500
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    compress_pod_grads: bool = False  # PowerSGD on the cross-pod all-reduce
    powersgd_rank: int = 8
    pipeline_stages: int = 0          # >0 => GPipe over the pod axis


@dataclass(frozen=True)
class MCTSConfig:
    """Paper application config (FUEGO analog)."""
    board_size: int = 9
    komi: float = 6.0
    lanes: int = 8                    # "threads": parallel simulations/iteration
    sims_per_move: int = 64           # playout budget ("seconds per move" analog)
    max_nodes: int = 4096             # tree arena capacity
    c_uct: float = 0.9
    virtual_loss: float = 1.0
    prior_weight: float = 1.0         # eval-lane UCT<->PUCT blend (traced)
    parallelism: str = "tree"         # tree | root | leaf
    root_trees: int = 1               # root parallelism degree (across devices)
    leaf_playouts: int = 1            # playouts per selected leaf
    affinity: str = "compact"         # compact | balanced | scatter
    expand_threshold: int = 1
    seed: int = 0


@dataclass(frozen=True)
class RunConfig:
    arch: str
    model: ModelConfig
    shape: ShapeConfig = field(default_factory=lambda: SHAPES["train_4k"])
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    mcts: Optional[MCTSConfig] = None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
# shapes an arch cannot run, with reason — consumed by dryrun + EXPERIMENTS
_SKIPS: Dict[str, Dict[str, str]] = {}


def register(arch_id: str, fn: Callable[[], ModelConfig],
             skip_shapes: Optional[Dict[str, str]] = None) -> None:
    _REGISTRY[arch_id] = fn
    _SKIPS[arch_id] = dict(skip_shapes or {})


def get_model_config(arch_id: str) -> ModelConfig:
    _ensure_configs_imported()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def skip_reason(arch_id: str, shape_name: str) -> Optional[str]:
    _ensure_configs_imported()
    return _SKIPS.get(arch_id, {}).get(shape_name)


def list_archs() -> List[str]:
    _ensure_configs_imported()
    return sorted(_REGISTRY)


def _ensure_configs_imported() -> None:
    import repro.configs  # noqa: F401  (registers everything)


# ---------------------------------------------------------------------------
# Overrides + serialization
# ---------------------------------------------------------------------------


def apply_overrides(cfg: Any, overrides: Dict[str, str]) -> Any:
    """Apply dotted key=value overrides to a (nested) frozen dataclass."""
    for key, raw in overrides.items():
        cfg = _set_dotted(cfg, key.split("."), raw)
    return cfg


def _set_dotted(cfg: Any, path: List[str], raw: str) -> Any:
    name = path[0]
    if not dataclasses.is_dataclass(cfg):
        raise TypeError(f"cannot override {name} on non-dataclass {type(cfg)}")
    cur = getattr(cfg, name)
    if len(path) == 1:
        ftypes = {f.name: f.type for f in dataclasses.fields(cfg)}
        val = _coerce(raw, cur, ftypes.get(name))
        return dataclasses.replace(cfg, **{name: val})
    return dataclasses.replace(cfg, **{name: _set_dotted(cur, path[1:], raw)})


def _coerce(raw: str, current: Any, _ftype: Any) -> Any:
    if isinstance(current, bool):
        return raw.lower() in ("1", "true", "yes", "on")
    if isinstance(current, int):
        return int(raw)
    if isinstance(current, float):
        return float(raw)
    if isinstance(current, tuple):
        return tuple(int(x) for x in raw.split(",") if x != "")
    return raw


def to_json(cfg: Any) -> str:
    return json.dumps(dataclasses.asdict(cfg), indent=2, sort_keys=True)


def parse_kv(args: List[str]) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for a in args:
        if "=" not in a:
            raise ValueError(f"override {a!r} is not key=value")
        k, v = a.split("=", 1)
        out[k] = v
    return out
