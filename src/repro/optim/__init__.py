from repro.optim.optimizers import (Optimizer, adamw, adafactor, sgdm,
                                    make_optimizer, global_norm, clip_by_global_norm)
from repro.optim.schedule import make_schedule

__all__ = ["Optimizer", "adamw", "adafactor", "sgdm", "make_optimizer",
           "global_norm", "clip_by_global_norm", "make_schedule"]
