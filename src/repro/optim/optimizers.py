"""Optimizers as pure pytree transforms: AdamW, Adafactor, SGD-momentum.

No external deps.  State layout mirrors the param tree so every state leaf
inherits the parameter's sharding (critical at 1T params: Adafactor's
factored second moment is the only optimizer whose state fits the kimi-k2
training dry-run — see DESIGN.md §5).

All update math runs in f32 regardless of param dtype; params may be bf16.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, lr) -> (new_params, new_state)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(step=jnp.int32(0), m=zeros,
                         v=jax.tree.map(jnp.copy, zeros))

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1 - b1 ** t
        c2 = 1 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            upd_ = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
            upd_ = upd_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd_).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamState(step=step, m=new_m, v=new_v)

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, beta1=0) — for the 1T-param MoE
# ---------------------------------------------------------------------------


class FactorState(NamedTuple):
    step: jax.Array
    vr: Any       # row accumulators (or full v for <2D leaves)
    vc: Any       # col accumulators (dummy for <2D leaves)


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0
              ) -> Optimizer:
    def init(params):
        def vr_init(p):
            if _factored(p.shape):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if _factored(p.shape):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return FactorState(step=jnp.int32(0),
                           vr=jax.tree.map(vr_init, params),
                           vc=jax.tree.map(vc_init, params))

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-decay_pow)

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr2 = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                vc2 = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                denom = jnp.maximum(
                    vr2.mean(axis=-1, keepdims=True), eps)
                r = (vr2 / denom)[..., None]
                u = g * jax.lax.rsqrt(r * vc2[..., None, :] + eps)
            else:
                vr2 = beta2 * vr + (1 - beta2) * g2
                vc2 = vc
                u = g * jax.lax.rsqrt(vr2 + eps)
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr2, vc2

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), FactorState(step=step, vr=pick(1), vc=pick(2))

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------


class SGDMState(NamedTuple):
    step: jax.Array
    mom: Any


def sgdm(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return SGDMState(step=jnp.int32(0), mom=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state, params, lr):
        def upd(g, m, p):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m2 = momentum * m + g
            return (p.astype(jnp.float32) - lr * m2).astype(p.dtype), m2

        out = jax.tree.map(upd, grads, state.mom, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), SGDMState(step=state.step + 1, mom=pick(1))

    return Optimizer(init=init, update=update)


def make_optimizer(name: str, weight_decay: float = 0.1) -> Optimizer:
    if name == "adamw":
        return adamw(weight_decay=weight_decay)
    if name == "adafactor":
        return adafactor(weight_decay=weight_decay * 0.0)
    if name == "sgdm":
        return sgdm(weight_decay=weight_decay)
    raise ValueError(f"unknown optimizer {name!r}")
