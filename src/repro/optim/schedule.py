"""LR schedules: linear warmup into cosine / linear / constant decay."""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, peak_lr: float, warmup_steps: int,
                  total_steps: int, final_frac: float = 0.1):
    """Returns step -> lr (jittable)."""

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        if kind == "cosine":
            decay = final_frac + (1 - final_frac) * 0.5 \
                * (1 + jnp.cos(jnp.pi * t))
        elif kind == "linear":
            decay = 1.0 - (1 - final_frac) * t
        else:  # constant
            decay = jnp.asarray(1.0)
        return jnp.where(step < warmup_steps, warm, peak_lr * decay)

    return sched
