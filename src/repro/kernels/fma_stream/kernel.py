"""Pallas kernel for the Xeon-Phi FMA micro-benchmark, TPU-native.

Hardware adaptation (DESIGN.md §2): the paper stresses the Phi's 512-bit VPU
with a scalar loop the compiler vectorises; on TPU the same stream maps onto
the VPU (8x128 vector registers) with explicit HBM->VMEM tiling.  One grid
step owns a ``(8, block)`` VMEM tile of each operand; ``repeats`` re-uses the
tile in registers/VMEM, dialling arithmetic intensity from 1 FMA/4 moved
words (bandwidth-bound, Fig. 8) up to compute-bound (Figs. 6-7) — the same
two regimes the paper sweeps.

f64 note: TPUs have no 64-bit VPU lanes, so the paper's double-precision
variant is represented by f32 (VPU-native) and int32; the f64 oracle path
still runs on CPU for completeness.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl


# 8 sublanes x 1024 lanes x 4 B = 32 KiB per operand tile; 4 tiles resident
# (a, b, c, out) = 128 KiB of VMEM — far below the ~16 MiB budget, letting
# the pipeline double-buffer aggressively.
SUBLANES = 8
DEFAULT_BLOCK = 1024


def _fma_kernel(a_ref, b_ref, c_ref, o_ref, *, repeats: int):
    a = a_ref[...]
    b = b_ref[...]
    acc = c_ref[...]

    def body(_, acc):
        return a * b + acc

    acc = jax.lax.fori_loop(0, repeats, body, acc)
    o_ref[...] = acc


def fma_stream_pallas(a: jax.Array, b: jax.Array, c: jax.Array,
                      repeats: int = 1, block: int = DEFAULT_BLOCK,
                      interpret: bool = False) -> jax.Array:
    """c <- a*b + c applied ``repeats`` times; 1-D inputs of equal length.

    The wrapper reshapes to ``(rows, SUBLANES, block)`` so each grid step
    streams one VMEM tile (inputs must divide; ``ops.py`` pads).
    """
    (n,) = a.shape
    tile = SUBLANES * block
    assert n % tile == 0, f"padded length {n} not a multiple of {tile}"
    rows = n // tile
    shp = (rows, SUBLANES, block)
    a3, b3, c3 = (x.reshape(shp) for x in (a, b, c))

    spec = pl.BlockSpec((1, SUBLANES, block), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        functools.partial(_fma_kernel, repeats=repeats),
        out_shape=jax.ShapeDtypeStruct(shp, a.dtype),
        grid=(rows,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        interpret=interpret,
    )(a3, b3, c3)
    return out.reshape(n)
