"""Jitted dispatch wrapper for ``fma_stream``.

Pallas on TPU; on CPU the oracle math (same numerics) so the op is usable
everywhere.  ``interpret=True`` forces the Pallas path in interpret mode for
kernel validation on CPU.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import pad_to_multiple
from repro.kernels.fma_stream.kernel import (DEFAULT_BLOCK, SUBLANES,
                                             fma_stream_pallas)
from repro.kernels.fma_stream.ref import fma_stream_ref


@functools.partial(jax.jit,
                   static_argnames=("repeats", "block", "interpret"))
def fma_stream(a, b, c, repeats: int = 1, block: int = DEFAULT_BLOCK,
               interpret: bool = False):
    """The paper's loop ``repeats x (c = a*b + c)`` on 1-D arrays."""
    use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        return fma_stream_ref(a, b, c, repeats)
    n = a.shape[0]
    tile = SUBLANES * block
    a2, b2, c2 = (pad_to_multiple(x, tile) for x in (a, b, c))
    out = fma_stream_pallas(a2, b2, c2, repeats=repeats, block=block,
                            interpret=interpret)
    return out[:n]
