"""Pure-jnp oracle for the paper's micro-benchmark loop.

Figs. 6-8 of the paper time ``for many times: c[j] = a[j]*b[j] + c[j]`` —
three streamed reads + one write and one FMA per element.  ``repeats`` is the
paper's "many times" (arithmetic-intensity dial: high repeats = compute-bound
Fig. 6/7 regime, repeats=1 = bandwidth-bound Fig. 8 regime).
"""
import jax.numpy as jnp


def fma_stream_ref(a, b, c, repeats: int = 1):
    for _ in range(repeats):
        c = a * b + c
    return c
