from repro.kernels.fma_stream.ops import fma_stream
