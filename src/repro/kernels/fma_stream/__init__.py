from repro.kernels.fma_stream.ops import fma_stream

__all__ = ["fma_stream"]
