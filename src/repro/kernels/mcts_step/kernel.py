"""Pallas kernels for the fused MCTS superstep: select-all-lanes + backup.

Hardware adaptation (DESIGN.md §2): the paper's finding is that past 32
threads FUEGO is gated by cache/memory behaviour *inside* the per-thread
search loop, not by parallelism.  The modern analogue: the unfused lane
scan re-reads the tree slabs (``visit/value/vloss/prior/legal/children``)
from HBM at every level of every lane and materialises per-level score
rows back to HBM.  Here one grid step owns one game's entire arena in
VMEM — the slabs are loaded once, all ``lanes`` sequential descents run
against the resident copies (each seeing the previous lanes' virtual
losses), and only the compact selection outputs (paths, leaves, updated
``vloss``) leave the kernel.

Pointer-chasing becomes linear algebra: the per-level child-statistics
gather (``visit[children[node]]``, the FUEGO hot read) is a one-hot
``[A, N] x [N]`` matmul on the MXU — the idiom the tree arena was shaped
for — and every scalar read from an ``[N]`` slab is a masked reduction,
so the kernel never needs an unaligned lane-axis dynamic slice.  Dynamic
*row* slices (``prior[node]``) use ``pl.ds`` on the sublane axis, the
well-supported case.  Per-lane outputs accumulate in loop-carried
vectors and are stored once, avoiding dynamic stores entirely.

Grid/tiling: ``grid=(G,)`` over games; per-game blocks ``(1, N)`` /
``(1, N, A)`` with ``A`` padded to a 128-lane multiple by ``ops.py``.
The descent loop is a masked ``fori_loop`` with static bound
``max_depth - 1`` (iterations after the lane stops are no-ops), the
Mosaic-safe form of the oracle's ``while_loop``.

Traced-vs-static: ``c_uct`` / ``vl_weight`` / ``prior_w`` / ``seed``
ride in as per-game ``(1, 1)`` blocks (values never recompile);
``lanes`` / ``max_depth`` / ``expand_threshold`` / ``use_puct`` and the
``prior_w``-presence program selector are static, mirroring
``kernels/uct_select`` exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.mcts_step.ref import UNVISITED, tie_break_noise
from repro.kernels.uct_select.ref import uct_scores_ref

LANE = 128   # action-axis padding multiple (shared with uct_select)


def _at(vec, idx, iota):
    """``vec[idx]`` as a masked reduction (no lane-axis dynamic slice)."""
    return jnp.sum(jnp.where(iota == idx, vec, jnp.zeros_like(vec)))


def _select_kernel(visit_ref, value_ref, vloss_ref, prior_ref, legal_ref,
                   children_ref, expanded_ref, terminal_ref, player_ref,
                   seed_ref, cuct_ref, vlw_ref, pw_ref,
                   paths_ref, depth_ref, leaf_ref, act_ref, canexp_ref,
                   vloss_out_ref, *, lanes: int, max_depth: int,
                   expand_threshold: int, use_puct: bool, blend: bool):
    n = visit_ref.shape[1]
    a = prior_ref.shape[2]
    visit = visit_ref[0, :]
    value = value_ref[0, :]
    expanded = expanded_ref[0, :]
    terminal = terminal_ref[0, :]
    player = player_ref[0, :]
    seed = seed_ref[0, 0]
    c_uct = cuct_ref[0, 0]
    vl_weight = vlw_ref[0, 0]
    prior_w = pw_ref[0, 0] if blend else None

    iota_n = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)[0]
    iota_a = jax.lax.broadcasted_iota(jnp.int32, (1, a), 1)[0]
    iota_d = jax.lax.broadcasted_iota(jnp.int32, (1, max_depth), 1)[0]
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (1, lanes), 1)[0]
    iota_an = jax.lax.broadcasted_iota(jnp.int32, (a, n), 1)
    a_iota = iota_a.astype(jnp.uint32)
    path0 = jnp.where(iota_d == 0, jnp.int32(0), jnp.int32(UNVISITED))

    def lane_body(lane, carry):
        vl, paths_m, depth_v, leaf_v, act_v, canexp_v = carry

        def level_body(level, c):
            node, depth, act, stop, path, path_mask = c
            run = ~stop & (depth < max_depth - 1)
            kids = children_ref[0, pl.ds(node, 1), :][0]         # [A] i32
            # child-statistics gather as a one-hot MXU pass
            oh = (iota_an == kids[:, None]).astype(jnp.float32)  # [A, N]
            cvisit = jnp.dot(oh, visit, preferred_element_type=jnp.float32)
            cvalue = jnp.dot(oh, value, preferred_element_type=jnp.float32)
            cvloss = jnp.dot(oh, vl, preferred_element_type=jnp.float32)
            has_child = (kids != UNVISITED).astype(jnp.float32)
            parent_n = _at(visit + vl, node, iota_n)
            prior_row = prior_ref[0, pl.ds(node, 1), :][0]
            legal_row = legal_ref[0, pl.ds(node, 1), :][0]
            scores = uct_scores_ref(
                cvisit[None], cvalue[None], cvloss[None], prior_row[None],
                legal_row[None], has_child[None], parent_n[None],
                _at(player, node, iota_n)[None],
                c_uct=c_uct, vl_weight=vl_weight, prior_w=prior_w,
                use_puct=use_puct)[0]
            scores = scores + tie_break_noise(seed, lane, level, a_iota)
            act_new = jnp.argmax(scores[None], axis=1)[0].astype(jnp.int32)
            child = jnp.sum(jnp.where(iota_a == act_new, kids, 0))
            nxt = jnp.where(child == UNVISITED, node, child)
            safe = jnp.maximum(child, 0)
            stop_new = (child == UNVISITED) \
                | (_at(terminal, safe, iota_n) > 0) \
                | ~(_at(expanded, safe, iota_n) > 0)
            depth_new = depth + jnp.where(child == UNVISITED, 0, 1)
            path_new = jnp.where(iota_d == depth_new, nxt, path)
            mask_new = path_mask + jnp.where(
                (iota_n == child) & (child != UNVISITED), 1.0, 0.0)
            return (jnp.where(run, nxt, node),
                    jnp.where(run, depth_new, depth),
                    jnp.where(run, act_new, act),
                    jnp.where(run, stop_new, stop),
                    jnp.where(run, path_new, path),
                    jnp.where(run, mask_new, path_mask))

        root_mask = jnp.where(iota_n == 0, 1.0, 0.0)
        init = (jnp.int32(0), jnp.int32(0), jnp.int32(a - 1),
                jnp.bool_(False), path0, root_mask)
        node, depth, act, _, path, path_mask = jax.lax.fori_loop(
            0, max_depth - 1, level_body, init)

        kids = children_ref[0, pl.ds(node, 1), :][0]
        child_at = jnp.sum(jnp.where(iota_a == act, kids, 0))
        can_exp = (child_at == UNVISITED) \
            & ~(_at(terminal, node, iota_n) > 0) \
            & (_at(visit + vl, node, iota_n) >= expand_threshold) \
            & (_at(expanded, node, iota_n) > 0)

        here = iota_l == lane
        return (vl + path_mask,
                jnp.where(here[:, None], path[None, :], paths_m),
                jnp.where(here, depth, depth_v),
                jnp.where(here, node, leaf_v),
                jnp.where(here, act, act_v),
                jnp.where(here, can_exp.astype(jnp.int32), canexp_v))

    zl = jnp.zeros((lanes,), jnp.int32)
    init = (vloss_ref[0, :],
            jnp.full((lanes, max_depth), UNVISITED, jnp.int32),
            zl, zl, zl, zl)
    vl, paths_m, depth_v, leaf_v, act_v, canexp_v = jax.lax.fori_loop(
        0, lanes, lane_body, init)
    paths_ref[0, :, :] = paths_m
    depth_ref[0, :] = depth_v
    leaf_ref[0, :] = leaf_v
    act_ref[0, :] = act_v
    canexp_ref[0, :] = canexp_v
    vloss_out_ref[0, :] = vl


def _backup_kernel(paths_ref, valsum_ref, visit_in_ref, value_in_ref,
                   visit_ref, value_ref, *, lanes: int, playouts: float):
    n = visit_in_ref.shape[1]
    d = paths_ref.shape[2]
    iota_dn = jax.lax.broadcasted_iota(jnp.int32, (d, n), 1)
    iota_l = jax.lax.broadcasted_iota(jnp.int32, (1, lanes), 1)[0]
    valsum = valsum_ref[0, :]

    def lane_body(lane, c):
        visit, value = c
        row = paths_ref[0, pl.ds(lane, 1), :][0]                 # [D] i32
        vs = _at(valsum, lane, iota_l)
        oh = ((iota_dn == row[:, None]) & (row != UNVISITED)[:, None]
              ).astype(jnp.float32)                              # [D, N]
        counts = jnp.sum(oh, axis=0)                             # [N]
        return visit + counts * playouts, value + counts * vs

    visit, value = jax.lax.fori_loop(
        0, lanes, lane_body, (visit_in_ref[0, :], value_in_ref[0, :]))
    visit_ref[0, :] = visit
    value_ref[0, :] = value


def mcts_select_pallas(visit, value, vloss, prior, legal, children, expanded,
                       terminal, player, seed, c_uct, vl_weight, prior_w=None,
                       *, lanes: int, max_depth: int, expand_threshold: int,
                       use_puct: bool, interpret: bool = False):
    """Batched fused selection: slabs ``[G, N]`` / ``[G, N, A_pad]``.

    Per-game traced scalars (``seed`` u32, ``c_uct`` / ``vl_weight`` /
    ``prior_w`` f32) arrive as ``[G]`` arrays; ``prior_w=None`` selects
    the non-blended program (static choice, as in ``uct_select``).
    """
    g, n = visit.shape
    a = prior.shape[-1]
    assert a % LANE == 0, a
    vec = pl.BlockSpec((1, n), lambda i: (i, 0))
    slab = pl.BlockSpec((1, n, a), lambda i: (i, 0, 0))
    col = pl.BlockSpec((1, 1), lambda i: (i, 0))
    lvec = pl.BlockSpec((1, lanes), lambda i: (i, 0))
    pvec = pl.BlockSpec((1, lanes, max_depth), lambda i: (i, 0, 0))
    blend = prior_w is not None
    scalars = [seed[:, None], c_uct[:, None], vl_weight[:, None],
               prior_w[:, None] if blend else jnp.zeros((g, 1), jnp.float32)]
    return pl.pallas_call(
        functools.partial(_select_kernel, lanes=lanes, max_depth=max_depth,
                          expand_threshold=expand_threshold,
                          use_puct=use_puct, blend=blend),
        out_shape=(
            jax.ShapeDtypeStruct((g, lanes, max_depth), jnp.int32),  # paths
            jax.ShapeDtypeStruct((g, lanes), jnp.int32),             # depth
            jax.ShapeDtypeStruct((g, lanes), jnp.int32),             # leaf
            jax.ShapeDtypeStruct((g, lanes), jnp.int32),             # act
            jax.ShapeDtypeStruct((g, lanes), jnp.int32),             # can_exp
            jax.ShapeDtypeStruct((g, n), jnp.float32),               # vloss
        ),
        grid=(g,),
        in_specs=[vec, vec, vec, slab, slab, slab, vec, vec, vec,
                  col, col, col, col],
        out_specs=(pvec, lvec, lvec, lvec, lvec, vec),
        interpret=interpret,
    )(visit, value, vloss, prior, legal, children, expanded, terminal,
      player, *scalars)


def mcts_backup_pallas(visit, value, paths, val_sum, *, playouts: float,
                       interpret: bool = False):
    """Batched fused backup: ``paths [G, L, D]``, ``val_sum [G, L]``."""
    g, n = visit.shape
    _, lanes, d = paths.shape
    vec = pl.BlockSpec((1, n), lambda i: (i, 0))
    lvec = pl.BlockSpec((1, lanes), lambda i: (i, 0))
    pvec = pl.BlockSpec((1, lanes, d), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_backup_kernel, lanes=lanes, playouts=playouts),
        out_shape=(jax.ShapeDtypeStruct((g, n), jnp.float32),
                   jax.ShapeDtypeStruct((g, n), jnp.float32)),
        grid=(g,),
        in_specs=[pvec, lvec, vec, vec],
        out_specs=(vec, vec),
        interpret=interpret,
    )(paths, val_sum, visit, value)
