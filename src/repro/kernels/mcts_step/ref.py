"""Pure-jnp oracle for the fused MCTS superstep (select + backup).

One ``mcts_select`` call performs what the unfused search does with a
``lax.scan`` of per-lane ``while_loop`` descents: all ``lanes`` root-to-leaf
walks of one iteration, sequentially, each lane scoring edges under the
virtual losses applied by the lanes before it.  ``mcts_backup`` is the
matching accumulation: the exact scatter-add of visits/values along every
lane's path.

Deferred-expansion semantics (the documented fused/unfused difference)
----------------------------------------------------------------------
The unfused lane scan *allocates* each lane's new child before the next
lane selects, so later lanes can descend into nodes expanded earlier in
the same iteration.  The fused selection runs over a **frozen** children
table: lanes still see earlier lanes' virtual losses (the decorrelation
that matters), but expansion is deferred — every lane reports the
``(leaf, action)`` edge it wants to expand and ``repro.core.mcts`` grows
the tree for all lanes at once, collapsing duplicate edge picks onto one
new node (mctx-style).  ``fused=False`` therefore stays bit-identical to
the historical program while ``fused=True`` is a search *variant* whose
contract is exact parity between this oracle and the Pallas kernel.

Tie-break noise is a counter-based hash (:func:`tie_break_noise`) rather
than a ``jax.random`` stream: the kernel cannot consume per-(lane, level)
PRNG keys without streaming an ``[L, D, A]`` noise tensor through HBM —
the exact traffic the fusion exists to remove — so both paths derive the
perturbation from ``(seed, lane, level, action)`` arithmetic alone.

Scoring reuses :func:`repro.kernels.uct_select.ref.uct_scores_ref` — one
formula, three call sites (unfused dispatch, this oracle, the Pallas
kernel) — with the same traced ``c_uct`` / ``vl_weight`` / ``prior_w``
contract.  All mask inputs arrive as f32 0/1 slabs (the kernel's native
type); boolean tests are ``> 0``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.uct_select.ref import uct_scores_ref

UNVISITED = -1
NOISE_SCALE = 1e-3          # matches the historical uniform tie-break
_MIX1 = 0x9E3779B9          # golden-ratio odd constants (lane / level / action)
_MIX2 = 0x85EBCA6B
_MIX3 = 0xC2B2AE35
_AVA1 = 0x7FEB352D          # 32-bit avalanche finalizer (degski / murmur-like)
_AVA2 = 0x846CA68B


def tie_break_noise(seed, lane, level, a_iota):
    """Deterministic per-(lane, level, action) noise in ``[0, NOISE_SCALE)``.

    ``seed`` / ``lane`` / ``level`` are traced integer scalars, ``a_iota``
    a uint32 action-index array of any shape.  Pure uint32 arithmetic so
    the Pallas kernel computes bit-identical values to this oracle.
    """
    x = (jnp.asarray(seed).astype(jnp.uint32)
         + jnp.asarray(lane).astype(jnp.uint32) * jnp.uint32(_MIX1)
         + jnp.asarray(level).astype(jnp.uint32) * jnp.uint32(_MIX2)
         + a_iota.astype(jnp.uint32) * jnp.uint32(_MIX3))
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_AVA1)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(_AVA2)
    x = x ^ (x >> 16)
    # top 24 bits -> f32 in [0, 1): exact, no rounding surprises
    return (x >> 8).astype(jnp.float32) * jnp.float32(NOISE_SCALE / (1 << 24))


def mcts_select_ref(visit, value, vloss, prior, legal, children, expanded,
                    terminal, player, seed, *, c_uct, vl_weight, prior_w=None,
                    use_puct: bool = False, lanes: int, max_depth: int,
                    expand_threshold: int):
    """All ``lanes`` sequential descents of one iteration, single game.

    Inputs: ``visit/value/vloss/player/expanded/terminal`` ``f32[N]``
    (masks as 0/1), ``prior/legal`` ``f32[N, A]``, ``children``
    ``i32[N, A]``, ``seed`` a uint32 scalar; ``c_uct`` / ``vl_weight`` /
    ``prior_w`` traced scalars.

    Returns ``(paths i32[L, D], depth i32[L], leaf i32[L], act i32[L],
    can_expand bool[L], vloss f32[N])`` where ``D = max_depth``; paths are
    node ids padded with ``UNVISITED`` and ``vloss`` carries every lane's
    in-flight increments (cleared by the backup, as in the unfused path).
    """
    a = prior.shape[-1]
    a_iota = jnp.arange(a, dtype=jnp.uint32)

    def lane(vl, l):
        path0 = jnp.full((max_depth,), UNVISITED, jnp.int32).at[0].set(0)

        def cond(c):
            _, depth, _, _, _, stop = c
            return (~stop) & (depth < max_depth - 1)

        def body(c):
            node, depth, _, path, level, _ = c
            kids = children[node]
            has_child = (kids != UNVISITED).astype(jnp.float32)
            cidx = jnp.maximum(kids, 0)
            parent_n = visit[node] + vl[node]
            scores = uct_scores_ref(
                visit[cidx][None], value[cidx][None], vl[cidx][None],
                prior[node][None], legal[node][None], has_child[None],
                parent_n[None], player[node][None],
                c_uct=c_uct, vl_weight=vl_weight, prior_w=prior_w,
                use_puct=use_puct)[0]
            scores = scores + tie_break_noise(seed, l, level, a_iota)
            act = jnp.argmax(scores).astype(jnp.int32)
            child = kids[act]
            nxt = jnp.where(child == UNVISITED, node, child)
            safe = jnp.maximum(child, 0)
            stop = (child == UNVISITED) | (terminal[safe] > 0) \
                | ~(expanded[safe] > 0)
            depth = depth + jnp.where(child == UNVISITED, 0, 1)
            path = path.at[depth].set(nxt)
            return nxt, depth, act, path, level + 1, stop

        init = (jnp.int32(0), jnp.int32(0), jnp.int32(a - 1), path0,
                jnp.int32(0), jnp.bool_(False))
        node, depth, act, path, _, _ = jax.lax.while_loop(cond, body, init)

        can_expand = (children[node, act] == UNVISITED) \
            & ~(terminal[node] > 0) \
            & (visit[node] + vl[node] >= expand_threshold) \
            & (expanded[node] > 0)

        valid = path != UNVISITED
        vl = vl.at[jnp.maximum(path, 0)].add(jnp.where(valid, 1.0, 0.0))
        return vl, (path, depth, node, act, can_expand)

    vl, (paths, depth, leaf, act, can_exp) = jax.lax.scan(
        lane, vloss, jnp.arange(lanes, dtype=jnp.int32))
    return paths, depth, leaf, act, can_exp, vl


def mcts_backup_ref(visit, value, paths, val_sum, playouts: float):
    """Exact scatter-add backup for one game's iteration.

    ``paths i32[L, D]`` (``UNVISITED`` pad), ``val_sum f32[L]`` (summed
    black-perspective returns per lane); every valid path entry gains
    ``playouts`` visits and its lane's ``val_sum``.  Identical arithmetic
    to the unfused ``MCTS._simulate`` backup.
    """
    d = paths.shape[-1]
    flat = paths.reshape(-1)
    ok = flat != UNVISITED
    safe = jnp.maximum(flat, 0)
    w = jnp.where(ok, 1.0, 0.0)
    vrep = jnp.repeat(val_sum, d)
    visit = visit.at[safe].add(w * playouts)
    value = value.at[safe].add(jnp.where(ok, vrep, 0.0))
    return visit, value
