"""Fused MCTS superstep kernels: batched select + scatter-add backup."""
from repro.kernels.mcts_step.ops import mcts_backup, mcts_select

__all__ = ["mcts_backup", "mcts_select"]
