"""Jitted dispatch wrappers for the fused MCTS superstep kernels.

Same dispatch contract as ``kernels/uct_select/ops.py``: the Pallas
kernels run on TPU (or anywhere under ``interpret=True`` for CPU
validation), the pure-jnp oracle elsewhere — so ``repro.core.mcts`` calls
one function and the backend picks the implementation.

Both entry points take **batched** slabs with a leading game axis
(``[G, N]`` / ``[G, N, A]``): the fused search operates on all games of a
``search_batch`` directly (``grid=(G,)`` in the kernel, ``vmap`` of the
single-game oracle on CPU) instead of relying on vmap-of-``pallas_call``
batching rules.

Traced-vs-static: ``c_uct`` / ``vl_weight`` / ``prior_w`` / ``seed`` are
traced per-game operands (scalar or ``[G]``; values never recompile);
``lanes`` / ``max_depth`` / ``expand_threshold`` / ``use_puct`` /
``playouts`` are static shape/program parameters, and the *presence* of
``prior_w`` selects the blended scoring program — identical to the
``uct_select`` contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad2, round_up
from repro.kernels.mcts_step.kernel import (LANE, mcts_backup_pallas,
                                            mcts_select_pallas)
from repro.kernels.mcts_step.ref import mcts_backup_ref, mcts_select_ref

UNVISITED = -1


def _per_game(x, g: int, dtype=jnp.float32):
    """Broadcast a scalar-or-``[G]`` traced knob to a ``[G]`` vector."""
    return jnp.broadcast_to(jnp.asarray(x, dtype), (g,))


@functools.partial(jax.jit, static_argnames=(
    "lanes", "max_depth", "expand_threshold", "use_puct", "interpret"))
def mcts_select(visit, value, vloss, prior, legal, children, expanded,
                terminal, player, seed, *, c_uct, vl_weight, prior_w=None,
                lanes: int, max_depth: int, expand_threshold: int = 1,
                use_puct: bool = False, interpret: bool = False):
    """All ``lanes`` descents for every game of a batch; see ref.py.

    ``visit/value/vloss/player`` ``f32[G, N]``; ``expanded/terminal``
    ``bool[G, N]``; ``prior`` ``f32[G, N, A]``; ``legal`` ``bool[G, N,
    A]``; ``children`` ``i32[G, N, A]``; ``seed`` ``u32[G]``.  Returns
    ``(paths i32[G, L, D], depth i32[G, L], leaf i32[G, L], act
    i32[G, L], can_expand bool[G, L], vloss f32[G, N])``.
    """
    g = visit.shape[0]
    legal = legal.astype(jnp.float32)
    expanded = expanded.astype(jnp.float32)
    terminal = terminal.astype(jnp.float32)
    seed = _per_game(seed, g, jnp.uint32)
    c = _per_game(c_uct, g)
    vlw = _per_game(vl_weight, g)
    pw = None if prior_w is None else _per_game(prior_w, g)
    use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        def one(vi, va, vl, pr, lg, ch, ex, te, pl_, sd, cc, vw, *rest):
            return mcts_select_ref(
                vi, va, vl, pr, lg, ch, ex, te, pl_, sd,
                c_uct=cc, vl_weight=vw,
                prior_w=rest[0] if rest else None,
                use_puct=use_puct, lanes=lanes, max_depth=max_depth,
                expand_threshold=expand_threshold)
        args = (visit, value, vloss, prior, legal, children, expanded,
                terminal, player, seed, c, vlw)
        out = jax.vmap(one)(*args) if pw is None \
            else jax.vmap(one)(*args, pw)
        paths, depth, leaf, act, can_exp, vl = out
        return paths, depth, leaf, act, can_exp, vl
    a = prior.shape[-1]
    ap = round_up(a, LANE)
    n = visit.shape[1]
    # pad the action axis: illegal zero-prior lanes can never win argmax
    pad3 = jax.vmap(lambda x: pad2(x, n, ap))
    prior_p = pad3(prior)
    legal_p = pad3(legal)
    kids_p = jnp.pad(children, ((0, 0), (0, 0), (0, ap - a)),
                     constant_values=UNVISITED) if ap != a else children
    paths, depth, leaf, act, can_exp, vl = mcts_select_pallas(
        visit, value, vloss, prior_p, legal_p, kids_p, expanded, terminal,
        player, seed, c, vlw, pw, lanes=lanes, max_depth=max_depth,
        expand_threshold=expand_threshold, use_puct=use_puct,
        interpret=interpret)
    return paths, depth, leaf, act, can_exp != 0, vl


@functools.partial(jax.jit, static_argnames=("playouts", "interpret"))
def mcts_backup(visit, value, paths, val_sum, *, playouts: float = 1.0,
                interpret: bool = False):
    """Scatter-add backup over every game/lane path; see ref.py.

    ``visit/value f32[G, N]``, ``paths i32[G, L, D]``, ``val_sum
    f32[G, L]`` -> updated ``(visit, value)``.  ``playouts`` is the
    static per-leaf playout count ``P`` (each path entry gains ``P``
    visits).
    """
    use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        return jax.vmap(
            functools.partial(mcts_backup_ref, playouts=playouts))(
                visit, value, paths, val_sum)
    return mcts_backup_pallas(visit, value, paths, val_sum,
                              playouts=playouts, interpret=interpret)
