"""Shared tile-padding helpers for the kernel dispatch wrappers.

Every ``kernels/*/ops.py`` dispatcher pads its operands up to the kernel's
tile multiples before the ``pallas_call`` and slices the result back.  The
helpers used to be copy-pasted per kernel (``_pad2`` / ``_pad_to`` /
``_pad_seq``); they live here now so a tiling bug is fixed once.

All helpers are no-ops (returning the input array unchanged, with zero pad
width where reported) when the shape already aligns — callers can branch on
that to skip the pad+slice round trip entirely (``uct_select.ops`` does).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad2(x: jax.Array, rows_to: int, cols_to: int) -> jax.Array:
    """Zero-pad a 2-D array up to ``(rows_to, cols_to)``.

    The row/col targets are absolute sizes (callers round up to their tile
    multiples first); equal sizes return ``x`` unchanged.
    """
    pr = rows_to - x.shape[0]
    pc = cols_to - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def pad_to_multiple(x: jax.Array, mult: int) -> jax.Array:
    """Zero-pad a 1-D array so its length is a multiple of ``mult``."""
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.pad(x, (0, pad))
    return x


def pad_axis(x: jax.Array, mult: int, axis: int) -> tuple[jax.Array, int]:
    """Zero-pad ``axis`` of ``x`` to a multiple of ``mult``.

    Returns ``(padded, pad_width)`` so callers can slice the kernel output
    back and decide whether padded positions need masking.
    """
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    width = [(0, 0)] * x.ndim
    width[axis] = (0, pad)
    return jnp.pad(x, width), pad


def round_up(n: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is ``>= n``."""
    return -(-n // mult) * mult
