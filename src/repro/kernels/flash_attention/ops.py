"""Dispatch wrapper for flash attention.

TPU -> Pallas kernel; other backends -> the memory-efficient chunked XLA
implementation in ``repro.models.attention`` (same math, scan over query
blocks) so large shapes stay lowerable in the CPU dry-run.  ``interpret=True``
forces the Pallas path for validation.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.common import pad_axis
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "kv_offset", "bq", "bk",
    "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    kv_offset: int = 0, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """q [B, Hq, Sq, D]; k, v [B, Hkv, Sk, D] -> [B, Hq, Sq, D]."""
    use_pallas = interpret or jax.default_backend() == "tpu"
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap, scale=scale,
                             kv_offset=kv_offset)
    sq, sk = q.shape[2], k.shape[2]
    bq_eff = min(bq, max(8, sq))
    bk_eff = min(bk, max(8, sk))
    qp, pq = pad_axis(q, bq_eff, 2)
    kp, pk = pad_axis(k, bk_eff, 2)
    vp, _ = pad_axis(v, bk_eff, 2)
    if pk:
        # padded KV columns must never win the max: rely on causal/window
        # masks only if they cover them; otherwise mask via kv_offset trick
        # (padded kpos > all qpos when causal). For non-causal, forbid pad.
        assert causal, "KV padding requires causal masking"
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, window=window, softcap=softcap,
        scale=scale, kv_offset=kv_offset, bq=bq_eff, bk=bk_eff,
        interpret=interpret)
    return out[:, :, :sq]
