"""Pallas TPU flash attention (forward): online softmax over KV blocks.

Hardware adaptation (DESIGN.md §2): blocked so the MXU sees aligned
``(BQ, D) x (D, BK)`` matmuls while the working set (one Q tile, one KV tile,
f32 accumulators) stays in VMEM: with BQ = BK = 128 and D = 128 that is
~260 KiB per step — comfortably double-bufferable in the ~16 MiB of a v5e
core.  Supports causal masking, sliding window, Gemma-2 logit softcap and
GQA (KV heads indexed via the BlockSpec index map — no KV replication in
HBM).

Grid ``(B, Hq, Sq/BQ, Sk/BK)``: the minor-most KV dimension iterates
sequentially on TPU, carrying the running max / denominator / accumulator in
VMEM scratch (the standard online-softmax recurrence).  Fully-masked KV
blocks (beyond the causal frontier or outside the window) still issue on this
simple grid; the cost model in EXPERIMENTS.md §Perf accounts for the ~2x
causal saving a skip-list grid would add on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, softcap: float,
                  kv_offset: int, bq: int, bk: int, kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + kv_offset
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows: keep exp argument finite
    p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(
        jnp.where(m_prev <= NEG_INF / 2, NEG_INF, m_prev - m_new))
    alpha = jnp.where(m_new <= NEG_INF / 2, 0.0, alpha)
    l_new = alpha * l_prev + p.sum(axis=1, keepdims=True)
    acc = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(kj == kv_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, scale: float | None = None,
                           kv_offset: int = 0, bq: int = DEFAULT_BQ,
                           bk: int = DEFAULT_BK, interpret: bool = False):
    """q [B, Hq, Sq, D]; k, v [B, Hkv, Sk, D] (dims divisible by bq/bk)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    scale = scale if scale is not None else d ** -0.5
    kv_blocks = sk // bk

    q_spec = pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, bk, d),
                           lambda b_, h, i, j: (b_, h // g, j, 0))
    o_spec = pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0))

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, kv_offset=kv_offset, bq=bq, bk=bk,
        kv_blocks=kv_blocks)

    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(b, hq, sq // bq, kv_blocks),
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
