"""Pure-jnp oracle for attention (causal / sliding-window / softcap / GQA).

Materialises the full [Sq, Sk] score matrix — the ground truth the blocked
kernel must match on every swept shape/dtype.
"""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: float | None = None,
                  kv_offset: int = 0):
    """q [B, Hq, Sq, D]; k, v [B, Hkv, Sk, D]; Hq % Hkv == 0.

    ``kv_offset``: absolute position of q[0] relative to k[0] (decode: the
    query sits at the end of the cache, offset = Sk - Sq).
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None] + kv_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    denom = p.sum(axis=-1, keepdims=True)
    p = jnp.where(denom > 0, p / jnp.maximum(denom, 1e-30), 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
