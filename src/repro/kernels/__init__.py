"""Pallas TPU kernels for the compute hot-spots.

Four kernels, each a ``kernel.py`` (``pl.pallas_call`` + explicit BlockSpec
VMEM tiling), ``ops.py`` (jitted dispatch wrapper: Pallas on TPU, oracle math
on other backends), and ``ref.py`` (pure-jnp oracle):

* ``fma_stream``  — the paper's own micro-benchmark loop
  ``c[j] = a[j]*b[j] + c[j]`` (Figs. 6-8), tiled for VMEM streaming.
* ``uct_select``  — the UCT/PUCT edge-scoring inner loop of parallel MCTS
  under virtual loss (the per-node hot path of selection).
* ``flash_attention`` — blocked online-softmax attention (causal, sliding
  window, logit softcap, GQA) for the long-context serving shapes.
* ``mcts_step``   — the fused MCTS superstep: all selection lanes of one
  iteration descend over VMEM-resident tree slabs, plus the matching
  scatter-add backup (``repro.core.mcts`` fused path).
"""
