"""Jitted dispatch wrapper for ``uct_scores``: Pallas on TPU, oracle on CPU.

Pads the action axis to a 128-lane multiple and the batch axis to the row
tile, calls the kernel, and slices back.  ``repro.core.mcts`` routes its
edge scoring through here so the kernel and the search share one call site.

``c_uct`` / ``vl_weight`` / ``prior_w`` are **traced** operands (Python
float or per-row ``[B]`` array, broadcast to a ``[B, 1]`` column for the
kernel) — never static arguments — so scoring N distinct search
configurations compiles exactly once.  Only ``use_puct``, ``interpret``,
and the *presence* of ``prior_w`` (the evaluation-lane blend: the guided
and unguided programs differ in arithmetic, not in its weight values)
select a program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import pad2, round_up
from repro.kernels.uct_select.kernel import LANE, ROWS, uct_scores_pallas
from repro.kernels.uct_select.ref import per_row, uct_scores_ref


@functools.partial(jax.jit, static_argnames=("use_puct", "interpret"))
def uct_scores(child_visit, child_value, child_vloss, prior, legal,
               has_child, parent_n, player, *, c_uct=0.9, vl_weight=1.0,
               prior_w=None, use_puct: bool = False,
               interpret: bool = False):
    """Batched edge scores [B, A]; see ref.py for semantics.

    ``c_uct`` / ``vl_weight`` accept a scalar (one configuration for the
    whole batch) or an ``[B]`` array (one per row); both are traced, so
    changing their values never recompiles.  ``prior_w`` (same shapes,
    also traced) selects the blended UCT/PUCT scoring: ``0`` rows score
    exactly like the static UCT program, ``1`` rows exactly like PUCT,
    and any mix shares one compiled program — ``use_puct`` is ignored
    when it is given.
    """
    use_pallas = interpret or jax.default_backend() == "tpu"
    legal = legal.astype(jnp.float32)
    has_child = has_child.astype(jnp.float32)
    if not use_pallas:
        return uct_scores_ref(child_visit, child_value, child_vloss, prior,
                              legal, has_child, parent_n, player,
                              c_uct=c_uct, vl_weight=vl_weight,
                              prior_w=prior_w, use_puct=use_puct)
    b, a = child_visit.shape
    bp = round_up(b, ROWS)
    ap = round_up(a, LANE)
    aligned = bp == b and ap == a   # skip the pad+slice round trip
    args2 = [pad2(x.astype(jnp.float32), bp, ap)
             for x in (child_visit, child_value, child_vloss, prior, legal,
                       has_child)]
    pn = jnp.pad(parent_n.astype(jnp.float32), (0, bp - b))[:, None]
    pidx = jnp.pad(player.astype(jnp.float32), (0, bp - b))[:, None]
    cols = [jnp.pad(per_row(x, b)[:, 0], (0, bp - b))[:, None]
            for x in (c_uct, vl_weight)]
    if prior_w is not None:
        # prefold the per-row legal count (the uniform-prior denominator)
        # so the kernel's blend matches the oracle's reduction exactly
        n_legal = jnp.pad(legal.sum(-1), (0, bp - b))[:, None]
        pw = jnp.pad(per_row(prior_w, b)[:, 0], (0, bp - b))[:, None]
        out = uct_scores_pallas(*args2, pn, pidx, *cols, pw, n_legal,
                                use_puct=False, interpret=interpret)
    else:
        out = uct_scores_pallas(*args2, pn, pidx, *cols,
                                use_puct=use_puct, interpret=interpret)
    return out if aligned else out[:b, :a]
