from repro.kernels.uct_select.ops import uct_scores

__all__ = ["uct_scores"]
