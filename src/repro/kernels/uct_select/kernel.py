"""Pallas kernel: batched UCT/PUCT edge scoring under virtual loss.

Hardware adaptation (DESIGN.md §2): FUEGO's selection walks pointers and does
scalar math per child — exactly what the paper found the Phi's in-order cores
to be slow at.  On TPU the per-node child statistics are already a dense
``[batch_of_nodes, actions]`` tile, so one VPU pass computes every child's
exploitation + exploration score; the transcendentals (log/sqrt) vectorise
over the 8x128 VREG lanes.

Tiling: one grid step owns a ``(ROWS, A_pad)`` tile of each [B, A] statistic
(A padded to a lane multiple of 128 by ``ops.py``).  Per-row scalars
(parent_n, player, and the *traced* search knobs c_uct / vl_weight) ride
along as ``(ROWS, 1)`` tiles and broadcast over the action lanes — so one
compiled kernel scores edges for any mix of per-row search configurations
(the tournament-multiplexing contract; only ``use_puct`` stays a Python
constant).  For the 9x9 Go action space (A=82 -> 128) and ROWS=8 that is
8 tiles x <= 4 KiB — tiny, letting many node-batches pipeline.

The evaluation lane (PR 7) adds a third per-row column, ``prior_w``: the
blended kernel computes *both* the UCT score (over the uniform prior
recomputed from the legal tile) and the PUCT score (over the stored
neural prior) in the same VPU pass and mixes them per row, so the guided
vs unguided choice is data, not a compiled branch — one kernel serves any
mix of blend weights, and ``prior_w = 0`` reproduces the UCT program's
arithmetic bit for bit (ref.py documents why).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.uct_select.ref import BIG, FPU

ROWS = 8
LANE = 128


def _uct_kernel(visit_ref, value_ref, vloss_ref, prior_ref, legal_ref,
                hasc_ref, parent_ref, player_ref, cuct_ref, vlw_ref,
                out_ref, *, use_puct: bool):
    n = visit_ref[...]
    v = value_ref[...]
    vl = vloss_ref[...]
    prior = prior_ref[...]
    legal = legal_ref[...]
    has_child = hasc_ref[...]
    parent_n = parent_ref[...]          # (ROWS, 1)
    player = player_ref[...]            # (ROWS, 1)
    c_uct = cuct_ref[...]               # (ROWS, 1) traced per-row knob
    vl_weight = vlw_ref[...]            # (ROWS, 1) traced per-row knob

    n_eff = jnp.maximum(n + vl, 1.0)
    q = (player * v - vl * vl_weight) / n_eff
    if use_puct:
        root_term = jnp.sqrt(parent_n)
        u = c_uct * prior * root_term / (1.0 + n + vl)
        score = jnp.where(has_child != 0, q + u, c_uct * prior * root_term)
    else:
        pn = jnp.maximum(parent_n, 2.0)
        u = c_uct * jnp.sqrt(jnp.log(pn) / n_eff)
        score = jnp.where(has_child != 0, q + u, FPU + prior)
    out_ref[...] = jnp.where(legal != 0, score, -BIG)


def _uct_blend_kernel(visit_ref, value_ref, vloss_ref, prior_ref, legal_ref,
                      hasc_ref, nleg_ref, parent_ref, player_ref, cuct_ref,
                      vlw_ref, pw_ref, out_ref):
    n = visit_ref[...]
    v = value_ref[...]
    vl = vloss_ref[...]
    prior = prior_ref[...]
    legal = legal_ref[...]
    has_child = hasc_ref[...]
    n_legal = nleg_ref[...]             # (ROWS, 1) precomputed legal count
    parent_n = parent_ref[...]          # (ROWS, 1)
    player = player_ref[...]            # (ROWS, 1)
    c_uct = cuct_ref[...]               # (ROWS, 1) traced per-row knob
    vl_weight = vlw_ref[...]            # (ROWS, 1) traced per-row knob
    w = pw_ref[...]                     # (ROWS, 1) traced prior blend

    n_eff = jnp.maximum(n + vl, 1.0)
    q = (player * v - vl * vl_weight) / n_eff
    # UCT half over the uniform prior recomputed from the legal tile: the
    # per-row legal count is prefolded host-side (ops.py) so the padded
    # action lanes cannot perturb the reduction
    uniform = legal / jnp.maximum(n_legal, 1.0)
    pn = jnp.maximum(parent_n, 2.0)
    u_uct = c_uct * jnp.sqrt(jnp.log(pn) / n_eff)
    s_uct = jnp.where(has_child != 0, q + u_uct, FPU + uniform)
    # PUCT half over the stored (evaluation-lane) prior
    root_term = jnp.sqrt(parent_n)
    u_puct = c_uct * prior * root_term / (1.0 + n + vl)
    s_puct = jnp.where(has_child != 0, q + u_puct,
                       c_uct * prior * root_term)
    score = (1.0 - w) * s_uct + w * s_puct
    out_ref[...] = jnp.where(legal != 0, score, -BIG)


def uct_scores_pallas(child_visit, child_value, child_vloss, prior, legal,
                      has_child, parent_n, player, c_uct, vl_weight,
                      prior_w=None, n_legal=None, *,
                      use_puct: bool, interpret: bool = False):
    """Inputs [B, A_pad] (f32; masks as f32 0/1); per-row [B, 1] columns.

    ``parent_n`` / ``player`` / ``c_uct`` / ``vl_weight`` are the per-row
    columns — the last two are traced search knobs, not constants.  With
    ``prior_w`` (and its companion ``n_legal`` legal-count column) the
    blended kernel runs instead and ``use_puct`` is ignored.
    """
    b, a = child_visit.shape
    assert b % ROWS == 0 and a % LANE == 0, (b, a)
    tile = pl.BlockSpec((ROWS, a), lambda i: (i, 0))
    col = pl.BlockSpec((ROWS, 1), lambda i: (i, 0))
    if prior_w is not None:
        assert n_legal is not None
        return pl.pallas_call(
            _uct_blend_kernel,
            out_shape=jax.ShapeDtypeStruct((b, a), jnp.float32),
            grid=(b // ROWS,),
            in_specs=[tile, tile, tile, tile, tile, tile,
                      col, col, col, col, col, col],
            out_specs=tile,
            interpret=interpret,
        )(child_visit, child_value, child_vloss, prior, legal, has_child,
          n_legal, parent_n, player, c_uct, vl_weight, prior_w)
    return pl.pallas_call(
        functools.partial(_uct_kernel, use_puct=use_puct),
        out_shape=jax.ShapeDtypeStruct((b, a), jnp.float32),
        grid=(b // ROWS,),
        in_specs=[tile, tile, tile, tile, tile, tile, col, col, col, col],
        out_specs=tile,
        interpret=interpret,
    )(child_visit, child_value, child_vloss, prior, legal, has_child,
      parent_n, player, c_uct, vl_weight)
