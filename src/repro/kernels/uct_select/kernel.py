"""Pallas kernel: batched UCT/PUCT edge scoring under virtual loss.

Hardware adaptation (DESIGN.md §2): FUEGO's selection walks pointers and does
scalar math per child — exactly what the paper found the Phi's in-order cores
to be slow at.  On TPU the per-node child statistics are already a dense
``[batch_of_nodes, actions]`` tile, so one VPU pass computes every child's
exploitation + exploration score; the transcendentals (log/sqrt) vectorise
over the 8x128 VREG lanes.

Tiling: one grid step owns a ``(ROWS, A_pad)`` tile of each [B, A] statistic
(A padded to a lane multiple of 128 by ``ops.py``).  Per-row scalars
(parent_n, player, and the *traced* search knobs c_uct / vl_weight) ride
along as ``(ROWS, 1)`` tiles and broadcast over the action lanes — so one
compiled kernel scores edges for any mix of per-row search configurations
(the tournament-multiplexing contract; only ``use_puct`` stays a Python
constant).  For the 9x9 Go action space (A=82 -> 128) and ROWS=8 that is
8 tiles x <= 4 KiB — tiny, letting many node-batches pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.uct_select.ref import BIG, FPU

ROWS = 8
LANE = 128


def _uct_kernel(visit_ref, value_ref, vloss_ref, prior_ref, legal_ref,
                hasc_ref, parent_ref, player_ref, cuct_ref, vlw_ref,
                out_ref, *, use_puct: bool):
    n = visit_ref[...]
    v = value_ref[...]
    vl = vloss_ref[...]
    prior = prior_ref[...]
    legal = legal_ref[...]
    has_child = hasc_ref[...]
    parent_n = parent_ref[...]          # (ROWS, 1)
    player = player_ref[...]            # (ROWS, 1)
    c_uct = cuct_ref[...]               # (ROWS, 1) traced per-row knob
    vl_weight = vlw_ref[...]            # (ROWS, 1) traced per-row knob

    n_eff = jnp.maximum(n + vl, 1.0)
    q = (player * v - vl * vl_weight) / n_eff
    if use_puct:
        root_term = jnp.sqrt(parent_n)
        u = c_uct * prior * root_term / (1.0 + n + vl)
        score = jnp.where(has_child != 0, q + u, c_uct * prior * root_term)
    else:
        pn = jnp.maximum(parent_n, 2.0)
        u = c_uct * jnp.sqrt(jnp.log(pn) / n_eff)
        score = jnp.where(has_child != 0, q + u, FPU + prior)
    out_ref[...] = jnp.where(legal != 0, score, -BIG)


def uct_scores_pallas(child_visit, child_value, child_vloss, prior, legal,
                      has_child, parent_n, player, c_uct, vl_weight, *,
                      use_puct: bool, interpret: bool = False):
    """Inputs [B, A_pad] (f32; masks as f32 0/1); per-row [B, 1] columns.

    ``parent_n`` / ``player`` / ``c_uct`` / ``vl_weight`` are the per-row
    columns — the last two are traced search knobs, not constants.
    """
    b, a = child_visit.shape
    assert b % ROWS == 0 and a % LANE == 0, (b, a)
    tile = pl.BlockSpec((ROWS, a), lambda i: (i, 0))
    col = pl.BlockSpec((ROWS, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_uct_kernel, use_puct=use_puct),
        out_shape=jax.ShapeDtypeStruct((b, a), jnp.float32),
        grid=(b // ROWS,),
        in_specs=[tile, tile, tile, tile, tile, tile, col, col, col, col],
        out_specs=tile,
        interpret=interpret,
    )(child_visit, child_value, child_vloss, prior, legal, has_child,
      parent_n, player, c_uct, vl_weight)
