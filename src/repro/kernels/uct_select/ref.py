"""Pure-jnp oracle for UCT/PUCT edge scoring under virtual loss.

This is the arithmetic every selection step of every lane performs at every
tree level — the paper's hottest loop (FUEGO spends its selection time here;
its low integer/scalar throughput on the Phi is one of the paper's findings).

``c_uct`` and ``vl_weight`` are *traced* operands — a Python float or a
per-row ``[B]`` array — never compile-time constants, so one compiled
program scores edges for any mix of search configurations (the per-slot
tournament multiplexing contract; see docs/ARCHITECTURE.md).  A scalar is
broadcast over the batch, which performs bit-identical arithmetic to the
historical static-constant path.

Semantics (matches ``repro.core.mcts.MCTS._edge_scores`` exactly):
  q    = (player * value - vloss * vl_weight) / max(n + vloss, 1)
  uct  : u = c * sqrt(log(max(parent_n, 2)) / max(n + vloss, 1))
         score = has_child ? q + u : FPU + prior
  puct : u = c * prior * sqrt(parent_n) / (1 + n + vloss)
         score = has_child ? q + u : c * prior * sqrt(parent_n)
  illegal edges score -BIG.

``prior_w`` (the evaluation-lane blend, PR 7) replaces the *static*
``use_puct`` branch with a traced per-row weight ``w``::

  score = (1 - w) * uct_score(uniform(legal)) + w * puct_score(prior)

The UCT half recomputes the uniform prior from the legal mask rather than
reading the stored (neural) prior, so ``w = 0`` is bit-identical to the
static UCT path over a uniform-prior tree whatever the evaluation lane
scattered into ``prior`` — both halves are always computed and the blend
is pure traced arithmetic, which is what lets one compiled dispatch serve
guided (``w > 0``) and unguided (``w = 0``) slots in the same pool.
"""
import jax.numpy as jnp

BIG = 1e9
FPU = 10.0


def per_row(x, b: int) -> jnp.ndarray:
    """Broadcast a scalar-or-``[B]`` traced knob to a ``[B, 1]`` column."""
    return jnp.broadcast_to(jnp.asarray(x, jnp.float32), (b,))[:, None]


def uct_scores_ref(child_visit, child_value, child_vloss, prior, legal,
                   has_child, parent_n, player, *, c_uct, vl_weight,
                   use_puct: bool, prior_w=None):
    """All inputs [B, A] except parent_n, player [B]; returns scores [B, A].

    ``c_uct`` / ``vl_weight`` are traced: float or [B] (broadcast per row).
    ``prior_w`` (float or [B], also traced) switches to the blended
    UCT/PUCT scoring described in the module docstring; ``use_puct`` is
    ignored when it is given.
    """
    b = child_visit.shape[0]
    c = per_row(c_uct, b)
    vlw = per_row(vl_weight, b)
    n_eff = jnp.maximum(child_visit + child_vloss, 1.0)
    q = (player[:, None] * child_value - child_vloss * vlw) / n_eff
    if prior_w is not None:
        w = per_row(prior_w, b)
        m = legal.astype(jnp.float32)
        uniform = m / jnp.maximum(m.sum(-1, keepdims=True), 1.0)
        pn = jnp.maximum(parent_n, 2.0)[:, None]
        u_uct = c * jnp.sqrt(jnp.log(pn) / n_eff)
        s_uct = jnp.where(has_child, q + u_uct, FPU + uniform)
        root_term = jnp.sqrt(parent_n)[:, None]
        u_puct = c * prior * root_term / (1.0 + child_visit + child_vloss)
        s_puct = jnp.where(has_child, q + u_puct, c * prior * root_term)
        score = (1.0 - w) * s_uct + w * s_puct
    elif use_puct:
        root_term = jnp.sqrt(parent_n)[:, None]
        u = c * prior * root_term / (1.0 + child_visit + child_vloss)
        score = jnp.where(has_child, q + u, c * prior * root_term)
    else:
        pn = jnp.maximum(parent_n, 2.0)[:, None]
        u = c * jnp.sqrt(jnp.log(pn) / n_eff)
        score = jnp.where(has_child, q + u, FPU + prior)
    return jnp.where(legal, score, -BIG)
