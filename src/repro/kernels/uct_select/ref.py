"""Pure-jnp oracle for UCT/PUCT edge scoring under virtual loss.

This is the arithmetic every selection step of every lane performs at every
tree level — the paper's hottest loop (FUEGO spends its selection time here;
its low integer/scalar throughput on the Phi is one of the paper's findings).

Semantics (matches ``repro.core.mcts.MCTS._edge_scores`` exactly):
  q    = (player * value - vloss * vl_weight) / max(n + vloss, 1)
  uct  : u = c * sqrt(log(max(parent_n, 2)) / max(n + vloss, 1))
         score = has_child ? q + u : FPU + prior
  puct : u = c * prior * sqrt(parent_n) / (1 + n + vloss)
         score = has_child ? q + u : c * prior * sqrt(parent_n)
  illegal edges score -BIG.
"""
import jax.numpy as jnp

BIG = 1e9
FPU = 10.0


def uct_scores_ref(child_visit, child_value, child_vloss, prior, legal,
                   has_child, parent_n, player, *, c_uct: float,
                   vl_weight: float, use_puct: bool):
    """All inputs [B, A] except parent_n, player [B]; returns scores [B, A]."""
    n_eff = jnp.maximum(child_visit + child_vloss, 1.0)
    q = (player[:, None] * child_value - child_vloss * vl_weight) / n_eff
    if use_puct:
        root_term = jnp.sqrt(parent_n)[:, None]
        u = c_uct * prior * root_term / (1.0 + child_visit + child_vloss)
        score = jnp.where(has_child, q + u, c_uct * prior * root_term)
    else:
        pn = jnp.maximum(parent_n, 2.0)[:, None]
        u = c_uct * jnp.sqrt(jnp.log(pn) / n_eff)
        score = jnp.where(has_child, q + u, FPU + prior)
    return jnp.where(legal, score, -BIG)
