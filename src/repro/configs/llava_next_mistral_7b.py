"""llava-next-mistral-7b — LLaVA-NeXT on a Mistral-7B backbone.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The anyres vision tower is a STUB per
the brief: ``input_specs`` provides precomputed patch embeddings
[B, 2880, 1024] (5 anyres tiles x 576 CLIP patches), projected by the
standard 2-layer MLP into the LM sequence ahead of the text tokens.
"""
from repro.config import AttnConfig, ModelConfig, register

ANYRES_PATCHES = 2880  # 5 tiles x 24x24 patches


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        d_ff=14336,
        vocab_size=32000,
        attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                        rope_theta=1000000.0, kv_seq_shard=True),
        frontend_tokens=ANYRES_PATCHES,
        act="swiglu",
        max_seq_len=32768,
    )


register("llava-next-mistral-7b", config, skip_shapes={
    "long_500k": "pure full-attention backbone: 512k decode context is out "
                 "of contract (quadratic prefill / unbounded KV)",
})
