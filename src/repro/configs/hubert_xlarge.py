"""hubert-xlarge — HuBERT X-Large audio encoder.

[arXiv:2106.07447; unverified] 48L d_model=1280 16H d_ff=5120 vocab=504
(masked-prediction codebook).  Encoder-only (bidirectional, no decode step);
the conv waveform frontend is a STUB per the brief — ``input_specs`` provides
precomputed frame embeddings [B, S, 1024].
"""
from repro.config import AttnConfig, ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        d_ff=5120,
        vocab_size=504,
        attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=80,
                        rope_theta=10000.0, causal=False),
        act="gelu",
        max_seq_len=32768,
    )


register("hubert-xlarge", config, skip_shapes={
    "decode_32k": "encoder-only architecture: no autoregressive decode step",
    "long_500k": "encoder-only architecture: no autoregressive decode step",
})
