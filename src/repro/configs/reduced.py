"""Reduced (smoke-test) variants of the assigned architectures.

Same family/topology — MoE stays MoE with a dense first layer, hybrid keeps
parallel attn+SSM heads, gemma2 keeps alternating windows and softcaps —
but small widths/layer counts/expert counts so one forward/train step runs
on a single CPU in seconds.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.config import ModelConfig, get_model_config


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    heads = min(cfg.attn.num_heads, 4) if cfg.attn.num_heads else 0
    kv = 0
    if heads:
        ratio = max(1, cfg.attn.num_heads // max(cfg.attn.num_kv_heads, 1))
        kv = max(1, heads // min(ratio, heads))
    attn = dataclasses.replace(
        cfg.attn,
        num_heads=heads, num_kv_heads=kv,
        head_dim=16 if heads else 0,
        window=min(cfg.attn.window, 16) if cfg.attn.window else 0,
        kv_seq_shard=False,
    )
    moe = cfg.moe
    if moe.num_experts:
        moe = dataclasses.replace(
            moe, num_experts=8, top_k=2,
            shared_experts=min(moe.shared_experts, 1),
            first_dense=min(moe.first_dense, 1),
            dense_ff=128 if moe.dense_ff else 0,
            # no token drops at smoke scale: keeps per-token determinism so
            # prefill<->decode consistency is exact
            capacity_factor=4.0)
    ssm = dataclasses.replace(
        cfg.ssm, d_state=16, head_dim=8, expand=2, chunk=16, conv_kernel=4,
        n_groups=1)
    n_layers = 3 if moe.num_experts and moe.first_dense else 2
    globals_ = tuple(g for g in (0,) if cfg.hybrid_global_layers)
    return dataclasses.replace(
        cfg,
        num_layers=n_layers,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=128,
        attn=attn, moe=moe, ssm=ssm,
        hybrid_global_layers=globals_,
        meta_tokens=8 if cfg.meta_tokens else 0,
        frontend_tokens=16 if cfg.frontend_tokens else 0,
        max_seq_len=256,
        # f32 at smoke scale: consistency tests check the *math* (chunked
        # SSD vs stepwise recurrence, cache vs training attention) without
        # bf16 accumulation noise; full configs stay bf16
        dtype="float32",
    )


def reduced(arch_id: str) -> ModelConfig:
    return reduce_config(get_model_config(arch_id))
