"""moonshot-v1-16b-a3b — Kimi/Moonlight 16B-A3B MoE.

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (GQA kv=16)
d_ff=1408 (per expert) vocab=163840, MoE 64 experts top-6, 2 shared experts,
first layer dense (DeepSeek-V3-style), dense layer d_ff=11264.
"""
from repro.config import AttnConfig, MoEConfig, ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        d_ff=1408,
        vocab_size=163840,
        attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                        rope_theta=50000.0),
        moe=MoEConfig(num_experts=64, top_k=6, shared_experts=2,
                      first_dense=1, dense_ff=11264,
                      capacity_factor=1.25),
        act="swiglu",
        max_seq_len=32768,
    )


register("moonshot-v1-16b-a3b", config, skip_shapes={
    "long_500k": "pure full-attention arch: 512k decode context is out of "
                 "contract (quadratic prefill / unbounded KV)",
})
