"""mamba2-2.7b — Mamba-2 2.7B (SSD, attention-free).

[arXiv:2405.21060; unverified] 64L d_model=2560 vocab=50280, ssm_state=128,
head_dim=64, expand=2 (d_inner=5120, 80 heads), conv kernel 4, chunk 128.
Attention-free: runs the ``long_500k`` shape (O(1) decode state).
"""
from repro.config import AttnConfig, ModelConfig, SSMConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        d_ff=0,
        vocab_size=50280,
        attn=AttnConfig(num_heads=0, num_kv_heads=0),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=128,
                      conv_kernel=4, n_groups=1),
        tie_embeddings=True,
        max_seq_len=1048576,
    )


register("mamba2-2.7b", config)
