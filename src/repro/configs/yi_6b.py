"""yi-6b — 01.AI Yi-6B dense (llama-architecture GQA).

[arXiv:2403.04652; hf] 32L d_model=4096 32H (GQA kv=4) d_ff=11008
vocab=64000.
"""
from repro.config import AttnConfig, ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        d_ff=11008,
        vocab_size=64000,
        attn=AttnConfig(num_heads=32, num_kv_heads=4, head_dim=128,
                        rope_theta=5000000.0, kv_seq_shard=True),
        act="swiglu",
        max_seq_len=32768,
    )


register("yi-6b", config, skip_shapes={
    "long_500k": "pure full-attention arch: 512k decode context is out of "
                 "contract (quadratic prefill / unbounded KV)",
})
