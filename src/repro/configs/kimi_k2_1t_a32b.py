"""kimi-k2-1t-a32b — Kimi K2, trillion-parameter MoE (paper-table).

[arXiv:2501.kimi2; unverified] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per expert) vocab=163840, MoE 384 experts top-8, 1 shared expert, first
layer dense (d_ff=18432).  Trains with Adafactor (factored second moment) —
1T params of Adam state does not fit 512 v5e chips (DESIGN.md §5).
"""
from repro.config import AttnConfig, MoEConfig, ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        d_ff=2048,
        vocab_size=163840,
        attn=AttnConfig(num_heads=64, num_kv_heads=8, head_dim=112,
                        rope_theta=50000.0, kv_seq_shard=True),
        moe=MoEConfig(num_experts=384, top_k=8, shared_experts=1,
                      first_dense=1, dense_ff=18432,
                      capacity_factor=1.25),
        act="swiglu",
        max_seq_len=131072,
    )


register("kimi-k2-1t-a32b", config, skip_shapes={
    "long_500k": "pure full-attention arch: 512k decode context is out of "
                 "contract (quadratic prefill / unbounded KV)",
})
