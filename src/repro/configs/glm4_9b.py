"""glm4-9b — THUDM GLM-4 9B dense.

[hf:THUDM/glm-4-9b; hf] 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552, RoPE, GQA.  Only 2 KV heads: the decode cache cannot be
head-sharded on a 16-way model axis, so the cache *sequence* is sharded and
decode attention merges shards via the LSE reduction (models/attention.py).
"""
from repro.config import AttnConfig, ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        num_layers=40,
        d_model=4096,
        d_ff=13696,
        vocab_size=151552,
        attn=AttnConfig(num_heads=32, num_kv_heads=2, head_dim=128,
                        rope_theta=10000.0, kv_seq_shard=True),
        act="swiglu",
        max_seq_len=131072,
    )


register("glm4-9b", config, skip_shapes={
    "long_500k": "pure full-attention arch: 512k decode context is out of "
                 "contract (quadratic prefill / unbounded KV)",
})
