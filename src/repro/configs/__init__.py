"""Assigned-architecture registry: importing this package registers every
``--arch`` id with ``repro.config``.  One module per architecture with the
exact published configuration (sources cited per module)."""
from repro.configs import (fuego9, gemma2_9b, glm4_9b, hubert_xlarge,
                           hymba_1p5b, kimi_k2_1t_a32b, llava_next_mistral_7b,
                           mamba2_2p7b, moonshot_v1_16b_a3b, phi3_medium_14b,
                           yi_6b)

__all__ = ["fuego9", "gemma2_9b", "glm4_9b", "hubert_xlarge", "hymba_1p5b",
           "kimi_k2_1t_a32b", "llava_next_mistral_7b", "mamba2_2p7b",
           "moonshot_v1_16b_a3b", "phi3_medium_14b", "yi_6b"]
