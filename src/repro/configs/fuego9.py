"""fuego9 — the paper's own application: tournament-setting parallel MCTS Go.

9x9 board, komi 6, Chinese rules (paper experimental setup); ``lanes`` is the
thread-count analogue swept by the benchmarks (FUEGO ran 1..240 threads on
the Phi).  Registered for the launcher; the LM shapes do not apply to it —
its dry-run cells are the distributed root-parallel self-play steps.
"""
from repro.config import MCTSConfig

SKIP_LM_SHAPES = "MCTS application: LM train/serve shapes do not apply"


def config() -> MCTSConfig:
    return MCTSConfig(
        board_size=9,
        komi=6.0,
        lanes=8,
        sims_per_move=256,
        max_nodes=8192,
        c_uct=0.9,
        virtual_loss=1.0,
        parallelism="tree",
        root_trees=256,
        affinity="compact",
    )
