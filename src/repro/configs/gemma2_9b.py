"""gemma2-9b — Google Gemma 2 9B.

[arXiv:2408.00118; hf] 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000.  Alternating local (4096 sliding window) / global attention,
attn logit softcap 50, final logit softcap 30, pre+post RMSNorm with (1+w)
scaling, GeGLU.
"""
from repro.config import AttnConfig, ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        d_ff=14336,
        vocab_size=256000,
        attn=AttnConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                        rope_theta=10000.0, window=4096,
                        alt_local_global=True, logit_softcap=50.0,
                        kv_seq_shard=True),
        act="geglu",
        final_logit_softcap=30.0,
        post_block_norm=True,
        max_seq_len=8192,
    )


register("gemma2-9b", config, skip_shapes={
    "long_500k": "half the layers are full-attention (global): 512k decode "
                 "is out of contract for the global-attention KV cache",
})
