"""hymba-1.5b — NVIDIA Hymba 1.5B hybrid (parallel attention + mamba heads).

[arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  Every block runs attention and an SSM mixer in
parallel on the same input and averages their normalised outputs; layers 0,
15, 31 use global attention, the rest sliding-window 1024; 128 learnable
meta tokens are prepended.  Hybrid: runs ``long_500k`` (bounded SWA KV +
O(1) SSM state; the 3 global layers keep full KV — linear, not quadratic).
"""
from repro.config import AttnConfig, ModelConfig, SSMConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        num_layers=32,
        d_model=1600,
        d_ff=5504,
        vocab_size=32001,
        attn=AttnConfig(num_heads=25, num_kv_heads=5, head_dim=64,
                        rope_theta=10000.0, window=1024,
                        kv_seq_shard=True),
        ssm=SSMConfig(d_state=16, head_dim=64, expand=2, chunk=128,
                      conv_kernel=4, n_groups=1),
        hybrid_global_layers=(0, 15, 31),
        meta_tokens=128,
        act="swiglu",
        max_seq_len=1048576,
    )


register("hymba-1.5b", config)
