"""phi3-medium-14b — Microsoft Phi-3 Medium.

[arXiv:2404.14219; unverified] 40L d_model=5120 40H (GQA kv=10) d_ff=17920
vocab=100352, RoPE + SwiGLU + GQA.
"""
from repro.config import AttnConfig, ModelConfig, register


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b",
        family="dense",
        num_layers=40,
        d_model=5120,
        d_ff=17920,
        vocab_size=100352,
        attn=AttnConfig(num_heads=40, num_kv_heads=10, head_dim=128,
                        rope_theta=10000.0, kv_seq_shard=True),
        act="swiglu",
        max_seq_len=131072,
    )


register("phi3-medium-14b", config, skip_shapes={
    "long_500k": "pure full-attention arch: 512k decode context is out of "
                 "contract (quadratic prefill / unbounded KV)",
})
