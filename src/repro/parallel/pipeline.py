"""GPipe-style pipeline parallelism over a mesh axis (the ``pod`` axis).

Alternative to pure cross-pod DP when even compressed gradient exchange is
too expensive: split the layer stack into one *stage per pod* and stream
microbatches through with ``collective_permute`` boundary handoffs.  The
classic GPipe schedule runs ``M + S - 1`` ticks for M microbatches and S
stages (bubble fraction (S-1)/(M+S-1)); activations cross the slow link once
per boundary instead of every gradient every step.

Implemented with ``shard_map`` over the stage axis: every device holds its
stage's layer slice (params are sharded layer-wise over the axis) and the
tick loop is a ``lax.scan`` whose carry is each stage's in-flight microbatch.
``pipeline_forward`` is the schedule core — it is validated numerically
against the unpartitioned stack in tests and lowered in the dry-run extras.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(layer_fn: Callable, mesh: Mesh, axis: str = "pod"):
    """Build fn(stage_params, x_microbatches) -> y_microbatches.

    ``layer_fn(params_slice, x) -> x`` applies one stage's layers.
    ``stage_params``: pytree with leading dim = n_stages (sharded over
    ``axis``).  ``x_microbatches``: [M, mb, ...] replicated along ``axis``.
    """
    n_stages = mesh.shape[axis]

    def staged(params_l, xs):
        # params_l: this stage's slice (leading dim 1) ; xs: [M, mb, ...]
        params_me = jax.tree.map(lambda a: a[0], params_l)
        stage = jax.lax.axis_index(axis)
        m = xs.shape[0]
        ticks = m + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry      # buf: [mb, ...] current stage input
            # stage s works on microbatch t - s when 0 <= t - s < m
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < m)
            x_in = jnp.where(
                stage == 0,
                xs[jnp.clip(mb_idx, 0, m - 1)],   # stage 0 pulls from feed
                buf)                               # others use handoff
            y = layer_fn(params_me, x_in)
            y = jnp.where(active, y, x_in)
            # last stage records its finished microbatch
            out_idx = t - (n_stages - 1)
            record = (stage == n_stages - 1) & (out_idx >= 0) & (out_idx < m)
            outs = jax.lax.cond(
                record,
                lambda o: o.at[jnp.clip(out_idx, 0, m - 1)].set(y),
                lambda o: o, outs)
            # hand off to the next stage
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(ticks))
        # every stage holds outs; only the last stage's copy is real -> share
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    # P(axis) is a prefix spec: every param leaf shards its leading (stage)
    # dim over ``axis``; microbatches are replicated along it.
    return shard_map(staged, mesh=mesh, in_specs=(P(axis), P()),
                     out_specs=P(), check_vma=False)
