"""PowerSGD gradient compression for the cross-pod all-reduce.

Between pods the gradient all-reduce crosses DCN (orders of magnitude slower
than ICI), so the pod axis is where compression pays.  Rank-r PowerSGD
(Vogels et al. 2019) with error feedback:

    M ~ P Q^T,  P = orthonormalise(M Q),  Q = M^T P

Only P and Q cross the slow link: a [m, n] gradient costs r*(m+n) instead of
m*n — e.g. a 4096x14336 block at rank 8 moves 0.25% of the bytes.  Error
feedback accumulates the residual locally so the compression error is
re-injected next step instead of biasing convergence.

Matrix leaves (>=2-D, both folded dims >= 8) are compressed; small leaves
pass through an uncompressed pmean.  Placeholders are size-0 arrays so the
state is a uniform pytree (checkpointable, shardable).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PowerSGDState(NamedTuple):
    q: Any        # per-leaf Q matrices (size-0 placeholder if uncompressed)
    error: Any    # per-leaf error-feedback residuals (same convention)


_EMPTY = lambda: jnp.zeros((0,), jnp.float32)


def _as_matrix(x: jax.Array) -> jax.Array:
    return x.reshape(-1, x.shape[-1])


def _compressible(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 \
        and int(np.prod(shape[:-1])) >= 8


def init_powersgd(grads, rank: int = 8, seed: int = 0) -> PowerSGDState:
    leaves = jax.tree.leaves(grads)
    keys = iter(jax.random.split(jax.random.PRNGKey(seed), len(leaves)))

    def init_q(g):
        k = next(keys)
        if not _compressible(g.shape):
            return _EMPTY()
        return jax.random.normal(k, (g.shape[-1], rank), jnp.float32)

    q = jax.tree.map(init_q, grads)
    err = jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32)
        if _compressible(g.shape) else _EMPTY(), grads)
    return PowerSGDState(q=q, error=err)


def _orthonormalise(p: jax.Array) -> jax.Array:
    q, _ = jnp.linalg.qr(p)   # r is tiny; QR cost negligible
    return q


def powersgd_compress(g: jax.Array, q: jax.Array, err: jax.Array
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One matrix leaf -> (P, new_Q, error-fed matrix) before reduction."""
    m = _as_matrix(g.astype(jnp.float32)) + _as_matrix(err)
    p = _orthonormalise(m @ q)            # [rows, r]
    q_new = m.T @ p                       # [cols, r]
    return p, q_new, m


def powersgd_decompress(p: jax.Array, q: jax.Array, shape) -> jax.Array:
    return (p @ q.T).reshape(shape)


def compressed_cross_pod_mean(grads, state: PowerSGDState, axis: str = "pod"):
    """Inside shard_map over ``axis``: mean grads across pods moving only
    rank-r factors for matrix leaves.  Returns (mean grads, new state)."""

    def leaf(g, q, err):
        if q.size == 0:
            return jax.lax.pmean(g, axis), q, err
        p, q_new, m = powersgd_compress(g, q, err)
        p = jax.lax.pmean(p, axis)            # the only cross-pod traffic
        q_new = jax.lax.pmean(q_new, axis)
        approx = powersgd_decompress(p, q_new, g.shape)
        new_err = (m - _as_matrix(approx)).reshape(g.shape)  # feedback
        return approx.astype(g.dtype), q_new, new_err

    out = jax.tree.map(leaf, grads, state.q, state.error)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), PowerSGDState(q=pick(1), error=pick(2))
