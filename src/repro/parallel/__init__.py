from repro.parallel.compress import (powersgd_compress, powersgd_decompress,
                                     PowerSGDState, init_powersgd,
                                     compressed_cross_pod_mean)
from repro.parallel.pipeline import pipeline_forward

__all__ = ["powersgd_compress", "powersgd_decompress", "PowerSGDState",
           "init_powersgd", "compressed_cross_pod_mean", "pipeline_forward"]
