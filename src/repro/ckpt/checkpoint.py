"""Sharded, resharding-on-restore checkpointing (fault-tolerance core).

Design (multi-host ready, no external deps):

* A checkpoint is a directory ``step_<N>/`` holding one ``.npy`` per pytree
  leaf (flattened key path as filename) plus ``manifest.json`` with the
  treedef, shapes, dtypes, per-leaf CRC32 and the writing process's count.
* **Elastic restore**: leaves are stored unsharded (gathered); restore
  ``device_put``s them under *any* new mesh/sharding — restarting 512-chip
  training on 256 chips (or a different DP/TP split) is a pure reshard.
  On real multi-host pods each process would write its addressable shards;
  the manifest format already carries per-leaf metadata to support that.
* **Async**: ``AsyncCheckpointer`` snapshots device arrays to host inside
  the step (cheap) and writes files on a worker thread, overlapping I/O
  with subsequent compute; ``wait()`` drains before exit/preemption.
* Atomicity: writes land in ``step_<N>.tmp`` and are renamed after fsync —
  a killed writer never corrupts the latest complete checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}.{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)) and not hasattr(node, "_fields"):
            for i, v in enumerate(node):
                walk(f"{prefix}[{i}]", v)
        elif hasattr(node, "_fields"):  # NamedTuple
            for k in node._fields:
                walk(f"{prefix}.{k}" if prefix else k, getattr(node, k))
        elif node is None:
            flat[prefix] = None
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _unflatten_like(template, flat: Dict[str, Any]):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}.{k}" if prefix else str(k), node[k])
                    for k in node}
        if hasattr(node, "_fields"):
            vals = {k: walk(f"{prefix}.{k}" if prefix else k,
                            getattr(node, k)) for k in node._fields}
            return type(node)(**vals)
        if isinstance(node, (list, tuple)):
            return type(node)(walk(f"{prefix}[{i}]", v)
                              for i, v in enumerate(node))
        if node is None:
            return None
        return flat[prefix]

    return walk("", template)


def save_checkpoint(directory: str, step: int, tree,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    """Gather-to-host + atomic write.  Returns the final path."""
    flat = _flatten_with_paths(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
            if v is not None}
    return _write_host_checkpoint(directory, step, host, extra)


def _write_host_checkpoint(directory: str, step: int,
                           host: Dict[str, np.ndarray],
                           extra: Optional[Dict[str, Any]] = None) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {},
                "process_count": jax.process_count()}
    for key, arr in host.items():
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes())
            & 0xFFFFFFFF,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, template, step: Optional[int] = None,
                       shardings=None, verify: bool = True
                       ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into ``template``'s structure.

    ``shardings``: optional pytree of NamedShardings (same structure) —
    the elastic-resume path: the stored arrays are placed onto whatever
    mesh the *current* job runs, regardless of the writer's mesh.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shard_flat = _flatten_with_paths(shardings) if shardings is not None \
        else {}

    flat = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {key} "
                              f"(crc {crc} != {meta['crc32']})")
        sh = shard_flat.get(key)
        flat[key] = jax.device_put(arr, sh) if sh is not None \
            else jax.device_put(arr)
    tree = _unflatten_like(template, flat)
    return tree, step, manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot-in-step, write-on-thread checkpointing."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        self.wait()  # one in flight at a time
        flat = _flatten_with_paths(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
                if v is not None}   # snapshot NOW (device -> host)

        def work():
            try:
                _write_host_checkpoint(self.directory, step, host, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(int(d.split("_")[1])
                       for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
