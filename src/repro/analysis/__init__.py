from repro.analysis.hlo import collective_bytes, parse_collectives
from repro.analysis.roofline import roofline_terms, model_flops

__all__ = ["collective_bytes", "parse_collectives", "roofline_terms",
           "model_flops"]
