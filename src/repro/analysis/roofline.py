"""Roofline terms from a compiled dry-run artifact (TPU v5e constants).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

``compiled.cost_analysis()`` on an SPMD executable reports the per-device
program, so no extra division by chip count is needed; the collective bytes
come from the per-device HLO (analysis/hlo.py).  The dominant term is the
bottleneck the §Perf loop iterates on.

MODEL_FLOPS uses 6*N*D for training (2 fwd + 4 bwd matmul passes per param
per token) and 2*N_active*D for single forward/decode, plus the attention
term 12*L*H*hd*S_ctx*D_tokens (train; halved causal) — the "useful" fraction
MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/redundancy waste.
"""
from __future__ import annotations

from typing import Dict

from repro.config import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def roofline_terms(cost: Dict, coll: Dict, chips: int,
                   peak_flops: float = PEAK_FLOPS_BF16,
                   hbm_bw: float = HBM_BW, ici_bw: float = ICI_BW
                   ) -> Dict[str, float]:
    """``cost``: {'flops', 'hbm_bytes'} from analysis.hlo.analyze (trip-
    count-aware, per-device); ``coll``: its wire-bytes dict."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("hbm_bytes", cost.get("bytes accessed", 0.0)))
    wire_dev = float(coll.get("total", 0.0))
    t_compute = flops_dev / peak_flops
    t_memory = bytes_dev / hbm_bw
    t_collective = wire_dev / ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective,
             "flops_per_device": flops_dev,
             "bytes_per_device": bytes_dev,
             "wire_bytes_per_device": wire_dev,
             "chips": chips}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    bound = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_step_s"] = bound
    # fraction of the step the MXU would be busy if the bound is achieved
    terms["compute_fraction_of_bound"] = (
        t_compute / bound if bound > 0 else 0.0)
    return terms


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic 'useful' FLOPs for one step of this (arch, shape) cell."""
    n_active = cfg.active_params()
    L = cfg.num_layers
    hq = cfg.attn.num_heads
    hd = cfg.head_dim
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_active * tokens
        if hq:
            # causal attention scores+values, fwd+bwd (x3), halved by mask
            flops += 3.0 * 2.0 * 2.0 * L * hq * hd * shape.seq_len ** 2 \
                * shape.global_batch / 2.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_active * tokens
        if hq:
            flops += 2.0 * 2.0 * L * hq * hd * shape.seq_len ** 2 \
                * shape.global_batch / 2.0
    else:  # decode: one token per sequence against the cached context
        tokens = shape.global_batch
        flops = 2.0 * n_active * tokens
        if hq:
            flops += 2.0 * 2.0 * L * hq * hd * shape.seq_len \
                * shape.global_batch
    return flops


def useful_fraction(cfg: ModelConfig, shape: ShapeConfig, cost: Dict,
                    chips: int) -> float:
    hlo_total = float(cost.get("flops", 0.0)) * chips
    if hlo_total <= 0:
        return 0.0
    return model_flops(cfg, shape) / hlo_total
