"""Render EXPERIMENTS.md tables from the dry-run JSON caches.

    PYTHONPATH=src python -m repro.analysis.report [results.json ...]
    PYTHONPATH=src python -m repro.analysis.report --kernels BENCH_kernels.json

``--kernels`` renders the fused-superstep before/after roofline table
from a ``bench_kernels`` artifact instead (the kernel-parity CI lane
uploads it as the roofline report).
"""
from __future__ import annotations

import json
import sys
from typing import Dict


def _fmt_gib(b):
    return f"{b / 2**30:.2f}" if b is not None else "-"


def dryrun_table(results: Dict) -> str:
    rows = ["| cell | mesh | compile s | HLO GFLOP/dev | HBM GiB/dev | "
            "wire GiB/dev | arg+tmp GiB/dev | fits 16G |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        v = results[key]
        arch, shape, mesh = key.split("|")
        if v.get("status") == "skipped":
            rows.append(f"| {arch} {shape} | {mesh} | skip | - | - | - | - |"
                        f" {v['reason'][:46]}... |")
            continue
        if v.get("status") != "ok":
            rows.append(f"| {arch} {shape} | {mesh} | ERROR | | | | | |")
            continue
        c = v["cost"]
        m = v["memory"]
        rows.append(
            f"| {arch} {shape} | {mesh} | {v['compile_s']} | "
            f"{c['flops'] / 1e9:.1f} | {_fmt_gib(c['hbm_bytes'])} | "
            f"{_fmt_gib(v['collectives']['total'])} | "
            f"{m.get('per_device_total_gib', '-')} | "
            f"{'Y' if v.get('fits_16g_hbm') else ('n/a' if v.get('fits_16g_hbm') is None else 'N')} |")
    return "\n".join(rows)


def roofline_table(results: Dict) -> str:
    rows = ["| cell | mesh | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful frac | one-line bottleneck |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        v = results[key]
        if v.get("status") != "ok":
            continue
        arch, shape, mesh = key.split("|")
        r = v["roofline"]
        mf = v.get("model_flops")
        uf = v.get("useful_fraction")
        note = _bottleneck_note(v)
        rows.append(
            f"| {arch} {shape} | {mesh} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{mf:.3e}" if mf else f"| {arch} {shape} | ... | -")
        rows[-1] = (
            f"| {arch} {shape} | {mesh} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            + (f"{mf:.3e}" if mf else "-") + " | "
            + (f"{uf:.3f}" if uf is not None else "-") + f" | {note} |")
    return "\n".join(rows)


def _bottleneck_note(v) -> str:
    r = v["roofline"]
    dom = r["dominant"]
    uf = v.get("useful_fraction") or 0
    if dom == "collective_s":
        return "cross-shard data movement dominates; move less or overlap"
    if dom == "memory_s" and uf < 0.3:
        return "replicated/redundant per-device work streams extra bytes"
    if dom == "memory_s":
        return "weight+activation streaming bound; fuse or quantise"
    return "MXU-bound; already near the compute roof"


def kernels_table(payload: Dict) -> str:
    """Before/after roofline table for BENCH_kernels.json (PR 8).

    One row per superstep variant: measured search throughput, hot-loop
    bytes per sim (HLO-measured for the unfused program, the Pallas
    block-transfer contract for the fused kernel), arithmetic intensity
    against the ridge, and the model roofline step time.
    """
    h, s = payload["hotloop"], payload["search"]
    rows = ["| superstep | sims/s (measured) | hot-loop KB/sim | source | "
            "FLOPs/byte | roofline frac | roofline step s |",
            "|---|---|---|---|---|---|---|"]
    for name in ("unfused", "fused"):
        c = h[name]
        rows.append(
            f"| {name} | {s[name]['sims_per_sec']:.0f} | "
            f"{c['bytes_per_sim'] / 1e3:.1f} | {c['source']} | "
            f"{c['flops_per_byte']:.3f} | {c['roofline_fraction']:.4f} | "
            f"{c['roofline']['roofline_step_s']:.3e} |")
    rows.append(
        f"\nfused/unfused: **{s['speedup']:.2f}x** sims/s, "
        f"**{h['bytes_reduction']:.2f}x** fewer hot-loop bytes/sim, "
        f"**{h['roofline_step_reduction']:.2f}x** lower roofline step "
        f"time (ridge {payload['ridge_flops_per_byte']:.1f} FLOPs/byte, "
        f"backend {payload['backend']}).")
    return "\n".join(rows)


def summary(results: Dict) -> str:
    ok = sum(1 for v in results.values() if v.get("status") == "ok")
    sk = sum(1 for v in results.values() if v.get("status") == "skipped")
    er = len(results) - ok - sk
    return f"{ok} compiled OK, {sk} skipped-by-contract, {er} errors"


def main() -> None:
    argv = sys.argv[1:]
    if argv and argv[0] == "--kernels":
        for p in argv[1:] or ["BENCH_kernels.json"]:
            with open(p) as f:
                payload = json.load(f)
            print(f"\n### {p} — fused superstep roofline\n")
            print(kernels_table(payload))
        return
    paths = argv or ["benchmarks/results/dryrun.json"]
    for p in paths:
        with open(p) as f:
            results = json.load(f)
        print(f"\n### {p} — {summary(results)}\n")
        print("#### Dry-run\n")
        print(dryrun_table(results))
        print("\n#### Roofline\n")
        print(roofline_table(results))


if __name__ == "__main__":
    main()
