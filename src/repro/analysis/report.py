"""Render EXPERIMENTS.md tables from the dry-run JSON caches.

    PYTHONPATH=src python -m repro.analysis.report [results.json ...]
"""
from __future__ import annotations

import json
import sys
from typing import Dict


def _fmt_gib(b):
    return f"{b / 2**30:.2f}" if b is not None else "-"


def dryrun_table(results: Dict) -> str:
    rows = ["| cell | mesh | compile s | HLO GFLOP/dev | HBM GiB/dev | "
            "wire GiB/dev | arg+tmp GiB/dev | fits 16G |",
            "|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        v = results[key]
        arch, shape, mesh = key.split("|")
        if v.get("status") == "skipped":
            rows.append(f"| {arch} {shape} | {mesh} | skip | - | - | - | - |"
                        f" {v['reason'][:46]}... |")
            continue
        if v.get("status") != "ok":
            rows.append(f"| {arch} {shape} | {mesh} | ERROR | | | | | |")
            continue
        c = v["cost"]
        m = v["memory"]
        rows.append(
            f"| {arch} {shape} | {mesh} | {v['compile_s']} | "
            f"{c['flops'] / 1e9:.1f} | {_fmt_gib(c['hbm_bytes'])} | "
            f"{_fmt_gib(v['collectives']['total'])} | "
            f"{m.get('per_device_total_gib', '-')} | "
            f"{'Y' if v.get('fits_16g_hbm') else ('n/a' if v.get('fits_16g_hbm') is None else 'N')} |")
    return "\n".join(rows)


def roofline_table(results: Dict) -> str:
    rows = ["| cell | mesh | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful frac | one-line bottleneck |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        v = results[key]
        if v.get("status") != "ok":
            continue
        arch, shape, mesh = key.split("|")
        r = v["roofline"]
        mf = v.get("model_flops")
        uf = v.get("useful_fraction")
        note = _bottleneck_note(v)
        rows.append(
            f"| {arch} {shape} | {mesh} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            f"{mf:.3e}" if mf else f"| {arch} {shape} | ... | -")
        rows[-1] = (
            f"| {arch} {shape} | {mesh} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s', '')} | "
            + (f"{mf:.3e}" if mf else "-") + " | "
            + (f"{uf:.3f}" if uf is not None else "-") + f" | {note} |")
    return "\n".join(rows)


def _bottleneck_note(v) -> str:
    r = v["roofline"]
    dom = r["dominant"]
    uf = v.get("useful_fraction") or 0
    if dom == "collective_s":
        return "cross-shard data movement dominates; move less or overlap"
    if dom == "memory_s" and uf < 0.3:
        return "replicated/redundant per-device work streams extra bytes"
    if dom == "memory_s":
        return "weight+activation streaming bound; fuse or quantise"
    return "MXU-bound; already near the compute roof"


def summary(results: Dict) -> str:
    ok = sum(1 for v in results.values() if v.get("status") == "ok")
    sk = sum(1 for v in results.values() if v.get("status") == "skipped")
    er = len(results) - ok - sk
    return f"{ok} compiled OK, {sk} skipped-by-contract, {er} errors"


def main() -> None:
    paths = sys.argv[1:] or ["benchmarks/results/dryrun.json"]
    for p in paths:
        with open(p) as f:
            results = json.load(f)
        print(f"\n### {p} — {summary(results)}\n")
        print("#### Dry-run\n")
        print(dryrun_table(results))
        print("\n#### Roofline\n")
        print(roofline_table(results))


if __name__ == "__main__":
    main()
