"""Trip-count-aware cost analysis of post-partitioning HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once*, which
under-reports every scanned layer stack / microbatch loop by its trip count.
This module parses ``compiled.as_text()`` (the per-device SPMD program) into
its computations and computes, bottom-up with loop multipliers from
``backend_config known_trip_count``:

* **flops** — 2*M*N*K for every ``dot`` (batch dims included), scaled by
  enclosing trips.  Elementwise FLOPs are ignored (MODEL_FLOPS convention).
* **hbm bytes** — XLA's unit of HBM traffic is the *fusion*: each top-level
  materialised instruction reads its operands and writes its result, interior
  elementwise ops are free.  We sum (operand + result bytes) over non-control
  instructions at computation level, scaled by trips.  For slicing-pattern
  ops (fusion / dynamic-slice / dynamic-update-slice / gather / scatter)
  each operand is capped at the result size: a loop step that slices one
  layer's activations out of the stacked [L, ...] remat buffer touches the
  slice, not the whole aliased buffer (XLA updates loop carries in place).
  Dots, custom-calls and collectives always count full operands.
* **wire bytes** — per-collective ring-model cost (see ``_wire``), scaled by
  trips (collectives inside scanned layers count once per layer!).

All sizes are per-device because the partitioned module is.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, NamedTuple, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+"
    r"([a-z][\w\-]*)\((.*)$")
_COMP_HEAD_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[\\\":{]+n[\\\":]+(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_COMP_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")
_CONTROL_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "partition-id", "replica-id",
                "iota", "rng-bit-generator", "opt-barrier"}
# ops that access a slice-sized window of possibly-huge aliased operands
_SLICING_OPS = {"fusion", "dynamic-slice", "dynamic-update-slice",
                "gather", "scatter", "copy"}


class Instr(NamedTuple):
    name: str
    shapes: List[tuple]          # [(dtype, dims), ...]
    opcode: str
    operands: List[str]
    rest: str                    # attrs after the operand close-paren


class Cost(NamedTuple):
    flops: float
    hbm_bytes: float
    wire: Dict[str, float]
    wire_counts: Dict[str, float]

    @staticmethod
    def zero() -> "Cost":
        return Cost(0.0, 0.0, defaultdict(float), defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> "Cost":
        w = defaultdict(float, self.wire)
        c = defaultdict(float, self.wire_counts)
        for k, v in other.wire.items():
            w[k] += v * mult
        for k, v in other.wire_counts.items():
            c[k] += v * mult
        return Cost(self.flops + other.flops * mult,
                    self.hbm_bytes + other.hbm_bytes * mult, w, c)


def _shape_list(text: str) -> List[tuple]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(shapes: List[tuple]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _wire(kind: str, size: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * size * (n - 1) / n
    if kind == "all-gather":
        return size * (n - 1) / n
    if kind == "reduce-scatter":
        return size * (n - 1)
    if kind == "all-to-all":
        return size * (n - 1) / n
    return float(size)   # collective-permute


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


class HloProgram:
    """Parsed computations of one HLO module."""

    def __init__(self, text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cache: Dict[str, Cost] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                # computation header: "name (args...) -> result {", no "="
                if line.endswith("{") and "->" in line and " = " not in line:
                    m = _COMP_HEAD_RE.match(line)
                    if m:
                        cur = m.group(2)
                        self.comps[cur] = []
                        if m.group(1):
                            self.entry = cur
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shapes_text, opcode, tail = m.groups()
            # operands: up to the first unnested ')'
            depth, idx = 1, 0
            for idx, ch in enumerate(tail):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            operand_text, rest = tail[:idx], tail[idx + 1:]
            self.comps[cur].append(Instr(
                name=name,
                shapes=_shape_list(shapes_text),
                opcode=opcode,
                operands=_OPERAND_RE.findall(operand_text),
                rest=rest))

    # -- cost --------------------------------------------------------------

    def cost(self, comp: Optional[str] = None, default_group: int = 1
             ) -> Cost:
        comp = comp or self.entry
        if comp is None:
            return Cost.zero()
        if comp in self._cache:
            return self._cache[comp]
        table = {i.name: i for i in self.comps.get(comp, [])}
        total = Cost.zero()
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            if op in _CONTROL_OPS:
                continue
            res_bytes = _bytes_of(ins.shapes)
            opnd = [_bytes_of(table[o].shapes) for o in ins.operands
                    if o in table]
            if op in _SLICING_OPS:
                opnd = [min(b, max(res_bytes, 1)) for b in opnd]
            io_bytes = res_bytes + sum(opnd)

            if op == "while":
                trip = 1
                m = _TRIP_RE.search(ins.rest)
                if m:
                    trip = int(m.group(1))
                inner = Cost.zero()
                b = _BODY_RE.search(ins.rest)
                c = _COND_RE.search(ins.rest)
                if b:
                    inner = inner.add(self.cost(b.group(1), default_group))
                if c:
                    inner = inner.add(self.cost(c.group(1), default_group))
                total = total.add(inner, trip)
                continue
            if op == "conditional":
                branches = []
                mb = _BRANCHES_RE.search(ins.rest)
                if mb:
                    branches = _OPERAND_RE.findall(mb.group(1))
                branches += _TF_COMP_RE.findall(ins.rest)
                if branches:
                    worst = max((self.cost(b, default_group)
                                 for b in branches),
                                key=lambda cc: cc.flops + cc.hbm_bytes)
                    total = total.add(worst)
                continue
            if op in ("fusion", "call", "async-start"):
                m = _CALLS_RE.search(ins.rest)
                if m:
                    inner = self.cost(m.group(1), default_group)
                    # interior dots/collectives count; interior elementwise
                    # traffic does not (fusion = the unit of HBM traffic)
                    total = Cost(total.flops + inner.flops,
                                 total.hbm_bytes,
                                 total.wire, total.wire_counts)
                    total = total.add(
                        Cost(0.0, 0.0, inner.wire, inner.wire_counts))
                total = total.add(Cost(0.0, io_bytes, {}, {}))
                continue

            kind = next((k for k in _COLLECTIVE_KINDS if op.startswith(k)),
                        None)
            if kind is not None:
                if op.endswith("-done"):
                    continue
                size = _bytes_of(ins.shapes)
                if op.endswith("-start") and kind in ("all-gather",
                                                      "all-reduce"):
                    size //= 2      # start result tuples carry (in, out)
                n = _group_size(ins.rest, default_group)
                w = _wire(kind, size, n)
                wd = defaultdict(float)
                wd[kind] = w
                cd = defaultdict(float)
                cd[kind] = 1.0
                total = total.add(Cost(0.0, io_bytes, wd, cd))
                continue

            if op == "dot":
                flops = 0.0
                if ins.shapes:
                    res_elems = 1
                    for d in ins.shapes[0][1]:
                        res_elems *= d
                    k_prod = 1
                    mcd = _LHS_CDIMS_RE.search(ins.rest)
                    lhs = table.get(ins.operands[0]) if ins.operands else None
                    if mcd and lhs and lhs.shapes:
                        for di in mcd.group(1).split(","):
                            if di.strip():
                                k_prod *= lhs.shapes[0][1][int(di)]
                    flops = 2.0 * res_elems * k_prod
                total = total.add(Cost(flops, io_bytes, {}, {}))
                continue

            # everything else materialised at top level: traffic only
            total = total.add(Cost(0.0, io_bytes, {}, {}))

        self._cache[comp] = total
        return total


def analyze(hlo_text: str, default_group: int = 1) -> Dict[str, object]:
    """Entry-point: per-device {flops, hbm_bytes, wire{kind}, counts}."""
    prog = HloProgram(hlo_text)
    c = prog.cost(default_group=default_group)
    wire = dict(c.wire)
    wire["total"] = sum(c.wire.values())
    return {"flops": c.flops, "hbm_bytes": c.hbm_bytes, "wire": wire,
            "wire_counts": dict(c.wire_counts)}


def collective_bytes(hlo_text: str, default_group: int = 1
                     ) -> Dict[str, object]:
    """Aggregate per-device wire bytes by kind (+ 'total'), trip-scaled."""
    res = analyze(hlo_text, default_group)
    out = dict(res["wire"])
    out["counts"] = res["wire_counts"]
    return out


def parse_collectives(hlo_text: str, default_group: int = 1):
    """Back-compat shim returning the aggregate (kept for tests)."""
    return collective_bytes(hlo_text, default_group)
