"""Distributed training step: microbatch accumulation + optimizer + FT hooks.

``train_step`` is one jitted function of ``(state, batch) -> (state,
metrics)``:

* The global batch splits into ``microbatches`` chunks scanned sequentially
  — each chunk's fwd+bwd is rematerialised, so peak activation memory is
  one microbatch while the gradient accumulator (same sharding as params)
  carries the sum.  The scan also gives XLA a window to overlap each
  chunk's gradient reduce-scatter with the next chunk's compute (the
  latency-hiding scheduler does this when
  ``--xla_tpu_enable_latency_hiding_scheduler`` is on — launch/mesh.py).
* Gradient clipping by global norm, then the optimizer (optim/).
* Optional PowerSGD compression of the *cross-pod* gradient mean
  (parallel/compress.py) under a partial-auto shard_map over the ``pod``
  axis: inside the body gradients are averaged over data/model by XLA as
  usual, while the pod-axis exchange moves only rank-r factors.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.config import TrainConfig
from repro.optim import (clip_by_global_norm, make_optimizer, make_schedule)
from repro.parallel.compress import (PowerSGDState, compressed_cross_pod_mean,
                                     init_powersgd)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array
    psgd: Optional[PowerSGDState]


def init_train_state(model, tcfg: TrainConfig, key) -> TrainState:
    params = model.init(key)
    opt = make_optimizer(tcfg.optimizer, tcfg.weight_decay)
    opt_state = opt.init(params)
    psgd = None
    if tcfg.compress_pod_grads:
        grads_like = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        psgd = init_powersgd(grads_like, rank=tcfg.powersgd_rank)
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.int32(0), psgd=psgd)


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by {n} chunks"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_train_step(model, tcfg: TrainConfig, total_steps: Optional[int]
                    = None, mesh: Optional[Mesh] = None):
    """Build the jittable step.  ``model`` must expose ``loss(params,
    batch, z_loss)``."""
    opt = make_optimizer(tcfg.optimizer, tcfg.weight_decay)
    sched = make_schedule(tcfg.schedule, tcfg.lr, tcfg.warmup_steps,
                          total_steps or tcfg.steps)
    n_mb = max(1, tcfg.microbatches)
    use_pod_compress = (tcfg.compress_pod_grads and mesh is not None
                        and "pod" in mesh.axis_names
                        and mesh.shape["pod"] > 1)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, z_loss=tcfg.z_loss)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def accumulate(params, batch):
        mbs = _split_microbatches(batch, n_mb)

        def body(carry, mb):
            gsum, lsum = carry
            (l, metrics), g = grad_fn(params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + l), metrics["ce"]

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, lsum), ces = jax.lax.scan(body, (g0, jnp.float32(0.0)), mbs)
        grads = jax.tree.map(lambda g: g / n_mb, gsum)
        return grads, lsum / n_mb, ces.mean()

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        new_psgd = state.psgd
        if use_pod_compress:
            # gradients stay per-pod until the compressed exchange: the
            # whole accumulate runs under a pod-manual shard_map (data and
            # model stay auto => XLA shards them as usual inside), so the
            # only cross-pod traffic is the rank-r factors.
            def per_pod(params, batch_pod, psgd):
                from repro.models import sharding as shlib
                with shlib.manual_axes({"pod"}):
                    grads, loss, ce = accumulate(params, batch_pod)
                grads, psgd = compressed_cross_pod_mean(grads, psgd,
                                                        axis="pod")
                loss = jax.lax.pmean(loss, "pod")
                ce = jax.lax.pmean(ce, "pod")
                return grads, loss, ce, psgd

            # New JAX: partial-auto (manual over pod only, data/model stay
            # auto).  Old JAX: its partial-auto lowering miscompiles, so go
            # fully manual — params/psgd replicated per device, batch
            # sharded over pod only.  Same numerics; data/model axes do
            # redundant compute, acceptable at old-JAX test scale.
            kw = ({"axis_names": {"pod"}} if compat.HAS_NATIVE_SHARD_MAP
                  else {})
            grads, loss, ce, new_psgd = shard_map(
                per_pod, mesh=mesh, in_specs=(P(), P("pod"), P()),
                out_specs=(P(), P(), P(), P()), check_vma=False,
                **kw)(state.params, batch, state.psgd)
        else:
            grads, loss, ce = accumulate(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = sched(state.step)
        params, opt_state = opt.update(grads, state.opt_state, state.params,
                                       lr)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1, psgd=new_psgd)
        return new_state, {"loss": loss, "ce": ce, "grad_norm": gnorm,
                           "lr": lr}

    return train_step
