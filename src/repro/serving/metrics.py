"""Latency observability for the serving tier: histograms + counters.

The paper's thesis is that *throughput* hides the failure mode — the
32-to-240-thread knee only shows in how long individual searches wait.
This module is the user-visible half of that lesson: every request
through :class:`~repro.serving.go_service.GoService` (and therefore the
HTTP front door, :mod:`repro.serving.server`) is timestamped at
submission, flush, and completion, and the deltas stream into
log-bucketed histograms whose p50/p95/p99 are the serving tier's health
metrics — `BENCH_load.json` plots them against offered load, mirroring
the paper's threads-vs-performance figure with arrival rate on the
x-axis.

Everything here is pure host-side bookkeeping (numpy counters, no JAX),
so recording a sample can never retrace or even touch the device.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class LatencyHistogram:
    """Streaming log-bucketed latency histogram with percentile reads.

    Buckets are geometric: edge ``i`` is ``lo_s * growth**i``, so the
    relative resolution of any percentile is bounded by ``growth - 1``
    (~7% at the default) regardless of how many samples stream through —
    constant memory, O(1) record, O(buckets) percentile.  Samples below
    ``lo_s`` clamp into the first bucket and samples above ``hi_s`` into
    the last (the last bucket's width absorbs outliers; ``max_s`` is
    kept exactly so the clamp is visible).  tests/test_server.py pins
    the percentile math against ``numpy.percentile`` on a recorded
    trace, within the bucket-resolution bound.
    """

    def __init__(self, lo_s: float = 1e-4, hi_s: float = 600.0,
                 growth: float = 1.07):
        if not (lo_s > 0 and hi_s > lo_s and growth > 1):
            raise ValueError(
                f"need 0 < lo_s < hi_s and growth > 1, got "
                f"({lo_s}, {hi_s}, {growth})")
        self.growth = growth
        n = int(np.ceil(np.log(hi_s / lo_s) / np.log(growth))) + 1
        self.edges = lo_s * growth ** np.arange(n + 1)   # n buckets
        self.counts = np.zeros(n, np.int64)
        self.count = 0
        self.sum_s = 0.0
        self.max_s = 0.0

    def record(self, value_s: float) -> None:
        """Add one latency sample (seconds)."""
        v = float(value_s)
        i = int(np.searchsorted(self.edges, v, side="right")) - 1
        self.counts[min(max(i, 0), len(self.counts) - 1)] += 1
        self.count += 1
        self.sum_s += v
        self.max_s = max(self.max_s, v)

    def percentile(self, q: float) -> float:
        """The ``q``-th percentile (seconds), interpolated in-bucket.

        Matches ``numpy.percentile``'s linear interpolation up to the
        geometric bucket resolution; 0.0 when no samples were recorded.
        """
        if self.count == 0:
            return 0.0
        # numpy's linear rule: rank q/100 * (n-1) into the sorted sample
        target = q / 100.0 * (self.count - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            # samples in this bucket occupy sorted ranks [cum, cum+c)
            if target < cum + c:
                frac = (target - cum + 0.5) / c     # mid-rank within bucket
                frac = min(max(frac, 0.0), 1.0)
                lo, hi = self.edges[i], min(self.edges[i + 1], self.max_s)
                hi = max(hi, lo)
                return float(lo + frac * (hi - lo))
            cum += c
        return self.max_s

    def snapshot(self) -> Dict[str, float]:
        """Counters + p50/p95/p99 in milliseconds (the /metrics shape)."""
        return {
            "count": int(self.count),
            "sum_ms": self.sum_s * 1e3,
            "mean_ms": (self.sum_s / self.count * 1e3) if self.count else 0.0,
            "max_ms": self.max_s * 1e3,
            "p50_ms": self.percentile(50.0) * 1e3,
            "p95_ms": self.percentile(95.0) * 1e3,
            "p99_ms": self.percentile(99.0) * 1e3,
        }


class ServingMetrics:
    """Per-service request ledger: counters plus latency histograms.

    Stages of one request's life (all host timestamps, monotonic):

    * ``queue`` — submit -> flush: time spent host-buffered before the
      dispatch pipeline pushed it to the device queues (admission wait);
    * ``dispatch`` — flush -> complete: device queueing + search;
    * ``total`` — submit -> complete: what the caller experiences.

    Counters: ``submitted`` / ``completed`` (answered), ``downgraded``
    (admitted with a deadline-cut ``sims`` budget), ``shed_overload``
    (rejected at admission, queue depth over the limit),
    ``shed_deadline`` (dropped before flush, deadline unmeetable or
    expired), ``deadline_miss`` (completed, but after its deadline —
    requests already on the device are never killed).
    """

    COUNTERS = ("submitted", "completed", "downgraded",
                "shed_overload", "shed_deadline", "deadline_miss")

    def __init__(self):
        self.counters = {name: 0 for name in self.COUNTERS}
        self.hists = {"queue": LatencyHistogram(),
                      "dispatch": LatencyHistogram(),
                      "total": LatencyHistogram()}

    def bump(self, counter: str, by: int = 1) -> None:
        """Increment one named counter (must be in :attr:`COUNTERS`)."""
        self.counters[counter] += by

    def observe(self, queue_s: Optional[float], dispatch_s: Optional[float],
                total_s: float, deadline_missed: bool = False) -> None:
        """Record one completed request's stage latencies."""
        self.counters["completed"] += 1
        if deadline_missed:
            self.counters["deadline_miss"] += 1
        if queue_s is not None:
            self.hists["queue"].record(queue_s)
        if dispatch_s is not None:
            self.hists["dispatch"].record(dispatch_s)
        self.hists["total"].record(total_s)

    @property
    def shed(self) -> int:
        """Total explicitly rejected requests (overload + deadline)."""
        return (self.counters["shed_overload"]
                + self.counters["shed_deadline"])

    def snapshot(self) -> Dict[str, object]:
        """The /metrics payload: counters + per-stage percentile blocks."""
        out: Dict[str, object] = dict(self.counters)
        out["shed"] = self.shed
        for name, h in self.hists.items():
            out[name] = h.snapshot()
        return out
