from repro.serving.engine import ServeEngine, make_prefill_fn, make_decode_fn

__all__ = ["ServeEngine", "make_prefill_fn", "make_decode_fn"]
