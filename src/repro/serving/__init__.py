from repro.serving.engine import ServeEngine, make_prefill_fn, make_decode_fn
from repro.serving.go_service import (DeadlineExceededError, DeadlinePolicy,
                                      GoService, MoveResult,
                                      OverCapacityError)
from repro.serving.metrics import LatencyHistogram, ServingMetrics

__all__ = ["ServeEngine", "make_prefill_fn", "make_decode_fn",
           "GoService", "MoveResult", "DeadlinePolicy",
           "DeadlineExceededError", "OverCapacityError",
           "LatencyHistogram", "ServingMetrics"]
