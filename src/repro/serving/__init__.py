from repro.serving.engine import ServeEngine, make_prefill_fn, make_decode_fn
from repro.serving.go_service import GoService, MoveResult

__all__ = ["ServeEngine", "make_prefill_fn", "make_decode_fn",
           "GoService", "MoveResult"]
