"""Batched serving engine: jitted prefill + decode over a shared KV cache.

Production shape: requests are padded into fixed (batch, prompt_len)
buckets so the jitted ``prefill``/``decode_step`` executables are reused
across requests (one compilation per bucket).  Greedy and temperature
sampling; per-request EOS masking; donation of the cache between steps so
decode runs in place.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import LMCache, TransformerLM


def make_prefill_fn(model: TransformerLM, max_len: int):
    @functools.partial(jax.jit, static_argnums=())
    def prefill(params, tokens, frontend=None):
        return model.prefill(params, tokens, frontend, max_len=max_len)

    return prefill


def make_decode_fn(model: TransformerLM, temperature: float = 0.0):
    """Jitted decode step with ``temperature`` as a *traced* argument.

    The seed baked the temperature into the jit closure, so every
    temperature change recompiled the decode executable.  Now greedy and
    sampled picks are both computed and selected branch-free, so one
    compilation serves all temperatures — pass a ``jnp`` scalar per call
    (``ServeEngine.generate`` does); the make-time float is only the
    default for legacy 4-argument callers.
    """
    @functools.partial(jax.jit, donate_argnums=(1,))
    def decode(params, cache: LMCache, tokens, rng, temperature=temperature):
        logits, cache = model.decode_step(params, cache, tokens)
        logits = logits[:, -1]
        temperature = jnp.asarray(temperature, logits.dtype)
        safe = jnp.maximum(temperature, jnp.asarray(1e-6, logits.dtype))
        sampled = jax.random.categorical(rng, logits / safe, axis=-1)
        greedy = jnp.argmax(logits, axis=-1)
        nxt = jnp.where(temperature > 0, sampled, greedy)
        return nxt.astype(jnp.int32)[:, None], cache

    return decode


class ServeEngine:
    """Fixed-bucket batched generation."""

    def __init__(self, model: TransformerLM, params, batch: int,
                 max_prompt: int, max_new: int, eos_id: int = 2,
                 temperature: float = 0.0):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_prompt = max_prompt
        self.max_new = max_new
        self.eos = eos_id
        self.temperature = temperature
        self.prefill = make_prefill_fn(model, max_prompt + max_new)
        self.decode = make_decode_fn(model)

    def _pad_prompts(self, prompts: List[List[int]]):
        assert len(prompts) <= self.batch
        toks = np.zeros((self.batch, self.max_prompt), np.int32)
        for i, p in enumerate(prompts):
            p = p[-self.max_prompt:]
            toks[i, -len(p):] = p          # left-pad: end-aligned prompts
        return jnp.asarray(toks)

    def generate(self, prompts: List[List[int]], seed: int = 0,
                 frontend=None,
                 temperature: float | None = None) -> List[List[int]]:
        """Greedy/temperature generation for a batch of token prompts.

        ``temperature`` overrides the engine default per call; it is a
        traced argument of the decode step, so varying it between calls
        never recompiles.
        """
        temp = jnp.float32(self.temperature if temperature is None
                           else temperature)
        tokens = self._pad_prompts(prompts)
        logits, cache = self.prefill(self.params, tokens, frontend)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        rng = jax.random.PRNGKey(seed)
        outs = [np.asarray(nxt)]
        done = np.zeros((self.batch,), bool)
        for _ in range(self.max_new - 1):
            rng, sub = jax.random.split(rng)
            nxt, cache = self.decode(self.params, cache, nxt, sub, temp)
            host = np.asarray(nxt)
            done |= (host[:, 0] == self.eos)
            outs.append(host)
            if done[: len(prompts)].all():
                break
        gen = np.concatenate(outs, axis=1)
        result = []
        for i in range(len(prompts)):
            row = gen[i].tolist()
            if self.eos in row:
                row = row[: row.index(self.eos) + 1]
            result.append(row)
        return result
