"""GoService: batched external best-move queries (queue -> ticket -> poll).

The Go-side counterpart of :class:`~repro.serving.engine.ServeEngine`'s
fixed-bucket pattern: requests are admitted into a fixed-capacity
SearchService slot pool so one compiled dispatch serves every query.  The
static bucket axes are ``(board_size, max_sims)``; the per-request
``sims`` budget, the strength knobs ``c_uct`` / ``virtual_loss``, **and
— since PR 10 — the scoring ``komi``** are *traced* (masked search tail;
per-lane scalar broadcast; per-slot komi column), so budgets from 1 to
``max_sims``, arbitrary UCT configurations, and arbitrary komis share
one executable — a caller can dial a query's exploration *and* its komi
per request with zero recompilation.

Two scheduling modes own that pool (``unified=``, default on):

* **unified** — every komi is a *bucket* inside ONE mesh-wide
  SearchService, scheduled by a single
  :class:`~repro.core.scheduler.BucketScheduler` pump/reconcile stream:
  one compiled dispatch and one pipeline serve all buckets, per-bucket
  shard partitions (+ idle-headroom borrowing) keep traffic classes
  apart, and ``pipeline_depth`` may adapt inside a static clamp
  (``max_pipeline_depth``).  Host pump cost no longer scales with
  bucket count.
* **per-bucket** (``unified=False``, the PR 6-9 shape, kept as the
  benchmark baseline) — each komi opens its own SearchService + pipeline
  and :meth:`poll` round-robins them (rotating its start bucket per
  call so no bucket eats every pump's first flush).

A query is a pure function of
``(board, to_play, sims, c_uct, virtual_loss, key)``: the dispatcher
admits serve tickets only into cells searched by the bucket's single
player, and the search consumes the request key directly, so results do
not depend on slot placement or on what else shares the batch
(tests/test_service.py and tests/test_multiplex.py pin this).

SLO discipline (the serving-tier front door contract, used by
:mod:`repro.serving.server`):

* **admission control** — :meth:`submit` rejects with
  :class:`OverCapacityError` when the bucket's queue depth crosses
  ``admission_limit`` (explicit load shedding, never silent loss);
* **deadlines** — ``deadline_ms`` threads a per-request SLO through
  submission: an unmeetable deadline is shed up front
  (:class:`DeadlineExceededError`), a tight one is *downgraded* — its
  traced ``sims`` budget is cut, which is free since budgets are traced
  (no recompile) — and a request that expires while still host-buffered
  is shed at the next :meth:`poll` (``SearchService.shed_expired``).
  Requests already flushed to the device always complete; finishing
  late only bumps the ``deadline_miss`` counter;
* **observability** — every request's queue/dispatch/total latency
  streams into :class:`~repro.serving.metrics.ServingMetrics`
  (p50/p95/p99 histograms + shed/downgrade counters), the payload the
  HTTP ``/metrics`` endpoint and benchmarks/bench_load.py read.

Typical use::

    svc = GoService(board_size=9, komi=6.0, max_sims=256)
    move = svc.best_move(board)                 # one blocking query
    tickets = [svc.submit(b) for b in boards]   # batched: queue ...
    moves = [svc.result(t) for t in tickets]    # ... then poll tickets
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core.scheduler import BucketScheduler
from repro.core.service import SearchService, pad_slots
from repro.core.streaming import DispatchPipeline
from repro.go.board import BLACK, NO_KO, GoEngine, GoState
from repro.serving.metrics import ServingMetrics


class OverCapacityError(RuntimeError):
    """Request shed at admission: bucket queue depth over the limit."""


class DeadlineExceededError(TimeoutError):
    """Request shed for its deadline: unmeetable at admission, or it
    expired while still host-buffered (never dispatched)."""


class MoveResult(NamedTuple):
    """One answered best-move query."""
    ticket: int
    action: int               # 0..n2-1 point, n2 = pass
    coord: Optional[Tuple[int, int]]   # (row, col), None for pass
    is_pass: bool
    root_visits: np.ndarray   # f32[A] root visit distribution
    sims_granted: int = 0     # playout budget actually dispatched (0 = full)
    downgraded: bool = False  # True when a deadline cut the budget
    latency_s: float = 0.0    # submit -> completion wall time


class DeadlinePolicy:
    """Admit / downgrade / shed decision for one deadline'd query.

    Linear cost model: a query admitted at queue depth ``d`` into a
    ``slots``-wide bucket waits ``waves = ceil((d + 1) / slots)`` search
    waves, each costing ``base_s + sim_cost_s * sims`` — so

        est(sims, depth) = base_s + sim_cost_s * sims * waves.

    :meth:`decide` compares the estimate against the request's remaining
    budget: full ``sims`` fits -> ``admit``; a cut budget of at least
    ``floor_sims`` fits -> ``downgrade`` (free: the budget is a traced
    dispatch input, PR 2); otherwise -> ``shed``.  ``observe`` keeps
    ``sim_cost_s`` calibrated by EWMA over completed requests, so the
    boundary tracks the machine; construct with ``calibrate=False`` for
    a fixed, deterministic policy (the unit tests do).
    """

    def __init__(self, base_s: float = 0.02, sim_cost_s: float = 1e-3,
                 floor_sims: int = 4, slots: int = 8,
                 calibrate: bool = True, ewma: float = 0.2):
        if floor_sims < 1:
            raise ValueError(f"floor_sims must be >= 1, got {floor_sims}")
        self.base_s = float(base_s)
        self.sim_cost_s = float(sim_cost_s)
        self.floor_sims = int(floor_sims)
        self.slots = max(1, int(slots))
        self.calibrate = calibrate
        self.ewma = float(ewma)

    def _waves(self, depth: int) -> int:
        return max(1, math.ceil((depth + 1) / self.slots))

    def estimate_s(self, sims: int, depth: int) -> float:
        """Predicted completion latency at the given queue depth."""
        return self.base_s + self.sim_cost_s * sims * self._waves(depth)

    def decide(self, remaining_s: Optional[float], depth: int,
               full_sims: int) -> Tuple[str, int]:
        """``("admit"|"downgrade"|"shed", granted_sims)`` for one query."""
        if remaining_s is None:
            return "admit", full_sims
        if self.estimate_s(full_sims, depth) <= remaining_s:
            return "admit", full_sims
        per_sim = self.sim_cost_s * self._waves(depth)
        fit = int((remaining_s - self.base_s) / max(per_sim, 1e-12))
        if fit >= self.floor_sims:
            return "downgrade", min(fit, full_sims)
        return "shed", 0

    def observe(self, latency_s: float, sims: int, depth: int) -> None:
        """EWMA-calibrate ``sim_cost_s`` from one completed request."""
        if not self.calibrate or sims < 1:
            return
        per_sim = max(latency_s - self.base_s, 0.0) / (
            sims * self._waves(depth))
        self.sim_cost_s += self.ewma * (per_sim - self.sim_cost_s)

    def observe_censored(self, waited_s: float, sims: int,
                         depth: int) -> None:
        """One-sided calibration from a shed or expired request.

        Learning only from completions biases the cost model optimistic
        under overload: the slowest requests are exactly the ones that
        never complete, so ``sim_cost_s`` drifts down while the machine
        drowns and the policy admits ever more unmeetable work.  A
        request shed after waiting ``waited_s`` is a *censored* sample —
        its true latency would have been at least the wait — so it may
        only pull the estimate **up** (standard censored-EWMA rule: skip
        the sample when the bound is already below the estimate).
        """
        if not self.calibrate or sims < 1:
            return
        per_sim = max(waited_s - self.base_s, 0.0) / (
            sims * self._waves(depth))
        if per_sim > self.sim_cost_s:
            self.sim_cost_s += self.ewma * (per_sim - self.sim_cost_s)


class _Ticket:
    """Host-side lifecycle record of one submitted query."""

    __slots__ = ("komi", "inner", "t_submit", "t_flush", "deadline",
                 "sims_granted", "downgraded", "depth")

    def __init__(self, komi: float, inner: int, t_submit: float,
                 deadline: Optional[float], sims_granted: int,
                 downgraded: bool, depth: int):
        self.komi = komi
        self.inner = inner              # SearchService ticket
        self.t_submit = t_submit
        self.t_flush: Optional[float] = None
        self.deadline = deadline        # absolute monotonic, None = no SLO
        self.sims_granted = sims_granted
        self.downgraded = downgraded
        self.depth = depth              # bucket queue depth at admission


class GoService:
    """Fixed-bucket batched Go move service over SearchService pools.

    ``mesh=`` shards every bucket's slot pool over a one-axis device mesh
    (``placement`` routes queries to shards, core/placement.py); serve
    answers are placement-independent by the dispatcher's RNG contract,
    so sharding only changes throughput, never a move.

    ``unified`` (default) schedules every komi bucket inside ONE shared
    SearchService pool via a
    :class:`~repro.core.scheduler.BucketScheduler`: one compiled
    dispatch, one pump/reconcile stream, per-bucket shard partitions
    with idle-headroom ``borrowing``.  With a single bucket, borrowing
    moot, and a fixed depth this is bit-identical (results *and* host
    syncs) to the per-bucket path; with many buckets it answers the
    same queries with one pump's host cost instead of one per bucket.
    ``unified=False`` keeps the PR 6-9 one-pool-per-komi shape (each
    new komi compiles its own bucket).

    ``pipeline_depth`` streams the serve loop: :meth:`poll` keeps up to
    that many supersteps in flight instead of awaiting each one —
    queued queries, result unpacking, and placement overlap with device
    search.  Answers are unchanged at any depth (the serve RNG contract
    makes them pure functions of the query).  In unified mode the depth
    may *adapt*: ``max_pipeline_depth > pipeline_depth`` (or
    ``adaptive_depth=True``) engages a
    :class:`~repro.core.scheduler.DepthController` that raises the
    window when the device runs ahead of the host and lowers it when
    reconciles block, clamped to the static ``max_pipeline_depth`` —
    depth only changes host read timing, so adaptation never compiles a
    new trace.

    ``admission_limit`` (0 = the bucket queue capacity) bounds each
    bucket's outstanding requests — :meth:`submit` sheds past it — and
    ``deadline_policy`` decides admit/downgrade/shed for deadline'd
    queries (see :class:`DeadlinePolicy`; the default self-calibrates).
    Neither knob touches the device: shedding happens before flush and
    downgrading rides the traced ``sims`` budget, so SLO enforcement
    adds **zero** new jit traces (tests/test_server.py asserts the
    compile count).

    Extra keyword arguments flow to :class:`~repro.core.mcts.MCTS` — in
    particular ``evaluator=EvalService(...)`` puts every bucket on the
    neural evaluation lane, after which the per-query ``prior_weight``
    knob blends UCT toward PUCT per request without a new trace
    (``prior_weight=0`` stays bit-identical to the unguided service).
    """

    def __init__(self, board_size: int = 9, komi: float = 6.0,
                 max_sims: int = 64, lanes: int = 8, slots: int = 8,
                 max_nodes: int = 0, superstep: int = 2, seed: int = 0,
                 queue_capacity: int = 0, mesh=None,
                 placement: str = "round_robin", pipeline_depth: int = 1,
                 admission_limit: int = 0,
                 deadline_policy: Optional[DeadlinePolicy] = None,
                 metrics: Optional[ServingMetrics] = None,
                 unified: bool = True, max_pipeline_depth: int = 0,
                 adaptive_depth: Optional[bool] = None,
                 borrowing: bool = True,
                 **mcts_kw):
        self.board_size = int(board_size)
        self.default_komi = float(komi)
        self.max_sims = int(max_sims)
        self.lanes = int(lanes)
        self.mesh = mesh
        self.placement = placement
        # pad the pool so every mesh shard gets an even share of slots
        self.slots = pad_slots(slots, mesh)
        self.max_nodes = int(max_nodes) or max(256, 4 * max_sims)
        self.superstep = superstep
        self.seed = seed
        self.queue_capacity = queue_capacity or 4 * self.slots
        self.pipeline_depth = int(pipeline_depth)
        self.admission_limit = int(admission_limit) or self.queue_capacity
        self.deadline_policy = deadline_policy or DeadlinePolicy(
            slots=self.slots)
        self.metrics = metrics or ServingMetrics()
        self.unified = bool(unified)
        # static depth clamp; > pipeline_depth gives the adaptive
        # controller headroom to raise the in-flight window
        self.max_pipeline_depth = (int(max_pipeline_depth)
                                   or self.pipeline_depth)
        if adaptive_depth is None:
            adaptive_depth = self.max_pipeline_depth > self.pipeline_depth
        self.adaptive_depth = bool(adaptive_depth)
        self.borrowing = bool(borrowing)
        self.mcts_kw = mcts_kw
        self._buckets: Dict[float, SearchService] = {}
        self._pipes: Dict[float, DispatchPipeline] = {}  # komi -> pipeline
        self._sched: Optional[BucketScheduler] = None
        self._poll_rot = 0        # per-bucket path: rotating pump offset
        self._tickets: Dict[int, _Ticket] = {}
        self._done: Dict[int, MoveResult] = {}
        self._shed_tickets: Dict[int, str] = {}    # ticket -> reason
        self._shed_new: List[int] = []             # shed since last pop_shed
        self._next_ticket = 0
        self._rng = np.random.default_rng(seed)
        if self.unified:
            svc = self._make_service(self.default_komi)
            self._buckets[self.default_komi] = svc
            self._sched = BucketScheduler(
                svc, depth=self.pipeline_depth,
                adaptive=self.adaptive_depth,
                max_depth=max(self.max_pipeline_depth, self.pipeline_depth),
                borrowing=self.borrowing)
            self._sched.bucket(self.default_komi)
        else:
            self._bucket(self.default_komi)   # compile the default bucket

    # ---------------------------------------------------------------- bucket

    def _make_service(self, komi: float) -> SearchService:
        """Build + reset one SearchService pool scored at ``komi``."""
        engine = GoEngine(self.board_size, komi=komi)
        cfg = MCTSConfig(board_size=self.board_size, komi=komi,
                         lanes=self.lanes, sims_per_move=self.max_sims,
                         max_nodes=self.max_nodes)
        player = MCTS(engine, cfg, **self.mcts_kw)
        svc = SearchService(engine, player, player, self.slots,
                            superstep=self.superstep, mesh=self.mesh,
                            placement=self.placement,
                            pipeline_depth=self.pipeline_depth)
        svc.reset(seed=self.seed, serve_capacity=self.queue_capacity,
                  game_capacity=2)
        return svc

    def _bucket(self, komi: float) -> SearchService:
        """The pool serving ``komi``: the shared one (unified — the komi
        just registers a scheduler bucket) or the komi's own (legacy)."""
        if self.unified:
            self._sched.bucket(komi)
            return self._buckets[self.default_komi]
        svc = self._buckets.get(komi)
        if svc is None:
            svc = self._make_service(komi)
            self._buckets[komi] = svc
            self._pipes[komi] = DispatchPipeline(svc)
        return svc

    @property
    def host_syncs(self) -> int:
        """Total blocking host<->device round-trips across all buckets."""
        return sum(b.host_syncs for b in self._buckets.values())

    @property
    def host_blocked_s(self) -> float:
        """Total time spent waiting on devices across all buckets."""
        return sum(b.host_blocked_s for b in self._buckets.values())

    @property
    def outstanding(self) -> int:
        """Submitted but neither answered nor shed, across all buckets."""
        return sum(b.outstanding for b in self._buckets.values())

    def shard_occupancy(self, komi: Optional[float] = None) -> np.ndarray:
        """Per-shard occupancy, aggregated across buckets.

        Unified mode has one pool, so every komi reads the same global
        occupancy.  Per-bucket mode returns the komi's own pool, or —
        with ``komi=None`` — the element-wise mean over all buckets'
        pools (each bucket owns a full ``slots``-wide pool there, so the
        mean is the fleet-level utilisation a capacity planner wants;
        with one bucket it degenerates to that bucket, the historical
        behaviour).
        """
        if self.unified:
            return self._buckets[self.default_komi].shard_occupancy()
        if komi is not None:
            return self._bucket(float(komi)).shard_occupancy()
        occ = [svc.shard_occupancy() for svc in self._buckets.values()]
        return np.mean(occ, axis=0)

    def scheduler_stats(self) -> dict:
        """Scheduler telemetry for ``/metrics``: per-bucket occupancy,
        queue depth, and the in-flight superstep count.

        Unified mode reports the single pipeline (current + max depth,
        adaptive-controller state) plus per-bucket queue depths and
        shard-partition sizes; per-bucket mode reports each bucket's own
        pipeline window.
        """
        if self.unified:
            s = self._sched.stats()
            s["unified"] = True
            s["in_flight_supersteps"] = self._sched.in_flight_supersteps
            s["per_bucket"] = {
                str(k): v for k, v in self._sched.bucket_stats().items()}
            return s
        return {
            "unified": False,
            "buckets": len(self._buckets),
            "per_bucket": {
                str(komi): {
                    "queue_depth": svc.outstanding,
                    "in_flight_supersteps":
                        self._pipes[komi].in_flight_supersteps,
                }
                for komi, svc in self._buckets.items()},
        }

    def _to_state(self, board, to_play: int, engine: GoEngine) -> GoState:
        b = np.asarray(board, np.int8).reshape(-1)
        if b.shape[0] != engine.n2:
            raise ValueError(f"board must have {engine.n2} points for "
                             f"{self.board_size}x{self.board_size}, "
                             f"got {b.shape[0]}")
        return GoState(board=jnp.asarray(b),
                       to_play=jnp.int8(to_play),
                       ko=jnp.int32(NO_KO),
                       pass_count=jnp.int32(0),
                       move_count=jnp.int32(0),
                       done=jnp.bool_(False))

    # ----------------------------------------------------------------- queue

    def submit(self, board, to_play: int = BLACK,
               komi: Optional[float] = None, sims: int = 0,
               key=None, c_uct: Optional[float] = None,
               virtual_loss: Optional[float] = None,
               prior_weight: Optional[float] = None,
               deadline_ms: Optional[float] = None) -> int:
        """Queue one best-move query; returns a ticket for :meth:`result`.

        Traced per-query knobs (no recompilation across values): ``sims``
        caps the playout budget (0 / > max_sims both mean ``max_sims``);
        ``c_uct`` / ``virtual_loss`` override the bucket's UCT constants
        (``None`` keeps the bucket defaults, bit-identical to omitting
        them); ``prior_weight`` sets the eval-lane UCT<->PUCT blend when
        the service was built with ``evaluator=`` (an
        :class:`repro.core.evaluator.EvalService` in ``mcts_kw``) — it is
        silently inert otherwise.  ``komi`` is traced too in unified
        mode (a new value just registers a scheduler bucket — zero
        recompilation); with ``unified=False`` it is static and a new
        value compiles its own pool.  ``key`` fixes the search RNG for
        reproducible answers (default: drawn from the service chain).

        SLO path: admission is queue-depth gated — past
        ``admission_limit`` outstanding requests in the bucket the query
        is shed with :class:`OverCapacityError` (counted
        ``shed_overload``).  ``deadline_ms`` (relative, wall) runs the
        :class:`DeadlinePolicy`: ``admit`` keeps the full budget,
        ``downgrade`` cuts the traced ``sims`` (counted; visible on the
        result), ``shed`` raises :class:`DeadlineExceededError` (counted
        ``shed_deadline``).  With ``deadline_ms=None`` the submission is
        bit-identical to the pre-SLO path.
        """
        komi = self.default_komi if komi is None else float(komi)
        svc = self._bucket(komi)
        now = time.monotonic()
        depth = (self._sched.buckets[komi].outstanding if self.unified
                 else svc.outstanding)
        if depth >= self.admission_limit:
            self.metrics.bump("shed_overload")
            raise OverCapacityError(
                f"bucket komi={komi} over capacity: {depth} outstanding "
                f">= admission limit {self.admission_limit}")
        full = int(sims) if 0 < int(sims) <= self.max_sims else self.max_sims
        deadline = None
        granted, downgraded = full, False
        if deadline_ms is not None:
            remaining = float(deadline_ms) / 1e3
            deadline = now + remaining
            verdict, granted = self.deadline_policy.decide(
                remaining, depth, full)
            if verdict == "shed":
                self.metrics.bump("shed_deadline")
                floor_est = self.deadline_policy.estimate_s(
                    self.deadline_policy.floor_sims, depth)
                raise DeadlineExceededError(
                    f"deadline {deadline_ms:.0f}ms unmeetable at queue "
                    f"depth {depth} (~{floor_est * 1e3:.0f}ms needed at "
                    "the floor budget)")
            downgraded = verdict == "downgrade"
            if downgraded:
                self.metrics.bump("downgraded")
        if key is None:
            key = self._rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)
        state = self._to_state(board, to_play, svc.engine)
        if self.unified:
            inner = self._sched.submit_serve(
                komi, state, key=key, sims=granted, c_uct=c_uct,
                virtual_loss=virtual_loss, prior_weight=prior_weight,
                deadline=deadline)
        else:
            inner = svc.submit_serve(state, key=key, sims=granted,
                                     c_uct=c_uct, virtual_loss=virtual_loss,
                                     prior_weight=prior_weight,
                                     deadline=deadline)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._tickets[ticket] = _Ticket(komi, inner, now, deadline,
                                        granted, downgraded, depth)
        self.metrics.bump("submitted")
        return ticket

    def flush(self) -> None:
        """Push every bucket's queued submissions to its device queues."""
        for svc in self._buckets.values():
            svc.flush()
        self._mark_flushed(time.monotonic())

    def _mark_flushed(self, now: float,
                      komi: Optional[float] = None) -> None:
        """Stamp queue-exit time on tickets that just left the host."""
        for t in self._tickets.values():
            if t.t_flush is None and (komi is None or t.komi == komi):
                t.t_flush = now

    def _shed_ticket(self, ticket: int, reason: str) -> None:
        self._shed_tickets[ticket] = reason
        self._shed_new.append(ticket)
        self.metrics.bump("shed_deadline")

    def pop_shed(self) -> Dict[int, str]:
        """Drain tickets shed since the last call (``ticket -> reason``).

        The HTTP front door's pump loop uses this to fail the matching
        waiters; :meth:`result` reports the same tickets by raising
        :class:`DeadlineExceededError`.
        """
        out = {t: self._shed_tickets[t] for t in self._shed_new}
        self._shed_new.clear()
        return out

    def _shed_with_calibration(self, ticket: int, now: float) -> None:
        """Shed one expired ticket; its wait is a censored latency
        sample, so it still calibrates the deadline policy (one-sided)."""
        t = self._tickets[ticket]
        self.deadline_policy.observe_censored(
            now - t.t_submit, t.sims_granted, t.depth)
        self._shed_ticket(ticket, "deadline")

    def _record_done(self, ticket: int, rec, engine: GoEngine) -> None:
        """Unpack one reconcile record into its ticket's MoveResult and
        land the request's stage latencies in the metrics + policy."""
        is_pass = rec.action >= engine.n2
        coord = (None if is_pass else
                 (rec.action // self.board_size,
                  rec.action % self.board_size))
        t = self._tickets[ticket]
        t_done = time.monotonic()
        total = t_done - t.t_submit
        queue = (t.t_flush - t.t_submit
                 if t.t_flush is not None else None)
        dispatch = (t_done - t.t_flush
                    if t.t_flush is not None else None)
        missed = t.deadline is not None and t_done > t.deadline
        self.metrics.observe(queue, dispatch, total,
                             deadline_missed=missed)
        self.deadline_policy.observe(total, t.sims_granted, t.depth)
        self._done[ticket] = MoveResult(
            ticket=ticket, action=rec.action, coord=coord,
            is_pass=is_pass, root_visits=rec.root_visits,
            sims_granted=t.sims_granted, downgraded=t.downgraded,
            latency_s=total)

    def poll(self) -> List[int]:
        """Pump the scheduler (or every bucket's pipeline); returns
        newly done tickets.

        Each call sheds expired host-buffered queries
        (``SearchService.shed_expired`` — they never reach the device,
        and their waits calibrate the deadline policy as censored
        samples), flushes the rest, tops the in-flight window(s) up to
        the pipeline depth, and reconciles the oldest superstep — at
        depth 1 exactly the old flush -> dispatch -> poll superstep;
        deeper windows leave the device running while the host unpacks
        answers.  Unified mode does all of this **once** for every
        bucket (one pump, one reconcile — host cost independent of
        bucket count); per-bucket mode loops the buckets, rotating the
        start offset each call so every bucket periodically gets the
        round's first flush.  Completed requests land their
        queue/dispatch/total latencies in :attr:`metrics` and
        recalibrate the deadline policy.
        """
        if self.unified:
            return self._poll_unified()
        done = []
        inner_to_ticket = {(t.komi, t.inner): ticket
                           for ticket, t in self._tickets.items()
                           if ticket not in self._done
                           and ticket not in self._shed_tickets}
        items = list(self._buckets.items())
        if len(items) > 1:            # pump fairness: rotate the start
            off = self._poll_rot % len(items)
            self._poll_rot += 1
            items = items[off:] + items[:off]
        for komi, svc in items:
            if svc.outstanding == 0:
                continue
            now = time.monotonic()
            for inner in svc.shed_expired(now):
                ticket = inner_to_ticket.pop((komi, inner), None)
                if ticket is not None:
                    self._shed_with_calibration(ticket, now)
            pipe = self._pipes[komi]
            pipe.pump()
            self._mark_flushed(time.monotonic(), komi=komi)
            for rec in pipe.reconcile():
                ticket = inner_to_ticket.get((komi, rec.ticket))
                if ticket is None:
                    continue        # a game lane sharing the bucket
                self._record_done(ticket, rec, svc.engine)
                done.append(ticket)
        return done

    def _poll_unified(self) -> List[int]:
        """One scheduler round: shed, pump once, reconcile once —
        every bucket's work moves in a single superstep stream."""
        done: List[int] = []
        svc = self._buckets[self.default_komi]
        if svc.outstanding == 0:
            return done
        inner_to_ticket = {t.inner: ticket
                           for ticket, t in self._tickets.items()
                           if ticket not in self._done
                           and ticket not in self._shed_tickets}
        now = time.monotonic()
        for inner in self._sched.shed_expired(now):
            ticket = inner_to_ticket.pop(inner, None)
            if ticket is not None:
                self._shed_with_calibration(ticket, now)
        self._sched.pump()
        self._mark_flushed(time.monotonic())
        for rec in self._sched.reconcile():
            ticket = inner_to_ticket.get(rec.ticket)
            if ticket is None:
                continue            # a game lane sharing the pool
            self._record_done(ticket, rec, svc.engine)
            done.append(ticket)
        return done

    def result(self, ticket: int, wait: bool = True,
               timeout_s: Optional[float] = None,
               max_polls: int = 10_000) -> Optional[MoveResult]:
        """Fetch a ticket's move; blocks (dispatching) unless ``wait=False``.

        ``timeout_s`` bounds the blocking wait in wall time and raises
        ``TimeoutError`` past it — without a timeout a lost ticket could
        spin the poll loop for ``max_polls`` rounds before the fallback
        ``RuntimeError``, which is the hang the HTTP server must never
        inherit.  A ticket shed for its deadline raises
        :class:`DeadlineExceededError`; an unknown one raises
        ``KeyError``.
        """
        if ticket not in self._tickets:
            raise KeyError(f"unknown ticket {ticket}")
        t0 = time.monotonic()
        polls = 0
        while ticket not in self._done:
            if ticket in self._shed_tickets:
                reason = self._shed_tickets.pop(ticket)
                del self._tickets[ticket]
                raise DeadlineExceededError(
                    f"ticket {ticket} was shed ({reason}) before dispatch")
            if not wait:
                return None
            if timeout_s is not None \
                    and time.monotonic() - t0 > timeout_s:
                raise TimeoutError(
                    f"ticket {ticket} not done within {timeout_s:.3f}s "
                    f"({polls} polls; the bucket may be overloaded — "
                    "raise timeout_s or shed load)")
            if polls >= max_polls:
                raise RuntimeError(f"ticket {ticket} not done after "
                                   f"{polls} polls")
            self.poll()
            polls += 1
        del self._tickets[ticket]
        return self._done.pop(ticket)

    # ------------------------------------------------------------ one-liners

    def best_move(self, board, to_play: int = BLACK,
                  komi: Optional[float] = None, sims: int = 0,
                  key=None, c_uct: Optional[float] = None,
                  virtual_loss: Optional[float] = None,
                  prior_weight: Optional[float] = None,
                  deadline_ms: Optional[float] = None,
                  timeout_s: Optional[float] = None) -> MoveResult:
        """Blocking single query: board in, move out.

        ``sims`` / ``c_uct`` / ``virtual_loss`` / ``prior_weight`` are
        the traced per-query knobs of :meth:`submit` (they never
        recompile the bucket); ``deadline_ms`` engages the SLO path
        (downgrade or shed) and ``timeout_s`` bounds the blocking wait.
        """
        return self.result(self.submit(board, to_play, komi, sims, key,
                                       c_uct=c_uct,
                                       virtual_loss=virtual_loss,
                                       prior_weight=prior_weight,
                                       deadline_ms=deadline_ms),
                           timeout_s=timeout_s)

    def best_move_batch(self, boards, to_play: int = BLACK,
                        komi: Optional[float] = None, sims: int = 0,
                        prior_weight: Optional[float] = None,
                        ) -> List[MoveResult]:
        """Queue a batch of queries, then poll them all (one pool pass)."""
        tickets = [self.submit(b, to_play, komi, sims,
                               prior_weight=prior_weight) for b in boards]
        self.flush()
        return [self.result(t) for t in tickets]
