"""GoService: batched external best-move queries (queue -> ticket -> poll).

The Go-side counterpart of :class:`~repro.serving.engine.ServeEngine`'s
fixed-bucket pattern: requests are admitted into a fixed-capacity
SearchService slot pool so one compiled dispatch serves every query.  The
static bucket axes are ``(board_size, komi, max_sims)`` — a new komi opens
a new bucket (engine komi is baked into playout scoring), while the
per-request ``sims`` budget **and the per-request strength knobs**
``c_uct`` / ``virtual_loss`` are *traced* (masked search tail; per-lane
scalar broadcast), so budgets from 1 to ``max_sims`` and arbitrary UCT
configurations share one executable — a caller can dial a query's
exploration per request with zero recompilation.

A query is a pure function of
``(board, to_play, sims, c_uct, virtual_loss, key)``: the dispatcher
admits serve tickets only into cells searched by the bucket's single
player, and the search consumes the request key directly, so results do
not depend on slot placement or on what else shares the batch
(tests/test_service.py and tests/test_multiplex.py pin this).

Typical use::

    svc = GoService(board_size=9, komi=6.0, max_sims=256)
    move = svc.best_move(board)                 # one blocking query
    tickets = [svc.submit(b) for b in boards]   # batched: queue ...
    moves = [svc.result(t) for t in tickets]    # ... then poll tickets
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core.service import SearchService, pad_slots
from repro.core.streaming import DispatchPipeline
from repro.go.board import BLACK, NO_KO, GoEngine, GoState


class MoveResult(NamedTuple):
    """One answered best-move query."""
    ticket: int
    action: int               # 0..n2-1 point, n2 = pass
    coord: Optional[Tuple[int, int]]   # (row, col), None for pass
    is_pass: bool
    root_visits: np.ndarray   # f32[A] root visit distribution


class GoService:
    """Fixed-bucket batched Go move service over SearchService pools.

    ``mesh=`` shards every bucket's slot pool over a one-axis device mesh
    (``placement`` routes queries to shards, core/placement.py); serve
    answers are placement-independent by the dispatcher's RNG contract,
    so sharding only changes throughput, never a move.

    ``pipeline_depth`` streams the serve loop: each bucket drives a
    persistent :class:`~repro.core.streaming.DispatchPipeline`, so
    :meth:`poll` keeps up to that many supersteps in flight instead of
    awaiting each one — queued queries, result unpacking, and placement
    overlap with device search.  Answers are unchanged at any depth (the
    serve RNG contract makes them pure functions of the query).
    """

    def __init__(self, board_size: int = 9, komi: float = 6.0,
                 max_sims: int = 64, lanes: int = 8, slots: int = 8,
                 max_nodes: int = 0, superstep: int = 2, seed: int = 0,
                 queue_capacity: int = 0, mesh=None,
                 placement: str = "round_robin", pipeline_depth: int = 1,
                 **mcts_kw):
        self.board_size = int(board_size)
        self.default_komi = float(komi)
        self.max_sims = int(max_sims)
        self.lanes = int(lanes)
        self.mesh = mesh
        self.placement = placement
        # pad the pool so every mesh shard gets an even share of slots
        self.slots = pad_slots(slots, mesh)
        self.max_nodes = int(max_nodes) or max(256, 4 * max_sims)
        self.superstep = superstep
        self.seed = seed
        self.queue_capacity = queue_capacity or 4 * self.slots
        self.pipeline_depth = int(pipeline_depth)
        self.mcts_kw = mcts_kw
        self._buckets: Dict[float, SearchService] = {}
        self._pipes: Dict[float, DispatchPipeline] = {}  # komi -> pipeline
        self._tickets: Dict[int, Tuple[float, int]] = {}  # ticket -> bucket
        self._done: Dict[int, MoveResult] = {}
        self._next_ticket = 0
        self._rng = np.random.default_rng(seed)
        self._bucket(self.default_komi)       # compile the default bucket

    # ---------------------------------------------------------------- bucket

    def _bucket(self, komi: float) -> SearchService:
        svc = self._buckets.get(komi)
        if svc is None:
            engine = GoEngine(self.board_size, komi=komi)
            cfg = MCTSConfig(board_size=self.board_size, komi=komi,
                             lanes=self.lanes, sims_per_move=self.max_sims,
                             max_nodes=self.max_nodes)
            player = MCTS(engine, cfg, **self.mcts_kw)
            svc = SearchService(engine, player, player, self.slots,
                                superstep=self.superstep, mesh=self.mesh,
                                placement=self.placement,
                                pipeline_depth=self.pipeline_depth)
            svc.reset(seed=self.seed, serve_capacity=self.queue_capacity,
                      game_capacity=2)
            self._buckets[komi] = svc
            self._pipes[komi] = DispatchPipeline(svc)
        return svc

    @property
    def host_syncs(self) -> int:
        """Total blocking host<->device round-trips across all buckets."""
        return sum(b.host_syncs for b in self._buckets.values())

    @property
    def host_blocked_s(self) -> float:
        """Total time spent waiting on devices across all buckets."""
        return sum(b.host_blocked_s for b in self._buckets.values())

    def shard_occupancy(self, komi: Optional[float] = None) -> np.ndarray:
        """Per-shard occupancy of one bucket's pool (default bucket)."""
        komi = self.default_komi if komi is None else float(komi)
        return self._bucket(komi).shard_occupancy()

    def _to_state(self, board, to_play: int, engine: GoEngine) -> GoState:
        b = np.asarray(board, np.int8).reshape(-1)
        if b.shape[0] != engine.n2:
            raise ValueError(f"board must have {engine.n2} points for "
                             f"{self.board_size}x{self.board_size}, "
                             f"got {b.shape[0]}")
        return GoState(board=jnp.asarray(b),
                       to_play=jnp.int8(to_play),
                       ko=jnp.int32(NO_KO),
                       pass_count=jnp.int32(0),
                       move_count=jnp.int32(0),
                       done=jnp.bool_(False))

    # ----------------------------------------------------------------- queue

    def submit(self, board, to_play: int = BLACK,
               komi: Optional[float] = None, sims: int = 0,
               key=None, c_uct: Optional[float] = None,
               virtual_loss: Optional[float] = None) -> int:
        """Queue one best-move query; returns a ticket for :meth:`result`.

        Traced per-query knobs (no recompilation across values): ``sims``
        caps the playout budget (0 / > max_sims both mean ``max_sims``);
        ``c_uct`` / ``virtual_loss`` override the bucket's UCT constants
        (``None`` keeps the bucket defaults, bit-identical to omitting
        them).  ``komi`` is *static* — a new value opens a new bucket and
        compiles.  ``key`` fixes the search RNG for reproducible answers
        (default: drawn from the service chain).
        """
        komi = self.default_komi if komi is None else float(komi)
        svc = self._bucket(komi)
        if key is None:
            key = self._rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)
        state = self._to_state(board, to_play, svc.engine)
        inner = svc.submit_serve(state, key=key, sims=int(sims),
                                 c_uct=c_uct, virtual_loss=virtual_loss)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._tickets[ticket] = (komi, inner)
        return ticket

    def flush(self) -> None:
        """Push every bucket's queued submissions to its device queues."""
        for svc in self._buckets.values():
            svc.flush()

    def poll(self) -> List[int]:
        """Pump every bucket's pipeline; returns newly done tickets.

        Each call flushes queued queries, tops the bucket's in-flight
        window up to ``pipeline_depth`` supersteps, and reconciles the
        oldest one — at depth 1 exactly the old flush -> dispatch ->
        poll superstep; deeper windows leave the device running while
        the host unpacks answers.
        """
        done = []
        inner_to_ticket = {(k, inn): t
                           for t, (k, inn) in self._tickets.items()
                           if t not in self._done}
        for komi, svc in self._buckets.items():
            if svc.outstanding == 0:
                continue
            pipe = self._pipes[komi]
            pipe.pump()
            for rec in pipe.reconcile():
                ticket = inner_to_ticket.get((komi, rec.ticket))
                if ticket is None:
                    continue        # a game lane sharing the bucket
                n2 = svc.engine.n2
                is_pass = rec.action >= n2
                coord = (None if is_pass else
                         (rec.action // self.board_size,
                          rec.action % self.board_size))
                self._done[ticket] = MoveResult(
                    ticket=ticket, action=rec.action, coord=coord,
                    is_pass=is_pass, root_visits=rec.root_visits)
                done.append(ticket)
        return done

    def result(self, ticket: int, wait: bool = True,
               max_polls: int = 10_000) -> Optional[MoveResult]:
        """Fetch a ticket's move; blocks (dispatching) unless ``wait=False``."""
        if ticket not in self._tickets:
            raise KeyError(f"unknown ticket {ticket}")
        polls = 0
        while ticket not in self._done:
            if not wait:
                return None
            if polls >= max_polls:
                raise RuntimeError(f"ticket {ticket} not done after "
                                   f"{polls} polls")
            self.poll()
            polls += 1
        del self._tickets[ticket]
        return self._done.pop(ticket)

    # ------------------------------------------------------------ one-liners

    def best_move(self, board, to_play: int = BLACK,
                  komi: Optional[float] = None, sims: int = 0,
                  key=None, c_uct: Optional[float] = None,
                  virtual_loss: Optional[float] = None) -> MoveResult:
        """Blocking single query: board in, move out.

        ``sims`` / ``c_uct`` / ``virtual_loss`` are the traced per-query
        knobs of :meth:`submit` (they never recompile the bucket).
        """
        return self.result(self.submit(board, to_play, komi, sims, key,
                                       c_uct=c_uct,
                                       virtual_loss=virtual_loss))

    def best_move_batch(self, boards, to_play: int = BLACK,
                        komi: Optional[float] = None,
                        sims: int = 0) -> List[MoveResult]:
        """Queue a batch of queries, then poll them all (one pool pass)."""
        tickets = [self.submit(b, to_play, komi, sims) for b in boards]
        self.flush()
        return [self.result(t) for t in tickets]
