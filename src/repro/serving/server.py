"""SLO-aware asyncio HTTP front door over :class:`GoService`.

The production serving tier of the reproduction (ROADMAP item 2): a thin
network surface over the ticketed queue -> poll protocol, so the PR 5
streaming pipeline's host/device overlap becomes *user-visible* latency
instead of an internal ``host_blocked_s`` counter.  Pure stdlib asyncio
(no framework dependency): requests are parsed off the stream, JSON in /
JSON out, connections keep-alive.

Endpoints::

    POST /v1/submit     {board, to_play?, komi?, sims?, c_uct?,
                         virtual_loss?, key?, deadline_ms?} -> {ticket}
    GET  /v1/result/T   {done: false} | the move payload | 410 if shed
    POST /v1/best_move  submit + await in one call (same body)
    GET  /metrics       ServingMetrics snapshot + outstanding depth
    GET  /healthz       {ok: true}

Load shedding is an HTTP status, never a hang: 503 for over-capacity
admission, 504 for a deadline shed (unmeetable at admission, expired in
queue, or still unanswered at its deadline).  Requests already on the
device always complete — a late answer is served with
``deadline_missed: true`` and counted, which is the honest half of the
SLO contract (the device program cannot be preempted mid-superstep).

Threading model: **all** GoService access runs on one single-thread
executor (``_call``) — submissions, polls, metrics reads — so the
service needs no internal locking and the asyncio event loop never
blocks on a device superstep.  One background pump task drives
``GoService.poll()`` whenever work is outstanding and resolves the
per-ticket futures that blocking ``best_move`` callers await.
"""
from __future__ import annotations

import asyncio
import functools
import json
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Tuple

from repro.serving.go_service import (DeadlineExceededError, GoService,
                                      MoveResult, OverCapacityError)

_JSON = {"Content-Type": "application/json"}


def _move_payload(res: MoveResult) -> dict:
    """JSON shape of one answered query (floats stay bit-exact: every
    float32 is exactly representable as a JSON double)."""
    return {
        "done": True,
        "ticket": res.ticket,
        "action": int(res.action),
        "coord": list(res.coord) if res.coord is not None else None,
        "is_pass": bool(res.is_pass),
        "root_visits": [float(v) for v in res.root_visits],
        "sims_granted": int(res.sims_granted),
        "downgraded": bool(res.downgraded),
        "latency_ms": res.latency_s * 1e3,
    }


class GoMoveServer:
    """Asyncio HTTP server wrapping one :class:`GoService`.

    ``poll_idle_s`` is the pump task's sleep when no work is
    outstanding; with work queued the pump spins as fast as the device
    answers (each ``poll()`` blocks in the executor on a superstep, not
    in the event loop).  ``await start()`` binds (port 0 picks a free
    one — the tests and the load bench use that), ``await stop()``
    drains the pump and closes the listener.
    """

    def __init__(self, service: GoService, poll_idle_s: float = 0.002,
                 best_move_timeout_s: float = 300.0):
        self.service = service
        self.poll_idle_s = poll_idle_s
        self.best_move_timeout_s = best_move_timeout_s
        self.host: Optional[str] = None
        self.port: Optional[int] = None
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="goservice")
        self._futures: Dict[int, asyncio.Future] = {}
        self._server: Optional[asyncio.AbstractServer] = None
        self._pump_task: Optional[asyncio.Task] = None

    # ------------------------------------------------------------- lifecycle

    async def start(self, host: str = "127.0.0.1", port: int = 0) -> int:
        """Bind and start serving; returns the bound port."""
        self._server = await asyncio.start_server(self._handle, host, port)
        self.host = host
        self.port = self._server.sockets[0].getsockname()[1]
        self._pump_task = asyncio.ensure_future(self._pump_loop())
        return self.port

    async def stop(self) -> None:
        """Stop the listener and the pump task; fail pending waiters."""
        if self._pump_task is not None:
            self._pump_task.cancel()
            try:
                await self._pump_task
            except asyncio.CancelledError:
                pass
            self._pump_task = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for fut in self._futures.values():
            if not fut.done():
                fut.set_exception(ConnectionError("server stopped"))
        self._futures.clear()
        self._exec.shutdown(wait=False)

    async def _call(self, fn, *args, **kw):
        """Run one GoService operation on the single service thread."""
        loop = asyncio.get_event_loop()
        if kw:
            fn = functools.partial(fn, **kw)
        return await loop.run_in_executor(self._exec, fn, *args)

    # ------------------------------------------------------------- pump loop

    def _pump_once(self):
        """One service-thread pump: poll, fetch results, drain sheds."""
        svc = self.service
        done = []
        if svc.outstanding > 0:
            for ticket in svc.poll():
                done.append((ticket, svc.result(ticket, wait=False)))
        shed = []
        for ticket, reason in svc.pop_shed().items():
            try:                 # consume the shed ticket's bookkeeping
                svc.result(ticket, wait=False)
            except DeadlineExceededError:
                pass
            shed.append((ticket, reason))
        return done, shed

    async def _pump_loop(self) -> None:
        """Drive GoService.poll() and resolve per-ticket futures."""
        while True:
            done, shed = await self._call(self._pump_once)
            for ticket, res in done:
                fut = self._futures.get(ticket)
                if fut is not None and not fut.done():
                    fut.set_result(res)
            for ticket, reason in shed:
                fut = self._futures.get(ticket)
                if fut is not None and not fut.done():
                    fut.set_exception(DeadlineExceededError(
                        f"ticket {ticket} shed ({reason})"))
            if not done and not shed:
                await asyncio.sleep(self.poll_idle_s)

    # --------------------------------------------------------------- routing

    def _submit(self, body: dict) -> int:
        """Service-thread submission; raises the shed exceptions."""
        key = body.get("key")
        return self.service.submit(
            body["board"],
            to_play=int(body.get("to_play", 1)),
            komi=body.get("komi"),
            sims=int(body.get("sims", 0)),
            key=key if key is None else list(key),
            c_uct=body.get("c_uct"),
            virtual_loss=body.get("virtual_loss"),
            deadline_ms=body.get("deadline_ms"),
        )

    async def _route(self, method: str, path: str,
                     body: Optional[dict]) -> Tuple[int, dict]:
        """Dispatch one parsed request to its handler."""
        if method == "GET" and path == "/healthz":
            return 200, {"ok": True}
        if method == "GET" and path == "/metrics":
            return 200, await self._call(self._metrics_payload)
        if method == "POST" and path in ("/v1/submit", "/v1/best_move"):
            if body is None or "board" not in body:
                return 400, {"error": "bad_request",
                             "detail": "JSON body with 'board' required"}
            loop = asyncio.get_event_loop()
            try:
                ticket = await self._call(self._submit, body)
            except OverCapacityError as e:
                return 503, {"error": "over_capacity", "detail": str(e)}
            except DeadlineExceededError as e:
                return 504, {"error": "deadline_shed", "detail": str(e)}
            except (KeyError, TypeError, ValueError) as e:
                return 400, {"error": "bad_request", "detail": str(e)}
            fut = loop.create_future()
            self._futures[ticket] = fut
            if path == "/v1/submit":
                return 200, {"ticket": ticket}
            try:
                # late answers are served (flagged + counted as misses),
                # so the wait bound is the server's, not the deadline's
                timeout = self.best_move_timeout_s
                res = await asyncio.wait_for(fut, timeout)
            except DeadlineExceededError as e:
                return 504, {"error": "deadline_shed", "detail": str(e)}
            except asyncio.TimeoutError:
                return 504, {"error": "timeout",
                             "detail": f"no answer in {timeout:.1f}s"}
            finally:
                self._futures.pop(ticket, None)
            return 200, self._finish(res, body)
        if method == "GET" and path.startswith("/v1/result/"):
            try:
                ticket = int(path.rsplit("/", 1)[1])
            except ValueError:
                return 400, {"error": "bad_request",
                             "detail": "ticket must be an integer"}
            fut = self._futures.get(ticket)
            if fut is None:
                return 404, {"error": "unknown_ticket", "ticket": ticket}
            if not fut.done():
                return 200, {"done": False, "ticket": ticket}
            self._futures.pop(ticket, None)
            try:
                res = fut.result()
            except DeadlineExceededError as e:
                return 410, {"error": "deadline_shed", "detail": str(e)}
            return 200, _move_payload(res)
        return 404, {"error": "not_found", "path": path}

    def _finish(self, res: MoveResult, body: dict) -> dict:
        """Annotate a served answer with its deadline verdict."""
        payload = _move_payload(res)
        deadline_ms = body.get("deadline_ms")
        payload["deadline_missed"] = bool(
            deadline_ms is not None and payload["latency_ms"] > deadline_ms)
        return payload

    def _metrics_payload(self) -> dict:
        """Service-thread /metrics snapshot."""
        svc = self.service
        return {
            "metrics": svc.metrics.snapshot(),
            "outstanding": svc.outstanding,
            "buckets": sorted(
                svc._sched.buckets if svc.unified else svc._buckets),
            "admission_limit": svc.admission_limit,
            "host_syncs": svc.host_syncs,
            "host_blocked_s": svc.host_blocked_s,
            # per-bucket occupancy / queue depth / in-flight supersteps
            "scheduler": svc.scheduler_stats(),
            "shard_occupancy": [float(x) for x in svc.shard_occupancy()],
        }

    # ------------------------------------------------------------------ http

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        """Serve one keep-alive connection: parse, route, respond."""
        try:
            while True:
                line = await reader.readline()
                if not line or line in (b"\r\n", b"\n"):
                    break
                try:
                    method, path, _ = line.decode("latin1").split()
                except ValueError:
                    break
                headers = {}
                while True:
                    h = await reader.readline()
                    if h in (b"\r\n", b"\n", b""):
                        break
                    k, _, v = h.decode("latin1").partition(":")
                    headers[k.strip().lower()] = v.strip()
                length = int(headers.get("content-length", 0))
                raw = await reader.readexactly(length) if length else b""
                body = None
                if raw:
                    try:
                        body = json.loads(raw)
                    except json.JSONDecodeError:
                        body = None
                try:
                    status, payload = await self._route(method, path, body)
                except Exception as e:   # never drop a connection silently
                    status, payload = 500, {"error": "internal",
                                            "detail": repr(e)}
                data = json.dumps(payload).encode()
                writer.write(
                    b"HTTP/1.1 %d OK\r\n"
                    b"Content-Type: application/json\r\n"
                    b"Content-Length: %d\r\n"
                    b"Connection: keep-alive\r\n\r\n"
                    % (status, len(data)))
                writer.write(data)
                await writer.drain()
                if headers.get("connection", "").lower() == "close":
                    break
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass


async def http_json(host: str, port: int, method: str, path: str,
                    payload: Optional[dict] = None,
                    timeout_s: float = 120.0) -> Tuple[int, dict]:
    """Minimal one-shot JSON-over-HTTP client (stdlib asyncio streams).

    The test suite and benchmarks/bench_load.py drive the front door
    with this instead of pulling in an HTTP client dependency.  Returns
    ``(status, decoded_body)``.
    """
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = b"" if payload is None else json.dumps(payload).encode()
        head = (f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
                f"Content-Type: application/json\r\n"
                f"Content-Length: {len(body)}\r\nConnection: close\r\n\r\n")
        writer.write(head.encode() + body)
        await writer.drain()

        async def read_all():
            status_line = await reader.readline()
            status = int(status_line.split()[1])
            length = None
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin1").partition(":")
                if k.strip().lower() == "content-length":
                    length = int(v)
            raw = (await reader.readexactly(length) if length is not None
                   else await reader.read())
            return status, json.loads(raw) if raw else {}

        return await asyncio.wait_for(read_all(), timeout_s)
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
