"""Go move-serving launcher: batched best-move queries via GoService.

Simulates external traffic: random mid-game positions are queued as serve
tickets and answered through the SearchService dispatcher's slot pool.
``--pipeline-depth K`` streams the serve loop — up to K supersteps stay
in flight while the host queues fresh queries and unpacks answers
(``host blocked`` in the report is the time that overlap removes).
``--eval-config`` serves through the neural evaluation lane
(core/evaluator.py); ``--prior-weight`` then blends UCT toward PUCT per
query without retracing.

    PYTHONPATH=src python -m repro.launch.serve_go --board 5 --sims 32 \
        --queries 8 --prefix-moves 6 --pipeline-depth 4
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.go import GoEngine
from repro.go.board import BLACK
from repro.serving.go_service import GoService


def random_position(engine: GoEngine, rng: np.random.Generator,
                    moves: int) -> tuple[np.ndarray, int]:
    """A plausible mid-game board: ``moves`` uniform legal non-pass moves."""
    import jax.numpy as jnp
    st = engine.init_state()
    for _ in range(moves):
        legal = np.asarray(engine.jit_legal(st))[: engine.n2]
        if not legal.any():
            break
        st = engine.jit_play(st, jnp.int32(rng.choice(np.where(legal)[0])))
    return np.asarray(st.board), int(st.to_play)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--board", type=int, default=9)
    ap.add_argument("--komi", type=float, default=6.0)
    ap.add_argument("--sims", type=int, default=64,
                    help="max playout budget per query (bucket size)")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8,
                    help="concurrent queries per dispatch")
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--prefix-moves", type=int, default=8,
                    help="random moves played before each queried position")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--c-uct", type=float, default=None,
                    help="per-query UCT exploration constant (traced: "
                         "any value reuses the compiled bucket)")
    ap.add_argument("--virtual-loss", type=float, default=None,
                    help="per-query virtual-loss weight (traced)")
    ap.add_argument("--eval-config", default=None, metavar="SPEC",
                    help="serve through the neural evaluation lane: a "
                         "k=v,k=v EvalConfig spec, e.g. "
                         "'d_model=64,ckpt_dir=/tmp/net' (board_size is "
                         "taken from --board); empty string = defaults")
    ap.add_argument("--prior-weight", type=float, default=None,
                    help="per-query UCT<->PUCT blend weight (traced; "
                         "needs --eval-config; 0 = unguided)")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the serving pool over this many devices")
    ap.add_argument("--placement", default="round_robin",
                    help="query->shard policy (repro.core.placement)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="supersteps kept in flight by the streaming "
                         "dispatch pipeline (1 = synchronous)")
    args = ap.parse_args()

    mesh = None
    if args.shards > 1:
        from repro.compat import make_service_mesh
        mesh = make_service_mesh(args.shards)

    mcts_kw = {}
    if args.eval_config is not None:
        from repro.core.evaluator import EvalConfig, EvalService
        cfg = EvalConfig.parse(args.eval_config, board_size=args.board)
        mcts_kw["evaluator"] = EvalService(cfg)

    engine = GoEngine(args.board, args.komi)
    rng = np.random.default_rng(args.seed)
    svc = GoService(board_size=args.board, komi=args.komi,
                    max_sims=args.sims, lanes=args.lanes, slots=args.slots,
                    seed=args.seed, mesh=mesh, placement=args.placement,
                    pipeline_depth=args.pipeline_depth, **mcts_kw)

    boards = [random_position(engine, rng, args.prefix_moves)
              for _ in range(args.queries)]

    # streaming serve loop: queue everything, then collect — result()
    # polls through the bucket pipelines, which keep pipeline-depth
    # supersteps in flight (and stall-guard with max_polls)
    t0 = time.time()
    tickets = [svc.submit(b, to_play=tp, c_uct=args.c_uct,
                          virtual_loss=args.virtual_loss,
                          prior_weight=args.prior_weight)
               for b, tp in boards]
    svc.flush()
    results = [svc.result(t) for t in tickets]
    dt = time.time() - t0

    for (board, to_play), res in zip(boards, results):
        mover = "B" if to_play == BLACK else "W"
        mv = "pass" if res.is_pass else f"{res.coord[0]},{res.coord[1]}"
        top = float(res.root_visits.max())
        print(f"ticket {res.ticket}: {mover} to play -> {mv} "
              f"({top:.0f} visits)")
    sims = args.queries * args.sims
    print(f"{args.queries} queries in {dt:.2f}s "
          f"({args.queries / dt:.1f} moves/s, ~{sims / dt:.0f} sims/s, "
          f"{svc.host_syncs} host syncs, "
          f"{svc.host_blocked_s:.2f}s host blocked, "
          f"pipeline depth {args.pipeline_depth})")
    if mesh is not None:
        print("shard occupancy: "
              + " ".join(f"{o:.2f}" for o in svc.shard_occupancy()))


if __name__ == "__main__":
    main()
