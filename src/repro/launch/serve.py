"""Serving launcher: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
        --batch 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_model_config
from repro.configs.reduced import reduce_config
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.models import sharding as shlib
from repro.serving import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode step")
    mesh = make_mesh(args.data_mesh, args.model_mesh)

    with shlib.use_mesh(mesh):
        model = build_model(cfg, mesh=mesh)
        params = model.init(jax.random.PRNGKey(args.seed))
        engine = ServeEngine(model, params, batch=args.batch,
                             max_prompt=args.max_prompt,
                             max_new=args.max_new,
                             temperature=args.temperature)

        rng = np.random.default_rng(args.seed)
        frontend = None
        if cfg.frontend_tokens:
            frontend = jax.numpy.asarray(rng.standard_normal(
                (args.batch, cfg.frontend_tokens, 1024), dtype=np.float32))
        done = 0
        t0 = time.time()
        while done < args.requests:
            n = min(args.batch, args.requests - done)
            prompts = [list(rng.integers(3, cfg.vocab_size,
                                         rng.integers(4, args.max_prompt)))
                       for _ in range(n)]
            outs = engine.generate(prompts, seed=args.seed + done,
                                   frontend=frontend)
            for i, o in enumerate(outs):
                print(f"req {done + i}: prompt {len(prompts[i])} toks -> "
                      f"{len(o)} new: {o[:10]}...")
            done += n
        dt = time.time() - t0
        total_new = args.requests * args.max_new
        print(f"{args.requests} requests, ~{total_new} tokens in {dt:.1f}s "
              f"({total_new / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
