"""Training launcher: the end-to-end driver (deliverable (b)).

Composes every substrate: config registry (--arch), mesh, sharded train
step with microbatch accumulation, deterministic resumable data pipeline,
async checkpointing, preemption handling, heartbeats and straggler
monitoring.  On this CPU container it trains reduced configs (see
``--reduced``); on a pod the same driver runs the full configs.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 50 --batch 8 --seq 128 --data-mesh 1 --model-mesh 1
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.config import TrainConfig, get_model_config
from repro.configs.reduced import reduce_config
from repro.data import Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.models import sharding as shlib
from repro.runtime import Heartbeat, PreemptionHandler
from repro.training import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--pods", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    mesh = make_mesh(args.data_mesh, args.model_mesh, args.pods)
    tcfg = TrainConfig(
        steps=args.steps, microbatches=args.microbatches, lr=args.lr,
        warmup_steps=args.warmup, optimizer=args.optimizer,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        compress_pod_grads=args.compress_pod_grads, seed=args.seed)

    model = build_model(cfg, mesh=mesh)
    seq = args.seq + cfg.frontend_tokens
    data = SyntheticLM(cfg, seq, args.batch, seed=args.seed)

    handler = PreemptionHandler()
    heartbeat = Heartbeat(args.ckpt_dir, jax.process_index())
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    with shlib.use_mesh(mesh):
        state = init_train_state(model, tcfg, jax.random.PRNGKey(args.seed))
        start = 0
        if args.resume and latest_step(args.ckpt_dir) is not None:
            restored, start, extra = restore_checkpoint(
                args.ckpt_dir, state._asdict())
            from repro.training.step import TrainState
            state = TrainState(**restored)
            print(f"[resume] restored step {start}")
        step_fn = jax.jit(make_train_step(model, tcfg, total_steps=args.steps,
                                          mesh=mesh), donate_argnums=(0,))

        prefetch = Prefetcher(
            lambda s: {k: jnp.asarray(v) for k, v in
                       data.batch_at(s).items()}, start_step=start)
        t_last = time.time()
        try:
            for i in range(start, args.steps):
                step_idx, batch = next(prefetch)
                state, metrics = step_fn(state, batch)
                if (i + 1) % args.log_every == 0 or i == start:
                    loss = float(metrics["loss"])
                    dt = time.time() - t_last
                    t_last = time.time()
                    heartbeat.beat(i + 1, dt / args.log_every)
                    print(f"step {i + 1:6d}  loss {loss:8.4f}  "
                          f"gnorm {float(metrics['grad_norm']):7.3f}  "
                          f"lr {float(metrics['lr']):.2e}  "
                          f"{dt:6.2f}s/{args.log_every}", flush=True)
                if (i + 1) % args.ckpt_every == 0 or handler.should_stop:
                    ckpt.save(i + 1, state._asdict(),
                              extra={"data_step": i + 1})
                if handler.should_stop:
                    print("[preempt] checkpointed, exiting cleanly")
                    break
        finally:
            prefetch.close()
            ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
