"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e-256 pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading ``pod`` axis
crosses DCN, so only pure-DP traffic (gradient all-reduce, optionally
PowerSGD-compressed) or pipeline handoffs ride it.

Functions, not module constants: importing this module must never touch jax
device state (the dry-run sets the fake-device count before first jax init).
"""
from __future__ import annotations

import os

import jax

# hardware constants used across the roofline analysis (TPU v5e class)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link

# XLA flags a real pod job would launch with (latency-hiding scheduler
# overlaps collectives with compute; async collectives enable the overlap)
TPU_XLA_FLAGS = " ".join([
    "--xla_tpu_enable_latency_hiding_scheduler=true",
    "--xla_enable_async_all_gather=true",
    "--xla_enable_async_collective_permute=true",
    "--xla_tpu_enable_async_collective_fusion=true",
])


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def ensure_fake_devices(n: int = 512) -> None:
    """For dry-run entrypoints only — must run before any jax device use."""
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = (cur + " " + flag).strip()
