import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the abstract inputs (ShapeDtypeStruct with
shardings — zero allocation), lowers the right step function
(train_step / prefill / serve decode_step), compiles it for the production
mesh, and records:

* ``compiled.memory_analysis()``  — proves the per-device program fits
* ``compiled.cost_analysis()``    — per-device FLOPs / bytes for §Roofline
* collective wire bytes parsed from the post-partitioning HLO
* the three roofline terms + dominant bottleneck + useful-FLOPs fraction

Results accumulate in a JSON cache (``--out``); finished cells are skipped
so the sweep is resumable.  Usage:

    python -m repro.launch.dryrun --all                 # every cell, 1 pod
    python -m repro.launch.dryrun --all --multi-pod     # 2x16x16
    python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    python -m repro.launch.dryrun --arch fuego9         # the MCTS app cell
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.analysis.hlo import analyze
from repro.analysis.roofline import (model_flops, roofline_terms,
                                     useful_fraction)
from repro.config import (SHAPES, TrainConfig, get_model_config, list_archs,
                          skip_reason)
from repro.launch.mesh import make_production_mesh
from repro.models import (batch_specs, build_model, decode_specs,
                          param_specs)
from repro.models import sharding as shlib
from repro.models.transformer import TransformerLM
from repro.optim.optimizers import (AdamState, FactorState, SGDMState,
                                    make_optimizer)
from repro.training.step import TrainState, make_train_step

DEFAULT_OUT = "benchmarks/results/dryrun.json"


# ---------------------------------------------------------------------------
# abstract state construction
# ---------------------------------------------------------------------------


def _with_sharding(leaf: jax.ShapeDtypeStruct, sh):
    return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=sh)


def abstract_train_state(cfg, tcfg: TrainConfig, mesh) -> TrainState:
    """TrainState of ShapeDtypeStructs with shardings (no allocation)."""
    pspecs = param_specs(cfg, mesh)
    opt = make_optimizer(tcfg.optimizer, tcfg.weight_decay)
    opt_abs = jax.eval_shape(opt.init, pspecs)

    model = TransformerLM(cfg)
    logical = model.param_logical()
    shapes = model.param_shapes()
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(v, (str, type(None))) for v in x)

    def like_param(tree_abs):
        return jax.tree.map(
            lambda lg, shp, leaf: _with_sharding(
                leaf, shlib.named_sharding(lg, shp, mesh)),
            logical, shapes, tree_abs, is_leaf=is_ax)

    def factored(tree_abs):
        def one(lg, shp, leaf):
            if leaf.shape == tuple(shp):
                axes = lg
            elif leaf.shape == tuple(shp[:-1]):
                axes = lg[:-1]
            elif leaf.shape == tuple(shp[:-2]) + tuple(shp[-1:]):
                axes = lg[:-2] + lg[-1:]
            else:
                axes = (None,) * len(leaf.shape)
            return _with_sharding(
                leaf, shlib.named_sharding(axes, leaf.shape, mesh))

        return jax.tree.map(one, logical, shapes, tree_abs, is_leaf=is_ax)

    rep = lambda leaf: _with_sharding(
        leaf, shlib.named_sharding((), (), mesh))
    if isinstance(opt_abs, AdamState):
        opt_abs = AdamState(step=rep(opt_abs.step), m=like_param(opt_abs.m),
                            v=like_param(opt_abs.v))
    elif isinstance(opt_abs, FactorState):
        opt_abs = FactorState(step=rep(opt_abs.step),
                              vr=factored(opt_abs.vr),
                              vc=factored(opt_abs.vc))
    elif isinstance(opt_abs, SGDMState):
        opt_abs = SGDMState(step=rep(opt_abs.step),
                            mom=like_param(opt_abs.mom))
    return TrainState(params=pspecs, opt_state=opt_abs,
                      step=jax.ShapeDtypeStruct((), np.int32), psgd=None)


def _train_tcfg(cfg, shape, mesh_cfg_chips_data: int) -> TrainConfig:
    # one row per device per microbatch: peak activations ~ one sequence
    mb = max(1, shape.global_batch // mesh_cfg_chips_data)
    opt = "adafactor" if cfg.moe.num_experts else "adamw"
    return TrainConfig(microbatches=mb, optimizer=opt, remat=True)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               save_hlo: Optional[str] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()

    if arch == "fuego9":
        lowered, donate = _lower_fuego(mesh), None
        cfg = None
        shape = None
    else:
        cfg = get_model_config(arch)
        shape = SHAPES[shape_name]
        with shlib.use_mesh(mesh):
            model = build_model(cfg, mesh=mesh)
            if shape.kind == "train":
                data_ways = mesh.shape["data"] * mesh.shape.get("pod", 1)
                tcfg = _train_tcfg(cfg, shape, data_ways)
                state_abs = abstract_train_state(cfg, tcfg, mesh)
                batch_abs = batch_specs(cfg, shape, mesh)
                step = make_train_step(model, tcfg, mesh=mesh)
                lowered = jax.jit(step, donate_argnums=(0,)).lower(
                    state_abs, batch_abs)
            elif shape.kind == "prefill":
                pspecs = param_specs(cfg, mesh)
                batch_abs = batch_specs(cfg, shape, mesh)
                if cfg.family == "audio":
                    fn = lambda p, fe: model.forward(p, None, fe)
                    lowered = jax.jit(fn).lower(pspecs, batch_abs["frontend"])
                else:
                    args = [pspecs, batch_abs["tokens"]]
                    fn = (lambda p, t, fe: model.prefill(p, t, fe)) \
                        if cfg.frontend_tokens else \
                        (lambda p, t: model.prefill(p, t))
                    if cfg.frontend_tokens:
                        args.append(batch_abs["frontend"])
                    lowered = jax.jit(fn).lower(*args)
            else:  # decode
                pspecs = param_specs(cfg, mesh)
                cache_abs, tok_abs = decode_specs(cfg, shape, mesh)
                fn = lambda p, c, t: model.decode_step(p, c, t)
                lowered = jax.jit(fn, donate_argnums=(1,)).lower(
                    pspecs, cache_abs, tok_abs)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    hc = analyze(hlo)          # trip-count-aware per-device flops/bytes/wire
    coll = dict(hc["wire"])
    coll["counts"] = hc["wire_counts"]
    if save_hlo:
        os.makedirs(save_hlo, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'pod2' if multi_pod else 'pod1'}"
        with open(os.path.join(save_hlo, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)

    terms = roofline_terms(hc, coll, chips)
    from repro.models import optflags as _of
    rec = {
        "status": "ok",
        "opt_flags": {k: v for k, v in _of.flags().__dict__.items() if v},
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": {"flops": hc["flops"], "hbm_bytes": hc["hbm_bytes"],
                 "xla_flops_bodies_once": ca.get("flops"),
                 "xla_bytes_bodies_once": ca.get("bytes accessed")},
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
            "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        },
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll.get("counts", {}),
        "roofline": terms,
    }
    if cfg is not None and shape is not None:
        rec["model_flops"] = model_flops(cfg, shape)
        rec["useful_fraction"] = useful_fraction(
            cfg, shape, {"flops": hc["flops"]}, chips)
        # per-device live bytes: params(+opt) args + temps
        arg = rec["memory"]["argument_bytes"] or 0
        tmp = rec["memory"]["temp_bytes"] or 0
        rec["memory"]["per_device_total_gib"] = round(
            (arg + tmp) / 2 ** 30, 3)
        rec["fits_16g_hbm"] = bool(arg + tmp < 16 * 2 ** 30)
    return rec


def _lower_fuego(mesh):
    from repro.configs.fuego9 import config as fuego_config
    from repro.core.distributed import selfplay_step
    from repro.go import GoEngine

    mcfg = fuego_config()
    eng = GoEngine(mcfg.board_size, mcfg.komi)
    step = selfplay_step(eng, mcfg, mesh, axis="data")
    root = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jax.numpy.shape(x), x.dtype),
        eng.init_state())
    rng = jax.ShapeDtypeStruct((2,), np.uint32)
    return jax.jit(step).lower(root, rng)


# ---------------------------------------------------------------------------
# sweep driver
# ---------------------------------------------------------------------------


def cell_key(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}|{shape}|{'2x16x16' if multi_pod else '16x16'}"


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(path: str, results: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def run_cells(cells, out: str, save_hlo: Optional[str], force: bool,
              verbose: bool = True) -> Dict[str, Any]:
    results = load_results(out)
    for arch, shape_name, multi_pod in cells:
        key = cell_key(arch, shape_name, multi_pod)
        if not force and results.get(key, {}).get("status") == "ok":
            if verbose:
                print(f"[skip cached] {key}")
            continue
        reason = skip_reason(arch, shape_name) if arch != "fuego9" else None
        if reason:
            results[key] = {"status": "skipped", "arch": arch,
                            "shape": shape_name, "reason": reason}
            save_results(out, results)
            if verbose:
                print(f"[skip] {key}: {reason}")
            continue
        if verbose:
            print(f"[lower+compile] {key} ...", flush=True)
        try:
            rec = lower_cell(arch, shape_name, multi_pod, save_hlo)
            results[key] = rec
            if verbose:
                r = rec["roofline"]
                print(f"  ok: compile {rec['compile_s']}s  "
                      f"compute {r['compute_s']:.4f}s  "
                      f"memory {r['memory_s']:.4f}s  "
                      f"collective {r['collective_s']:.4f}s  "
                      f"dominant={r['dominant']}", flush=True)
        except Exception as e:
            results[key] = {"status": "error", "arch": arch,
                            "shape": shape_name,
                            "error": f"{type(e).__name__}: {e}",
                            "trace": traceback.format_exc()[-2000:]}
            print(f"  ERROR {key}: {type(e).__name__}: {e}", flush=True)
        save_results(out, results)
    return results


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all)")
    ap.add_argument("--shape", default=None,
                    help="shape name (default: all four)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all archs x shapes (+ fuego9)")
    ap.add_argument("--out", default=None,
                    help="results JSON (default depends on --opt)")
    ap.add_argument("--opt", type=int, default=0,
                    help="optimization level 0..3 (models.optflags)")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.models import optflags
    optflags.set_level(args.opt)
    if args.out is None:
        args.out = DEFAULT_OUT if args.opt == 0 else \
            DEFAULT_OUT.replace(".json", f"_opt{args.opt}.json")

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all or args.arch is None:
        archs = list_archs()
        for mp in meshes:
            for a in archs:
                for s in SHAPES:
                    cells.append((a, s, mp))
            cells.append(("fuego9", "selfplay", mp))
    else:
        shapes = [args.shape] if args.shape else \
            (["selfplay"] if args.arch == "fuego9" else list(SHAPES))
        for mp in meshes:
            for s in shapes:
                cells.append((args.arch, s, mp))

    results = run_cells(cells, args.out, args.save_hlo, args.force)
    ok = sum(1 for v in results.values() if v.get("status") == "ok")
    sk = sum(1 for v in results.values() if v.get("status") == "skipped")
    err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"\n== dry-run summary: {ok} ok / {sk} skipped / {err} error ==")
    if err:
        for k, v in results.items():
            if v.get("status") == "error":
                print(f"  FAIL {k}: {v['error']}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
