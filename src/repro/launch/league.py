"""League launcher: a persistent, crash-resumable evaluation service.

Runs a :class:`~repro.core.league.League` over a set of MCTS
configurations: Bradley–Terry ratings with covariance drive the
schedule (only still-overlapping pairings get more games), the colour
ledger forces strict per-pairing +-1 Black/White balance through the
multiplexed pool, and every wave boundary snapshots league state to
``--state-dir``.  SIGTERM/SIGINT flip the
:class:`~repro.runtime.ft.PreemptionHandler` flag, the league exits at
the next wave boundary, and ``--resume`` continues the exact schedule —
the resumed run converges to the same cross table as an uninterrupted
one.

``--configs`` is a semicolon-separated list of ``k=v,k=v`` overrides on
the shared base config (board/lanes/tree shape come from the other
flags); only traced fields (``sims_per_move``, ``c_uct``,
``virtual_loss``, ``prior_weight``, ``seed``) may differ between
entries — the league exists to multiplex one compiled dispatch.  A
``name=...`` key labels the entry in the standings.

    PYTHONPATH=src python -m repro.launch.league --board 5 --komi 0.5 \
        --configs "sims_per_move=16;sims_per_move=8;sims_per_move=4" \
        --confidence 1.96 --budget 120 --state-dir /tmp/league
"""
from __future__ import annotations

import argparse
import signal

from repro.config import MCTSConfig, apply_overrides
from repro.core.league import League
from repro.go import GoEngine
from repro.runtime.ft import PreemptionHandler


def parse_configs(spec: str, base: MCTSConfig):
    """Parse ``k=v,k=v;k=v,...`` into (configs, names) over ``base``."""
    configs, names = [], []
    for entry in spec.split(";"):
        entry = entry.strip()
        if not entry:
            continue
        cfg, name = base, None
        for kv in entry.split(","):
            k, _, v = kv.partition("=")
            k = k.strip()
            if not _:
                raise ValueError(f"--configs entry {kv!r} is not k=v")
            if k == "name":
                name = v.strip()
            else:
                cfg = apply_overrides(cfg, {k: v.strip()})
        configs.append(cfg)
        names.append(name or f"cfg{len(configs) - 1}:"
                     f"{cfg.lanes}x{cfg.sims_per_move}")
    if len(configs) < 2:
        raise ValueError("--configs needs at least 2 entries")
    return configs, names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", required=True,
                    help="semicolon-separated k=v,k=v override lists, one "
                         "per player (traced fields only; name=... labels)")
    ap.add_argument("--board", type=int, default=9)
    ap.add_argument("--komi", type=float, default=6.0)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--max-nodes", type=int, default=4096)
    ap.add_argument("--confidence", type=float, default=1.96,
                    help="separation threshold in standard errors of the "
                         "rating difference (1.96 = 95%%)")
    ap.add_argument("--budget", type=int, default=None,
                    help="total game budget (default: play to separation)")
    ap.add_argument("--games-per-wave", type=int, default=2,
                    help="games per still-overlapping pairing per wave")
    ap.add_argument("--round-robin", action="store_true",
                    help="control arm: fund every pairing each wave")
    ap.add_argument("--state-dir", default=None,
                    help="wave-boundary snapshot directory (enables "
                         "checkpointing; see --resume)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest valid snapshot in --state-dir "
                         "and continue the schedule")
    ap.add_argument("--max-waves", type=int, default=None)
    ap.add_argument("--slots", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--superstep", type=int, default=4)
    ap.add_argument("--pipeline-depth", type=int, default=1)
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the pool over this many devices")
    ap.add_argument("--placement", default="round_robin")
    args = ap.parse_args()

    base = MCTSConfig(board_size=args.board, komi=args.komi,
                      lanes=args.lanes, max_nodes=args.max_nodes)
    configs, names = parse_configs(args.configs, base)
    mesh = None
    if args.shards > 1:
        from repro.compat import make_service_mesh
        mesh = make_service_mesh(args.shards)

    engine = GoEngine(args.board, args.komi)
    league = League(
        engine, configs, names=names, z=args.confidence,
        budget=args.budget, games_per_wave=args.games_per_wave,
        schedule="round_robin" if args.round_robin else "adaptive",
        state_dir=args.state_dir, resume=args.resume, slots=args.slots,
        seed=args.seed, superstep=args.superstep, mesh=mesh,
        placement=args.placement, pipeline_depth=args.pipeline_depth,
        preemption=PreemptionHandler(signals=(signal.SIGTERM,
                                              signal.SIGINT)),
        on_wave=lambda rec: print(
            f"wave {rec['wave']}: {rec['games']} games over "
            f"{len(rec['pairs'])} pairings "
            f"(total {rec['games_played']}), separation "
            + " ".join(f"{p}={s}" for p, s in rec["separation"].items())))

    if league.wave:
        print(f"resumed at wave {league.wave} "
              f"({league.games_played} games played)")
    res = league.run(max_waves=args.max_waves)
    print()
    print(res.table())
    verdict = ("converged" if res.converged
               else "preempted" if res.stopped
               else "max waves reached" if args.max_waves is not None
               and res.waves >= args.max_waves
               else "budget exhausted")
    print(f"\n{verdict}: {res.games_played} games over {res.waves} waves")


if __name__ == "__main__":
    main()
