"""Self-play launcher: the paper's experiment as a CLI.

Runs the effective-speedup match (2n lanes vs n lanes) for one point of
Figs. 4/5/11 on the batched game arena (core/arena.py): one search per
move, ``--arena-slots`` concurrent games with finished slots refilled
from the pending queue.

    PYTHONPATH=src python -m repro.launch.selfplay --board 5 --lanes 2 \
        --sims 32 --games 8 --arena-slots 4
"""
from __future__ import annotations

import argparse
import time

from repro.config import MCTSConfig
from repro.core.selfplay import effective_speedup_point
from repro.go import GoEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--board", type=int, default=9)
    ap.add_argument("--komi", type=float, default=6.0)
    ap.add_argument("--lanes", type=int, default=4,
                    help="base thread count n (plays 2n vs n)")
    ap.add_argument("--sims", type=int, default=64,
                    help="playouts/move for the base player")
    ap.add_argument("--games", type=int, default=16)
    ap.add_argument("--max-nodes", type=int, default=2048)
    ap.add_argument("--parallelism", default="tree",
                    choices=("tree", "root", "leaf"))
    ap.add_argument("--affinity", default="compact")
    ap.add_argument("--virtual-loss", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arena-slots", type=int, default=0,
                    help="concurrent arena games (0 = one slot per game)")
    ap.add_argument("--max-moves", type=int, default=0,
                    help="per-game move cap (0 = engine default)")
    ap.add_argument("--refill", default="device",
                    choices=("device", "host"),
                    help="slot refill: SearchService device-side queue "
                         "(default) or the PR 1 host queue")
    args = ap.parse_args()

    eng = GoEngine(args.board, args.komi)
    cfg = MCTSConfig(board_size=args.board, komi=args.komi,
                     lanes=args.lanes, sims_per_move=args.sims,
                     max_nodes=args.max_nodes, parallelism=args.parallelism,
                     affinity=args.affinity, virtual_loss=args.virtual_loss)
    t0 = time.time()
    res = effective_speedup_point(eng, cfg, games=args.games,
                                  seed=args.seed,
                                  batch=args.arena_slots,
                                  max_moves=args.max_moves or None,
                                  refill=args.refill)
    dt = time.time() - t0
    moves = res.mean_moves * args.games
    print(f"board {args.board}x{args.board}  {2 * args.lanes} vs "
          f"{args.lanes} lanes  {args.sims} sims/move")
    print(f"  2x player win rate: {res.rate}")
    print(f"  games {res.a_wins}W/{res.b_wins}L/{res.draws}D  "
          f"mean length {res.mean_moves:.1f}  "
          f"mean tree {res.mean_tree_nodes:.0f} nodes  {dt:.1f}s  "
          f"({moves / dt:.1f} moves/s)")


if __name__ == "__main__":
    main()
