"""HTTP move-serving launcher: the SLO-aware front door over GoService.

Starts :class:`~repro.serving.server.GoMoveServer` on one persistent
:class:`~repro.serving.go_service.GoService` (per-komi buckets, streaming
dispatch pipelines) and serves until interrupted:

    PYTHONPATH=src python -m repro.launch.serve_http --board 9 \
        --sims 64 --slots 8 --port 8080 --pipeline-depth 2

Then::

    curl -s localhost:8080/healthz
    curl -s -X POST localhost:8080/v1/best_move \
        -d '{"board": [0, 0, ...81 ints...], "deadline_ms": 500}'
    curl -s localhost:8080/metrics

Load-shedding responses are explicit: 503 = over capacity (queue depth
past ``--admission-limit``), 504 = deadline shed.  See
docs/ARCHITECTURE.md "Serving tier" for the request lifecycle and the
deadline -> downgrade -> shed decision table.
"""
from __future__ import annotations

import argparse
import asyncio

from repro.serving.go_service import DeadlinePolicy, GoService
from repro.serving.server import GoMoveServer


def build_service(args: argparse.Namespace) -> GoService:
    """Construct the GoService a parsed CLI asks for."""
    mesh = None
    if args.shards > 1:
        from repro.compat import make_service_mesh
        mesh = make_service_mesh(args.shards)
    policy = DeadlinePolicy(slots=args.slots,
                            floor_sims=args.floor_sims)
    return GoService(board_size=args.board, komi=args.komi,
                     max_sims=args.sims, lanes=args.lanes,
                     slots=args.slots, seed=args.seed, mesh=mesh,
                     placement=args.placement,
                     pipeline_depth=args.pipeline_depth,
                     admission_limit=args.admission_limit,
                     deadline_policy=policy)


async def serve(args: argparse.Namespace) -> None:
    """Start the front door and serve until cancelled."""
    service = build_service(args)
    server = GoMoveServer(service)
    port = await server.start(host=args.host, port=args.port)
    print(f"serving Go moves on http://{args.host}:{port} "
          f"(board {args.board}, komi {args.komi}, max_sims {args.sims}, "
          f"admission limit {service.admission_limit})")
    try:
        await asyncio.Event().wait()          # until Ctrl-C
    finally:
        await server.stop()


def main() -> None:
    """CLI entry point."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080,
                    help="0 picks a free port (printed at startup)")
    ap.add_argument("--board", type=int, default=9)
    ap.add_argument("--komi", type=float, default=6.0)
    ap.add_argument("--sims", type=int, default=64,
                    help="max playout budget per query (bucket size)")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--slots", type=int, default=8,
                    help="concurrent queries per dispatch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the serving pool over this many devices")
    ap.add_argument("--placement", default="round_robin",
                    help="query->shard policy (repro.core.placement)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="supersteps kept in flight per bucket")
    ap.add_argument("--admission-limit", type=int, default=0,
                    help="shed (503) past this many outstanding requests "
                         "per bucket (0 = the bucket queue capacity)")
    ap.add_argument("--floor-sims", type=int, default=4,
                    help="minimum downgraded playout budget before a "
                         "deadline'd query is shed instead")
    args = ap.parse_args()
    try:
        asyncio.run(serve(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
