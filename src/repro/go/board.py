"""Jittable Go engine (the FUEGO substrate).

Fully vectorised, ``jax.jit``/``vmap``-compatible Go rules for an ``n x n``
board: flood-fill connected groups, exact liberty counting, captures, suicide
and simple-ko legality, true-eye detection for the playout policy, and
Tromp–Taylor (Chinese/area) scoring.

Representation
--------------
* ``board``: ``int8[n2]`` flattened, ``+1`` black / ``-1`` white / ``0`` empty.
* All neighbour/diagonal lookups go through precomputed tables padded with a
  sentinel index ``n2`` that maps to an off-board "wall" cell, so gathers never
  need bounds checks (the wall never matches any colour test that matters and
  scatters to it are discarded).
* Moves are ``0..n2-1`` for points and ``n2`` for pass.

The engine object holds only *static* numpy tables; every method is a pure
function of its arguments and can be wrapped in ``jit``/``vmap`` freely.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

EMPTY, BLACK, WHITE = 0, 1, -1
_OFF = 3  # wall cell "colour": matches neither player nor empty
NO_KO = -1


class GoState(NamedTuple):
    board: jax.Array       # int8[n2]
    to_play: jax.Array     # int8 scalar, +1 / -1
    ko: jax.Array          # int32 scalar, simple-ko forbidden point or -1
    pass_count: jax.Array  # int32 scalar
    move_count: jax.Array  # int32 scalar
    done: jax.Array        # bool scalar


def _build_tables(size: int):
    n2 = size * size
    nbr = np.full((n2, 4), n2, dtype=np.int32)
    diag = np.full((n2, 4), n2, dtype=np.int32)
    for r in range(size):
        for c in range(size):
            p = r * size + c
            for k, (dr, dc) in enumerate(((-1, 0), (1, 0), (0, -1), (0, 1))):
                rr, cc = r + dr, c + dc
                if 0 <= rr < size and 0 <= cc < size:
                    nbr[p, k] = rr * size + cc
            for k, (dr, dc) in enumerate(((-1, -1), (-1, 1), (1, -1), (1, 1))):
                rr, cc = r + dr, c + dc
                if 0 <= rr < size and 0 <= cc < size:
                    diag[p, k] = rr * size + cc
    return nbr, diag


class GoEngine:
    """Static-size Go rules engine; every method is jit/vmap-safe."""

    def __init__(self, size: int = 9, komi: float = 6.0):
        self.size = int(size)
        self.komi = float(komi)
        self.n2 = self.size * self.size
        self.num_actions = self.n2 + 1          # + pass
        self.pass_action = self.n2
        self.max_moves = 2 * self.n2            # hard game-length cap
        nbr, diag = _build_tables(self.size)
        self.nbr = jnp.asarray(nbr)             # int32[n2, 4], n2 = wall
        self.diag = jnp.asarray(diag)
        # number of on-board neighbours/diagonals per point
        self.nbr_valid = jnp.asarray((nbr < self.n2), dtype=jnp.int32)
        self.diag_valid = jnp.asarray((diag < self.n2), dtype=jnp.int32)
        # Static trip count for the min-label component fixpoint.  Hook +
        # one pointer-jump converges in O(log n2) rounds; stress-tested over
        # random boards plus adversarial serpentine/spiral/comb families
        # (worst observed: 10/16/19/27 rounds at sizes 5/9/13/19 vs bounds
        # 21/27/30/33 from this formula).
        self.label_rounds = 3 * max(1, (self.n2 - 1).bit_length()) + 6

    # -- state ----------------------------------------------------------------

    def init_state(self) -> GoState:
        return GoState(
            board=jnp.zeros((self.n2,), jnp.int8),
            to_play=jnp.int8(BLACK),
            ko=jnp.int32(NO_KO),
            pass_count=jnp.int32(0),
            move_count=jnp.int32(0),
            done=jnp.bool_(False),
        )

    def _pad(self, cells: jax.Array, wall_value) -> jax.Array:
        """Append the wall cell so sentinel gathers are safe."""
        return jnp.concatenate(
            [cells, jnp.full((1,), wall_value, cells.dtype)])

    # -- groups & liberties -----------------------------------------------------

    def _min_label_components(self, active: jax.Array,
                              same: jax.Array) -> jax.Array:
        """Min-index connected-component labels over the neighbour graph.

        ``active`` is ``bool[n2]`` (cells that participate); ``same`` is
        ``bool[n2, 4]`` (which neighbour edges connect).  Returns
        ``int32[n2]`` labels: the smallest cell index in each component,
        ``n2`` for inactive cells — the same fixpoint the old data-dependent
        ``while_loop`` reached, but via a *static* ``fori_loop`` trip count
        (hook to the neighbour min, then one pointer jump per round,
        FastSV-style) so the loop is shaped for a Pallas port: fixed rounds,
        fixed-size gathers, no convergence flag.
        """
        n2 = self.n2
        ids0 = jnp.where(active, jnp.arange(n2, dtype=jnp.int32), n2)

        def body(_, ids):
            idp = self._pad(ids, n2)
            cand = jnp.where(same, idp[self.nbr], n2)     # hook: nbr min
            new = jnp.minimum(ids, cand.min(axis=1))
            newp = self._pad(new, n2)
            new = jnp.minimum(new, newp[new])             # pointer jump
            return jnp.where(active, new, n2)

        return jax.lax.fori_loop(0, self.label_rounds, body, ids0)

    def group_info(self, board: jax.Array):
        """Connected components + exact per-group liberty counts.

        Returns
        -------
        ids : int32[n2]   root-cell index of each stone's group (n2 for empty)
        libs : int32[n2]  liberties of the group each stone belongs to
                          (0 for empty cells)
        """
        n2 = self.n2
        bp = self._pad(board, _OFF)                       # int8[n2+1]
        stone = board != EMPTY
        same = bp[self.nbr] == board[:, None]             # same colour as self
        ids = self._min_label_components(stone, same)

        # distinct-liberty counting: each empty cell credits each *distinct*
        # adjacent group exactly once.
        idp = self._pad(ids, n2)
        nb_ids = idp[self.nbr]                            # [n2, 4] group of each nbr
        empty = board == EMPTY
        # for empty cell e, neighbour k contributes iff it is a stone-group id
        # (< n2) and differs from all previous neighbour ids of e
        contrib = (nb_ids < n2) & empty[:, None]
        for k in range(1, 4):
            dup = jnp.zeros_like(contrib[:, k])
            for j in range(k):
                dup = dup | (nb_ids[:, k] == nb_ids[:, j])
            contrib = contrib.at[:, k].set(contrib[:, k] & ~dup)
        libs_by_root = jnp.zeros((n2 + 1,), jnp.int32).at[
            nb_ids.reshape(-1)].add(contrib.reshape(-1).astype(jnp.int32))
        libs = jnp.where(stone, libs_by_root[jnp.where(stone, ids, n2)], 0)
        return ids, libs

    # -- legality ---------------------------------------------------------------

    def _legal_points(self, state: GoState, libs: jax.Array) -> jax.Array:
        """Exact point legality from precomputed group liberties."""
        board = state.board
        bp = self._pad(board, _OFF)
        libp = self._pad(libs, 0)
        me = state.to_play
        nb_col = bp[self.nbr]                              # [n2, 4]
        nb_lib = libp[self.nbr]
        empty_nbr = (nb_col == EMPTY).any(axis=1)
        friend_spare = ((nb_col == me) & (nb_lib > 1)).any(axis=1)
        enemy_atari = ((nb_col == -me) & (nb_lib == 1)).any(axis=1)
        playable = (board == EMPTY) & (empty_nbr | friend_spare | enemy_atari)
        ko_mask = jnp.arange(self.n2, dtype=jnp.int32) != state.ko
        return playable & ko_mask & ~state.done

    def legal_moves(self, state: GoState) -> jax.Array:
        """Exact legality mask, ``bool[num_actions]`` (pass always legal)."""
        _, libs = self.group_info(state.board)
        pts = self._legal_points(state, libs)
        return jnp.concatenate([pts, jnp.ones((1,), jnp.bool_)])

    def true_eyes(self, board: jax.Array, color) -> jax.Array:
        """Heuristic true-eye mask for ``color`` (playout move filter)."""
        bp = self._pad(board, _OFF)
        nb = bp[self.nbr]
        # every on-board neighbour is own colour (wall counts as own)
        nbrs_own = ((nb == color) | (nb == _OFF)).all(axis=1)
        dg = bp[self.diag]
        bad_diag = (dg == -color).astype(jnp.int32).sum(axis=1)
        n_valid_diag = self.diag_valid.sum(axis=1)
        # interior: at most 1 hostile diagonal; edge/corner: none
        limit = jnp.where(n_valid_diag == 4, 1, 0)
        return (board == EMPTY) & nbrs_own & (bad_diag <= limit)

    def playout_mask(self, state: GoState) -> jax.Array:
        """Playout policy support: legal and not filling own true eye."""
        legal = self.legal_moves(state)
        eyes = self.true_eyes(state.board, state.to_play)
        pts = legal[: self.n2] & ~eyes
        return jnp.concatenate([pts, jnp.ones((1,), jnp.bool_)])

    # -- playing a move -----------------------------------------------------------

    def play(self, state: GoState, move) -> GoState:
        """Apply a (legal) move; ``move == n2`` is pass."""
        move = jnp.asarray(move, jnp.int32)
        is_pass = (move >= self.n2) | state.done
        me = state.to_play
        pt = jnp.clip(move, 0, self.n2 - 1)

        placed = state.board.at[pt].set(me.astype(jnp.int8))
        board1 = jnp.where(is_pass, state.board, placed)

        _, libs = self.group_info(board1)
        cap = (board1 == -me) & (libs == 0) & ~is_pass
        ncap = cap.sum()
        board2 = jnp.where(cap, jnp.int8(EMPTY), board1)

        # simple ko: single capture by a lone stone that now has exactly the
        # captured point as its only liberty
        bp2 = self._pad(board2, _OFF)
        nb2 = bp2[self.nbr[pt]]
        lone = ~(nb2 == me).any()
        one_lib = (nb2 == EMPTY).sum() == 1
        cap_idx = jnp.argmax(cap).astype(jnp.int32)
        ko_new = jnp.where((ncap == 1) & lone & one_lib, cap_idx,
                           jnp.int32(NO_KO))
        ko_new = jnp.where(is_pass, jnp.int32(NO_KO), ko_new)

        pass_count = jnp.where(is_pass, state.pass_count + 1, 0)
        move_count = state.move_count + jnp.where(state.done, 0, 1)
        done = state.done | (pass_count >= 2) | (move_count >= self.max_moves)
        return GoState(board=board2, to_play=(-me).astype(jnp.int8),
                       ko=ko_new, pass_count=pass_count.astype(jnp.int32),
                       move_count=move_count.astype(jnp.int32), done=done)

    # -- scoring ------------------------------------------------------------------

    def _reach(self, board: jax.Array, color) -> jax.Array:
        """Cells reachable from ``color`` stones through empty cells.

        Reformulated from mask-growth iteration to connected components of
        the *empty* cells: an empty cell is reached iff its empty-region
        contains a cell adjacent to a ``color`` stone.  Same result as the
        old ``while_loop`` growth, but on the static-trip-count label
        fixpoint shared with ``group_info``.
        """
        empty = board == EMPTY
        bp = self._pad(board, _OFF)
        nb_col = bp[self.nbr]                              # [n2, 4]
        same = empty[:, None] & (nb_col == EMPTY)
        ids = self._min_label_components(empty, same)
        adj = empty & (nb_col == color).any(axis=1)        # region seed cells
        seeded = jnp.zeros((self.n2 + 1,), jnp.int32).at[ids].add(
            adj.astype(jnp.int32))
        return (board == color) | (empty & (seeded[ids] > 0))

    def score(self, board: jax.Array) -> jax.Array:
        """Tromp–Taylor area score, black-positive, before komi."""
        rb = self._reach(board, BLACK)
        rw = self._reach(board, WHITE)
        empty = board == EMPTY
        black_pts = (board == BLACK).sum() + (empty & rb & ~rw).sum()
        white_pts = (board == WHITE).sum() + (empty & rw & ~rb).sum()
        return (black_pts - white_pts).astype(jnp.float32)

    def result(self, state: GoState, komi=None) -> jax.Array:
        """+1 black win / -1 white win / 0 draw, komi applied.

        ``komi`` may be a traced per-game value; ``None`` falls back to the
        engine's static komi (the historical program, bit for bit — half-
        integer komis are exact in f32 either way).
        """
        k = self.komi if komi is None else komi
        s = self.score(state.board) - k
        return jnp.sign(s)

    # -- playouts ----------------------------------------------------------------

    def _play_with_info(self, state: GoState, move, ids: jax.Array,
                        libs: jax.Array) -> GoState:
        """Apply a *legal* move reusing the pre-move group analysis.

        §Perf (fuego hillclimb): the placed stone removes exactly one
        liberty (itself) from each adjacent enemy group, so a group is
        captured iff its pre-move liberties were 1 — no post-move flood
        fill needed.  Halves the per-playout-move fixpoint work.
        """
        move = jnp.asarray(move, jnp.int32)
        is_pass = (move >= self.n2) | state.done
        me = state.to_play
        pt = jnp.clip(move, 0, self.n2 - 1)

        placed = state.board.at[pt].set(me.astype(jnp.int8))
        board1 = jnp.where(is_pass, state.board, placed)

        bp = self._pad(state.board, _OFF)
        idp = self._pad(ids, self.n2)
        libp = self._pad(libs, 0)
        nbrs = self.nbr[pt]                                # [4]
        cap_mask = jnp.zeros((self.n2,), jnp.bool_)
        for k in range(4):
            q = nbrs[k]
            hit = (bp[q] == -me) & (libp[q] == 1)
            cap_mask = cap_mask | (hit & (ids == idp[q]))
        cap_mask = cap_mask & ~is_pass
        ncap = cap_mask.sum()
        board2 = jnp.where(cap_mask, jnp.int8(EMPTY), board1)

        bp2 = self._pad(board2, _OFF)
        nb2 = bp2[nbrs]
        lone = ~(nb2 == me).any()
        one_lib = (nb2 == EMPTY).sum() == 1
        cap_idx = jnp.argmax(cap_mask).astype(jnp.int32)
        ko_new = jnp.where((ncap == 1) & lone & one_lib, cap_idx,
                           jnp.int32(NO_KO))
        ko_new = jnp.where(is_pass, jnp.int32(NO_KO), ko_new)

        pass_count = jnp.where(is_pass, state.pass_count + 1, 0)
        move_count = state.move_count + jnp.where(state.done, 0, 1)
        done = state.done | (pass_count >= 2) | (move_count >= self.max_moves)
        return GoState(board=board2, to_play=(-me).astype(jnp.int8),
                       ko=ko_new, pass_count=pass_count.astype(jnp.int32),
                       move_count=move_count.astype(jnp.int32), done=done)

    def playout_step(self, state: GoState, rng: jax.Array) -> GoState:
        """One uniform-random playout move (pass if nothing sensible).

        Fused: one ``group_info`` fixpoint serves both the legality mask
        and the capture bookkeeping of the chosen move.
        """
        ids, libs = self.group_info(state.board)
        pts = self._legal_points(state, libs)
        eyes = self.true_eyes(state.board, state.to_play)
        pts = pts & ~eyes
        n_ok = pts.sum()
        logits = jnp.where(pts, 0.0, -jnp.inf)
        pick = jax.random.categorical(rng, logits)
        move = jnp.where(n_ok > 0, pick, self.pass_action)
        return self._play_with_info(state, move, ids, libs)

    def random_playout(self, state: GoState, rng: jax.Array) -> GoState:
        """Play uniformly random moves until the game ends (bounded)."""

        def cond(carry):
            st, _ = carry
            return ~st.done

        def body(carry):
            st, key = carry
            key, sub = jax.random.split(key)
            return self.playout_step(st, sub), key

        final, _ = jax.lax.while_loop(cond, body, (state, rng))
        return final

    def playout_value(self, state: GoState, rng: jax.Array,
                      komi=None) -> jax.Array:
        """Black-perspective playout outcome in ``{-1, 0, +1}``."""
        return self.result(self.random_playout(state, rng), komi)

    # -- convenience ----------------------------------------------------------------

    @functools.partial(jax.jit, static_argnums=0)
    def jit_play(self, state: GoState, move) -> GoState:
        return self.play(state, move)

    @functools.partial(jax.jit, static_argnums=0)
    def jit_legal(self, state: GoState) -> jax.Array:
        return self.legal_moves(state)

    def render(self, board) -> str:
        chars = {EMPTY: ".", BLACK: "X", WHITE: "O"}
        b = np.asarray(board).reshape(self.size, self.size)
        return "\n".join(" ".join(chars[int(v)] for v in row) for row in b)
