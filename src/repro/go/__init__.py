from repro.go.board import GoEngine, GoState, EMPTY, BLACK, WHITE

__all__ = ["GoEngine", "GoState", "EMPTY", "BLACK", "WHITE"]
