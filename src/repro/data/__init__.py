from repro.data.pipeline import (SyntheticLM, MemmapTokens, make_batch_fn,
                                 Prefetcher)

__all__ = ["SyntheticLM", "MemmapTokens", "make_batch_fn", "Prefetcher"]
