"""Deterministic, resumable, sharded data pipeline.

Every batch is a pure function of ``(seed, step)`` — resume after preemption
needs no iterator state, only the step counter from the checkpoint (the
fault-tolerance contract runtime/ relies on).  Two sources:

* ``SyntheticLM`` — seeded random token streams (plus modality stubs for the
  audio/VLM archs), used by tests, benchmarks and the end-to-end examples.
* ``MemmapTokens`` — a flat binary token file sampled by deterministic
  random offsets; the production path for real corpora.

``Prefetcher`` overlaps host batch synthesis with device compute (the
host-side half of compute/comm overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.config import ModelConfig


class SyntheticLM:
    """Deterministic synthetic batches for any model family."""

    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.cfg = cfg
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        cfg, b, s = self.cfg, self.batch, self.seq
        if cfg.family == "audio":
            return {
                "frontend": rng.standard_normal(
                    (b, s, 1024), dtype=np.float32),
                "labels": rng.integers(0, cfg.vocab_size, (b, s),
                                       dtype=np.int32),
                "mask": rng.random((b, s)) < 0.3,
            }
        text = s - cfg.frontend_tokens
        toks = rng.integers(0, cfg.vocab_size, (b, text + 1), dtype=np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend_tokens:
            out["frontend"] = rng.standard_normal(
                (b, cfg.frontend_tokens, 1024), dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapTokens:
    """Flat binary int32 token file; batches are seeded random windows."""

    def __init__(self, path: str, seq_len: int, global_batch: int,
                 seed: int = 0):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        if len(self.tokens) < seq_len + 1:
            raise ValueError("token file shorter than one sequence")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        hi = len(self.tokens) - self.seq - 1
        starts = rng.integers(0, hi, self.batch)
        rows = np.stack([np.asarray(self.tokens[s: s + self.seq + 1])
                         for s in starts])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}


def make_batch_fn(source) -> Callable[[int], Dict[str, np.ndarray]]:
    return source.batch_at


class Prefetcher:
    """Host-thread prefetch: synthesise batch t+1 while t computes."""

    def __init__(self, batch_fn: Callable[[int], Dict[str, np.ndarray]],
                 start_step: int = 0, depth: int = 2,
                 put_fn: Optional[Callable] = None):
        self.batch_fn = batch_fn
        self.put_fn = put_fn or (lambda x: x)
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self.stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self.stop.is_set():
            batch = self.put_fn(self.batch_fn(step))
            while not self.stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self.stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)
