from repro.runtime.ft import (Heartbeat, PreemptionHandler, StragglerMonitor,
                              elastic_mesh_for)

__all__ = ["Heartbeat", "PreemptionHandler", "StragglerMonitor",
           "elastic_mesh_for"]
