"""Fault-tolerance runtime: preemption, heartbeats, stragglers, elasticity.

At 1000+ nodes the failure model is: (a) planned preemption (SIGTERM with a
grace window), (b) silent node loss (detected by missing heartbeats), and
(c) stragglers (a slow host stretching every synchronous step).  The
training loop (launch/train.py) composes:

* ``PreemptionHandler`` — SIGTERM/SIGINT flip a flag; the loop checkpoints
  at the next step boundary and exits cleanly (data pipeline resume is a
  pure function of the restored step counter — repro.data).
* ``Heartbeat`` / ``StragglerMonitor`` — per-host step-time beacons to a
  shared directory (on pods: GCS/NFS); the monitor flags hosts whose recent
  step times exceed ``threshold`` x the fleet median, the restart policy the
  paper's "asymmetric thread regions" finding maps onto at pod scale.
* ``elastic_mesh_for`` — rebuild the largest usable (data, model) mesh from
  the devices that survive, preferring to shrink the *data* axis (pure-DP
  loss) so TP groups stay intact; combined with resharding restore
  (repro.ckpt) this is elastic scaling.
"""
from __future__ import annotations

import json
import os
import signal
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit flag."""

    def __init__(self, signals: Sequence[int] = (signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:  # not main thread (tests)
                pass

    def _handler(self, _signum, _frame):
        self._flag.set()

    @property
    def should_stop(self) -> bool:
        return self._flag.is_set()

    def trigger(self) -> None:  # for tests / manual drain
        self._flag.set()

    def restore(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)


class Heartbeat:
    """Per-host liveness + step-time beacon (file-based; GCS/NFS on pods)."""

    def __init__(self, directory: str, host_id: int):
        self.path = os.path.join(directory, f"heartbeat_{host_id}.json")
        os.makedirs(directory, exist_ok=True)
        self.host_id = host_id

    def beat(self, step: int, step_time_s: float) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host_id, "step": step,
                       "step_time_s": step_time_s, "ts": time.time()}, f)
        os.replace(tmp, self.path)


class StragglerMonitor:
    """Reads all heartbeats; flags dead hosts and stragglers.

    Synchronous SPMD steps run at the pace of the slowest host, so a
    straggler taxes the whole fleet; the mitigation at scale is restart /
    exclusion plus checkpoint-resume, which this monitor drives.
    """

    def __init__(self, directory: str, dead_after_s: float = 60.0,
                 straggler_factor: float = 2.0):
        self.directory = directory
        self.dead_after_s = dead_after_s
        self.factor = straggler_factor

    def read(self) -> List[Dict]:
        beats = []
        if not os.path.isdir(self.directory):
            return beats
        for f in os.listdir(self.directory):
            if f.startswith("heartbeat_") and f.endswith(".json"):
                try:
                    with open(os.path.join(self.directory, f)) as fh:
                        beats.append(json.load(fh))
                except (json.JSONDecodeError, OSError):
                    continue  # torn read: treat as missing this round
        return beats

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = now or time.time()
        return sorted(b["host"] for b in self.read()
                      if now - b["ts"] > self.dead_after_s)

    def stragglers(self) -> List[int]:
        beats = self.read()
        if len(beats) < 2:
            return []
        times = np.array([b["step_time_s"] for b in beats])
        med = float(np.median(times))
        if med <= 0:
            return []
        return sorted(b["host"] for b, t in zip(beats, times)
                      if t > self.factor * med)


def elastic_mesh_for(n_devices: int, model_parallel: int
                     ) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving device count.

    Keeps the TP degree fixed (param shardings stay valid) and shrinks the
    data axis — the restored checkpoint reshards onto the smaller mesh and
    training continues with a smaller global batch or more microbatches.
    """
    if n_devices < model_parallel:
        # degenerate loss: shrink TP to the largest power-of-two that fits
        mp = 1
        while mp * 2 <= n_devices:
            mp *= 2
        model_parallel = mp
    data = n_devices // model_parallel
    return data, model_parallel
