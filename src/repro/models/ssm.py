"""Mamba-2 SSD (state-space duality) mixer: chunked scan + O(1) decode.

The SSD algorithm (Dao & Gu 2024) splits the sequence into chunks: within a
chunk the recurrence is computed as a masked attention-like quadratic form
(two MXU-friendly ``[Q, N] x [N, Q]`` einsums per head), between chunks a
single recurrent state ``[H, P, N]`` scans forward.  Because A < 0 and
dt > 0 all decay factors are exp(negative) <= 1 — numerically safe in f32.

TPU adaptation: chunk length defaults to 128 (MXU tile), the chunk loop is a
``lax.scan`` (keeps the HLO small for 32k prefill: 256 sequential chunk
steps, each dense), and the per-chunk working set is O(B*H*Q*Q) — VMEM-scale
rather than the O(S^2) a naive SSD attention-form would need.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import ParamDef, rms_norm


class SSMState(NamedTuple):
    conv: jax.Array   # [B, convdim, K-1] last inputs of the causal conv
    ssm: jax.Array    # [B, H, P, N] recurrent state


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    heads = d_in // s.head_dim
    convdim = d_in + 2 * s.n_groups * s.d_state
    return d_in, heads, convdim


def ssm_param_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    s = cfg.ssm
    d = cfg.d_model
    d_in, heads, convdim = _dims(cfg)
    return {
        "in_proj": ParamDef(
            (d, 2 * d_in + 2 * s.n_groups * s.d_state + heads),
            ("embed", "ssm_inner")),
        "conv_w": ParamDef((s.conv_kernel, convdim), ("conv", None)),
        "conv_b": ParamDef((convdim,), (None,), "zeros"),
        "a_log": ParamDef((heads,), (None,), "ones"),
        "dt_bias": ParamDef((heads,), (None,), "zeros"),
        "d_skip": ParamDef((heads,), (None,), "ones"),
        "norm_w": ParamDef((d_in,), (None,), "ones"),
        "out_proj": ParamDef((d_in, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s = cfg.ssm
    d_in, heads, _ = _dims(cfg)
    gn = s.n_groups * s.d_state
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * gn], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B, S, C] with kernel [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunk_scan(x, dt, a, b, c, chunk: int):
    """Chunked SSD.  x [B,S,H,P]; dt [B,S,H]; a [H]<0; b,c [B,S,G,N]."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    nc = s // chunk
    rep = h // g

    def resh(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (resh(x * dt[..., None]),             # dt-weighted input
          resh(dt), resh(b), resh(c))

    def body(state, xs_c):
        xdt, dtc, bc, cc = xs_c                 # [B,Q,H,P], [B,Q,H], [B,Q,G,N]
        da = dtc * a                            # [B,Q,H] (negative)
        cum = jnp.cumsum(da, axis=1)            # [B,Q,H]
        bh = jnp.repeat(bc, rep, axis=2).astype(jnp.float32)   # [B,Q,H,N]
        ch = jnp.repeat(cc, rep, axis=2).astype(jnp.float32)
        xdtf = xdt.astype(jnp.float32)

        # intra-chunk (attention-like, lower-triangular)
        seg = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # [B,Q,Q,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        seg = jnp.where(tri[None, :, :, None], seg, 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", ch, bh) * seg
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdtf)

        # inter-chunk: contribution of the carried state
        decay_out = jnp.exp(cum)                                 # [B,Q,H]
        y = y + jnp.einsum("bihn,bhpn->bihp", ch, state) \
            * decay_out[..., None]

        # state update for the next chunk
        decay_in = jnp.exp(cum[:, -1:, :] - cum)                 # [B,Q,H]
        new_state = state * jnp.exp(cum[:, -1, :])[..., None, None] \
            + jnp.einsum("bjhn,bjhp->bhpn", bh * decay_in[..., None], xdtf)
        return new_state, y.astype(x.dtype)

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final_state, ys = jax.lax.scan(body, state0, xs)
    y = ys.swapaxes(0, 1).reshape(bsz, s, h, p)
    return y, final_state


def mamba_mixer(x: jax.Array, params: Dict, cfg: ModelConfig,
                return_state: bool = False):
    """Full Mamba-2 block on [B, S, d_model] (train / prefill)."""
    s_cfg = cfg.ssm
    d_in, heads, convdim = _dims(cfg)
    bsz, s, _ = x.shape

    zxbcdt = x @ params["in_proj"]
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
    gn = s_cfg.n_groups * s_cfg.d_state
    xi, b, c = jnp.split(xbc, [d_in, d_in + gn], axis=-1)

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xi.reshape(bsz, s, heads, s_cfg.head_dim)
    bg = b.reshape(bsz, s, s_cfg.n_groups, s_cfg.d_state)
    cg = c.reshape(bsz, s, s_cfg.n_groups, s_cfg.d_state)

    chunk = min(s_cfg.chunk, s)
    while s % chunk:          # largest divisor <= configured chunk
        chunk -= 1
    y, final_state = _ssd_chunk_scan(xh, dt, a, bg, cg, chunk)
    y = y + xh * params["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, d_in)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        k = s_cfg.conv_kernel
        conv_state = jnp.pad(
            xbc_raw, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1):, :] \
            .swapaxes(1, 2)                                   # [B, C, K-1]
        return out, SSMState(conv=conv_state, ssm=final_state)
    return out


def init_ssm_state(cfg: ModelConfig, batch: int,
                   dtype=jnp.bfloat16) -> SSMState:
    s = cfg.ssm
    d_in, heads, convdim = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, convdim, s.conv_kernel - 1), dtype),
        ssm=jnp.zeros((batch, heads, s.head_dim, s.d_state), jnp.float32),
    )


def mamba_decode_step(x: jax.Array, state: SSMState, params: Dict,
                      cfg: ModelConfig) -> Tuple[jax.Array, SSMState]:
    """One-token step: x [B, d_model] -> (out [B, d_model], new state)."""
    s_cfg = cfg.ssm
    d_in, heads, convdim = _dims(cfg)
    bsz = x.shape[0]

    zxbcdt = x @ params["in_proj"]
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)

    # rolling causal conv
    k = s_cfg.conv_kernel
    window = jnp.concatenate([state.conv, xbc_new[:, :, None]], axis=2)
    conv_out = jnp.einsum("bck,kc->bc", window,
                          params["conv_w"].astype(window.dtype))
    xbc = jax.nn.silu(
        (conv_out + params["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    new_conv = window[:, :, 1:]

    gn = s_cfg.n_groups * s_cfg.d_state
    xi, b, c = jnp.split(xbc, [d_in, d_in + gn], axis=-1)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xi.reshape(bsz, heads, s_cfg.head_dim).astype(jnp.float32)
    bg = jnp.repeat(b.reshape(bsz, s_cfg.n_groups, s_cfg.d_state),
                    heads // s_cfg.n_groups, axis=1).astype(jnp.float32)
    cg = jnp.repeat(c.reshape(bsz, s_cfg.n_groups, s_cfg.d_state),
                    heads // s_cfg.n_groups, axis=1).astype(jnp.float32)

    da = jnp.exp(dt * a)                                   # [B, H]
    new_ssm = state.ssm * da[..., None, None] \
        + jnp.einsum("bhn,bhp->bhpn", bg * dt[..., None], xh)
    y = jnp.einsum("bhn,bhpn->bhp", cg, new_ssm)
    y = y + xh * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_w"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, SSMState(conv=new_conv, ssm=new_ssm)
