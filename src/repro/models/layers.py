"""Shared neural layers: norms, RoPE, embeddings, gated MLPs, param plumbing.

Parameters are declared through ``ParamDef`` descriptors so a single source
of truth yields (a) the initialised pytree, (b) the logical-axis tree that
``models.sharding`` turns into ``in_shardings`` for pjit, and (c) analytic
param counts.  Params are stored bf16 (configurable); norms and softmaxes
compute in f32.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import sharding


class ParamDef(NamedTuple):
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 1.0          # fan-in override multiplier


ParamTree = Dict  # nested {name: ParamDef | ParamTree}


def init_params(defs: ParamTree, key: jax.Array, dtype=jnp.bfloat16):
    """Initialise a pytree of ParamDefs (fan-in scaled normal)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))

    def one(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        if d.init == "embed":
            return (jax.random.normal(k, d.shape, jnp.float32)
                    * d.scale).astype(dtype)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, d.shape, jnp.float32)
                * std).astype(dtype)

    return jax.tree.unflatten(treedef, [one(d, k)
                                        for d, k in zip(leaves, keys)])


def logical_tree(defs: ParamTree):
    """Extract the logical-axes pytree (same structure as the params)."""
    return jax.tree.map(lambda d: d.logical, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def shape_tree(defs: ParamTree):
    return jax.tree.map(lambda d: d.shape, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs: ParamTree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(math.prod(d.shape) for d in leaves)


def stack_layer_defs(defs: ParamTree, num_layers: int) -> ParamTree:
    """Prepend a scanned 'layers' axis to every descriptor."""
    return jax.tree.map(
        lambda d: ParamDef((num_layers,) + d.shape, ("layers",) + d.logical,
                           d.init, d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    """RMSNorm in f32; ``plus_one`` = gemma-style (1 + w) scaling."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    y = y * (1.0 + w) if plus_one else y * w
    return y.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x [..., S, H, D], positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq   # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                           # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def gated_mlp(x: jax.Array, wi: jax.Array, wg: Optional[jax.Array],
              wo: jax.Array, act: str = "swiglu") -> jax.Array:
    """SwiGLU / GeGLU: (act(x@wg) * (x@wi)) @ wo; plain gelu if wg is None."""
    h = x @ wi
    if wg is None:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        g = x @ wg
        g = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) \
            if act == "geglu" \
            else jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
        h = h * g
    h = sharding.constrain(h, "batch", None, "ffn")
    return h @ wo


def embed_lookup(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embed, tokens, axis=0)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    xf = x.astype(jnp.float32)
    return (cap * jnp.tanh(xf / cap)).astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0, ignore: int = -1):
    """Token CE with optional z-loss; logits [..., V] f32, labels int.

    With ``optflags.ce_onehot`` the gold logit is a fused one-hot
    contraction (sharding-friendly over a vocab-sharded axis: partial sums
    + a scalar-ish psum); the baseline take_along_axis gather forces GSPMD
    to replicate the full logits tensor.
    """
    from repro.models.optflags import flags
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    if flags().ce_onehot:
        iota = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(
            jnp.where(iota == safe[..., None], logits, 0.0), axis=-1)
    else:
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss > 0:
        nll = nll + z_loss * lse ** 2
    mask = (labels != ignore).astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / total
