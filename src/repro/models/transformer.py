"""Decoder-only LM covering the dense / MoE / SSM / hybrid families.

One scanned layer body per family; per-layer variation (gemma2's alternating
local/global windows, hymba's three global-attention layers) rides along the
scan as data so all layers share one traced body.  Params are declared as
``ParamDef`` descriptors (models/layers.py) giving init + sharding from one
source.  Training forward uses ``jax.checkpoint`` per layer (remat) and
activation sharding constraints; serving exposes ``prefill`` + single-token
``decode_step`` over a stacked per-layer KV/SSM cache.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import sharding
from repro.models.attention import (KVCache, cache_update, decode_attention,
                                    decode_attention_seq_sharded,
                                    full_attention)
from repro.models.layers import (ParamDef, cross_entropy, embed_lookup,
                                 gated_mlp, init_params, logical_tree,
                                 rms_norm, rope, shape_tree, softcap,
                                 stack_layer_defs)
from repro.models.moe import moe_ffn, moe_param_defs
from repro.models.ssm import (SSMState, init_ssm_state, mamba_decode_step,
                              mamba_mixer, ssm_param_defs)


class LMCache(NamedTuple):
    """Stacked per-layer decode state; unused fields are None."""
    k: Optional[jax.Array]          # [L, B, Hkv, S, D]
    v: Optional[jax.Array]
    conv: Optional[jax.Array]       # [L, B, convdim, K-1]
    ssm: Optional[jax.Array]        # [L, B, H, P, N]
    length: jax.Array               # i32 scalar


# ---------------------------------------------------------------------------
# parameter declaration
# ---------------------------------------------------------------------------


def _padded_heads(num_heads: int) -> int:
    """O3 pad_heads: query heads padded to a model-axis multiple (16)."""
    from repro.models.optflags import flags
    if flags().pad_heads:
        return -(-num_heads // 16) * 16
    return num_heads


def _attn_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    a, d, hd = cfg.attn, cfg.d_model, cfg.head_dim
    hq = _padded_heads(a.num_heads)
    return {
        "wq": ParamDef((d, hq * hd), ("embed", "heads")),
        "wk": ParamDef((d, a.num_kv_heads * hd), ("embed", "kv_heads")),
        "wv": ParamDef((d, a.num_kv_heads * hd), ("embed", "kv_heads")),
        "wo": ParamDef((hq * hd, d), ("heads", "embed")),
    }


def _mlp_defs(cfg: ModelConfig, d_ff: Optional[int] = None
              ) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    defs = {
        "wi": ParamDef((d, f), ("embed", "ffn")),
        "wo": ParamDef((f, d), ("ffn", "embed")),
    }
    if cfg.act in ("swiglu", "geglu"):
        defs["wg"] = ParamDef((d, f), ("embed", "ffn"))
    return defs


def _layer_defs(cfg: ModelConfig, moe: bool, dense_ff: int = 0
                ) -> Dict[str, Any]:
    d = cfg.d_model
    defs: Dict[str, Any] = {"ln1": ParamDef((d,), (None,), "ones")}
    if cfg.family == "ssm":
        defs["ssm"] = ssm_param_defs(cfg)
        return defs
    defs["attn"] = _attn_defs(cfg)
    defs["ln2"] = ParamDef((d,), (None,), "ones")
    if cfg.family == "hybrid":
        defs["ssm"] = ssm_param_defs(cfg)
        defs["fuse_na"] = ParamDef((d,), (None,), "ones")
        defs["fuse_ns"] = ParamDef((d,), (None,), "ones")
    if moe:
        defs["moe"] = moe_param_defs(cfg)
    elif cfg.d_ff:
        defs["mlp"] = _mlp_defs(cfg, dense_ff or None)
    if cfg.post_block_norm:
        defs["ln1_post"] = ParamDef((d,), (None,), "ones")
        defs["ln2_post"] = ParamDef((d,), (None,), "ones")
    return defs


class LayerIO(NamedTuple):
    """Optional per-layer decode/prefill state flowing through a block."""
    kv: Optional[KVCache] = None        # decode: cache to append+attend
    ssm: Optional[SSMState] = None      # decode: recurrent state
    emit_state: bool = False            # prefill: emit k/v + final ssm state


class TransformerLM:
    """Builds init/apply/serve functions for one ModelConfig."""

    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg
        self.mesh = mesh
        self.is_moe = cfg.moe.num_experts > 0
        self.n_front = cfg.moe.first_dense if self.is_moe else 0
        self.n_scan = cfg.num_layers - self.n_front
        # gemma2 style: (1+w) norms, sqrt(d) embedding scale, post norms
        self.gemma_style = cfg.post_block_norm
        self.windows = self._window_schedule()

    # -- per-layer static schedule -------------------------------------------
    def _window_schedule(self) -> np.ndarray:
        cfg = self.cfg
        w = np.zeros(cfg.num_layers, np.int32)
        if cfg.attn.alt_local_global:
            w[::2] = cfg.attn.window or 4096   # even local, odd global
        elif cfg.family == "hybrid":
            w[:] = cfg.attn.window or 1024
            for g in cfg.hybrid_global_layers:
                w[g] = 0
        elif cfg.attn.window:
            w[:] = cfg.attn.window
        return w

    # -- params ----------------------------------------------------------------
    def param_defs(self) -> Dict[str, Any]:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        from repro.models.optflags import flags
        embed_axes = ("vocab", None) if flags().embed_vocab_only \
            else ("vocab", "embed")
        defs: Dict[str, Any] = {
            "embed": ParamDef((v, d), embed_axes, "embed"),
            "final_norm": ParamDef((d,), (None,), "ones"),
        }
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef((d, v), ("embed", "vocab"))
        if cfg.meta_tokens:
            defs["meta"] = ParamDef((cfg.meta_tokens, d), (None, "embed"),
                                    "embed", 0.02)
        if cfg.frontend_tokens or cfg.family == "audio":
            # stub modality projector (LLaVA 2-layer MLP / HuBERT feat proj)
            defs["frontend"] = {
                "proj1": ParamDef((1024, d), (None, "embed")),
                "proj2": ParamDef((d, d), ("embed", None)),
            }
        if cfg.family == "audio":
            # encoder-only masked prediction: learned [MASK] frame embedding
            defs["mask_embed"] = ParamDef((d,), (None,), "embed", 0.02)
        if self.n_front:
            defs["front_layers"] = stack_layer_defs(
                _layer_defs(cfg, moe=False, dense_ff=cfg.moe.dense_ff),
                self.n_front)
        defs["layers"] = stack_layer_defs(
            _layer_defs(cfg, moe=self.is_moe), self.n_scan)
        return defs

    def init(self, key: jax.Array):
        return init_params(self.param_defs(), key, jnp.dtype(self.cfg.dtype))

    def param_logical(self):
        return logical_tree(self.param_defs())

    def param_shapes(self):
        return shape_tree(self.param_defs())

    # -- blocks ------------------------------------------------------------------
    def _attn(self, x, p, window, positions, io: LayerIO):
        """Returns (attn_out, new_kv_cache | (k, v) | None).

        O3 ``pad_heads``: query heads padded to a 16-multiple and K/V
        repeated to match (MHA-ised) so attention shards fully over the
        model axis even when Hq/Hkv are mesh-indivisible; the pad heads'
        K/V are zero, so their outputs vanish exactly.
        """
        from repro.models.optflags import flags
        fl = flags()
        cfg = self.cfg
        a, hd = cfg.attn, self.cfg.head_dim
        hq_real = a.num_heads
        hq_pad = _padded_heads(hq_real)
        b, s, d = x.shape
        q = (x @ p["wq"]).reshape(b, s, hq_pad, hd)
        k = (x @ p["wk"]).reshape(b, s, a.num_kv_heads, hd)
        v = (x @ p["wv"]).reshape(b, s, a.num_kv_heads, hd)
        q = rope(q, positions, a.rope_theta)
        k = rope(k, positions, a.rope_theta)
        q, k, v = (t.swapaxes(1, 2) for t in (q, k, v))  # [B, H, S, D]
        q = sharding.constrain(q, "batch", "heads", None, None)
        k = sharding.constrain(k, "batch", "kv_heads", None, None)
        v = sharding.constrain(v, "batch", "kv_heads", None, None)

        if io.kv is not None:   # decode against a cache (real heads only)
            q_dec = q[:, :hq_real] if hq_pad != hq_real else q
            new_cache = cache_update(io.kv, k, v)
            if a.kv_seq_shard and self.mesh is not None \
                    and "model" in self.mesh.axis_names:
                out = decode_attention_seq_sharded(
                    q_dec, new_cache, self.mesh, window=window,
                    softcap=a.logit_softcap)
            else:
                out = decode_attention(q_dec, new_cache, window=window,
                                       softcap=a.logit_softcap)
            if hq_pad != hq_real:
                out = jnp.concatenate(
                    [out, jnp.zeros((b, hq_pad - hq_real) + out.shape[2:],
                                    out.dtype)], axis=1)
            state_out = new_cache
        else:
            if fl.pad_heads:
                g = hq_real // a.num_kv_heads
                k_att = jnp.tile(k, (1, g, 1, 1))     # head h -> kv h%Hkv
                v_att = jnp.tile(v, (1, g, 1, 1))
                if hq_pad != hq_real:
                    zpad = jnp.zeros(
                        (b, hq_pad - hq_real) + k_att.shape[2:], k.dtype)
                    k_att = jnp.concatenate([k_att, zpad], axis=1)
                    v_att = jnp.concatenate([v_att, zpad], axis=1)
                k_att = sharding.constrain(k_att, "batch", "heads", None,
                                           None)
                v_att = sharding.constrain(v_att, "batch", "heads", None,
                                           None)
                out = full_attention(q, k_att, v_att, causal=a.causal,
                                     window=window,
                                     softcap=a.logit_softcap)
            else:
                out = full_attention(q, k, v, causal=a.causal,
                                     window=window,
                                     softcap=a.logit_softcap)
            state_out = (k, v) if io.emit_state else None
        out = out.swapaxes(1, 2).reshape(b, s, hq_pad * hd)
        return out @ p["wo"], state_out

    def _layer(self, h, p, window, positions, moe: bool,
               io: LayerIO = LayerIO()):
        """One block; returns (h, aux, kv_state, ssm_state)."""
        cfg = self.cfg
        aux = jnp.float32(0.0)
        x = rms_norm(h, p["ln1"], cfg.norm_eps, self.gemma_style)

        def run_ssm(x):
            if io.ssm is not None and x.shape[1] == 1:
                out, st = mamba_decode_step(x[:, 0], io.ssm, p["ssm"], cfg)
                return out[:, None], st
            if io.emit_state or io.ssm is not None:
                return mamba_mixer(x, p["ssm"], cfg, return_state=True)
            return mamba_mixer(x, p["ssm"], cfg), None

        if cfg.family == "ssm":
            mixed, new_ssm = run_ssm(x)
            return h + mixed, aux, None, new_ssm

        attn_out, kv_state = self._attn(x, p["attn"], window, positions, io)
        new_ssm = None
        if cfg.family == "hybrid":
            ssm_out, new_ssm = run_ssm(x)
            # hymba: mean of per-path normalised outputs
            attn_out = 0.5 * (rms_norm(attn_out, p["fuse_na"], cfg.norm_eps)
                              + rms_norm(ssm_out, p["fuse_ns"], cfg.norm_eps))
        if cfg.post_block_norm:
            attn_out = rms_norm(attn_out, p["ln1_post"], cfg.norm_eps,
                                self.gemma_style)
        h = h + attn_out
        h = sharding.constrain(h, "batch", None, None)

        x2 = rms_norm(h, p["ln2"], cfg.norm_eps, self.gemma_style)
        if moe:
            b, s, d = x2.shape
            y2d, aux = moe_ffn(x2.reshape(b * s, d), p["moe"], cfg)
            ffn_out = y2d.reshape(b, s, d)
        else:
            ffn_out = gated_mlp(x2, p["mlp"]["wi"], p["mlp"].get("wg"),
                                p["mlp"]["wo"], cfg.act)
        if cfg.post_block_norm:
            ffn_out = rms_norm(ffn_out, p["ln2_post"], cfg.norm_eps,
                               self.gemma_style)
        h = h + ffn_out
        return sharding.constrain(h, "batch", None, None), aux, kv_state, \
            new_ssm

    # -- embedding helpers ---------------------------------------------------
    def _embed_inputs(self, params, tokens, frontend_embeds, mask=None):
        cfg = self.cfg
        if cfg.family == "audio":
            # encoder-only: the (stub) frame features ARE the sequence
            fe = frontend_embeds @ params["frontend"]["proj1"]
            fe = jax.nn.gelu(fe.astype(jnp.float32)).astype(fe.dtype)
            fe = fe @ params["frontend"]["proj2"]
            if mask is not None:
                fe = jnp.where(mask[..., None],
                               params["mask_embed"].astype(fe.dtype), fe)
            return sharding.constrain(fe, "batch", None, None)
        h = embed_lookup(params["embed"], tokens)
        if self.gemma_style:
            h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
        parts = []
        if cfg.meta_tokens:
            meta = jnp.broadcast_to(
                params["meta"], (h.shape[0],) + params["meta"].shape)
            parts.append(meta.astype(h.dtype))
        if cfg.frontend_tokens:
            fe = frontend_embeds.astype(h.dtype) @ params["frontend"]["proj1"]
            fe = jax.nn.gelu(fe.astype(jnp.float32)).astype(h.dtype)
            fe = fe @ params["frontend"]["proj2"]
            parts.append(fe)
        if parts:
            h = jnp.concatenate(parts + [h], axis=1)
        return sharding.constrain(h, "batch", None, None)

    @property
    def prefix_tokens(self) -> int:
        return self.cfg.meta_tokens + self.cfg.frontend_tokens

    def _logits(self, params, h):
        cfg = self.cfg
        h = rms_norm(h, params["final_norm"], cfg.norm_eps, self.gemma_style)
        head = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = (h @ head).astype(jnp.float32)
        logits = softcap(logits, cfg.final_logit_softcap)
        return sharding.constrain(logits, "batch", None, "vocab")

    # -- training forward -----------------------------------------------------
    def forward(self, params, tokens, frontend_embeds=None, mask=None):
        """tokens [B, S] -> (logits [B, S_total, V] f32, aux scalar)."""
        cfg = self.cfg
        h = self._embed_inputs(params, tokens, frontend_embeds, mask)
        positions = jnp.arange(h.shape[1])
        aux_total = jnp.float32(0.0)

        no_window = not bool(self.windows.any())

        def run_stack(h, aux_total, stack, windows, moe):
            def body(carry, xs):
                hh, aux = carry
                p, w = xs
                if no_window:
                    w = 0          # static: lets attention skip masks/bias
                hh, a, _, _ = self._layer(hh, p, w, positions, moe)
                return (hh, aux + a), None

            body = jax.checkpoint(body) if cfg.num_layers > 2 else body
            (h, aux_total), _ = jax.lax.scan(
                body, (h, aux_total), (stack, windows))
            return h, aux_total

        wins = jnp.asarray(self.windows)
        if self.n_front:
            h, aux_total = run_stack(h, aux_total, params["front_layers"],
                                     wins[: self.n_front], False)
        h, aux_total = run_stack(h, aux_total, params["layers"],
                                 wins[self.n_front:], self.is_moe)
        return self._logits(params, h), aux_total

    def loss(self, params, batch, z_loss: float = 1e-4):
        """batch: {tokens, labels[, frontend, mask]} -> (scalar, metrics).

        For ``audio`` (encoder-only masked prediction) loss is computed on
        masked positions only (HuBERT-style); otherwise next-token CE.
        """
        logits, aux = self.forward(params, batch.get("tokens"),
                                   batch.get("frontend"),
                                   batch.get("mask"))
        labels = batch["labels"]
        if self.cfg.family == "audio":
            labels = jnp.where(batch["mask"], labels, -1)
        elif self.prefix_tokens:
            pad = jnp.full(
                (labels.shape[0], self.prefix_tokens), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        ce = cross_entropy(logits, labels, z_loss=z_loss)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving ------------------------------------------------------------------
    def cache_shapes(self, batch: int, max_len: int) -> LMCache:
        """Abstract cache description (shapes) for dry-run input_specs."""
        cfg = self.cfg
        L = cfg.num_layers
        s = max_len + self.prefix_tokens
        k = v = conv = ssm = None
        if cfg.family != "ssm":
            k = v = (L, batch, cfg.attn.num_kv_heads, s, self.cfg.head_dim)
        if cfg.family in ("ssm", "hybrid"):
            st = init_ssm_state(cfg, 1)
            conv = (L, batch) + st.conv.shape[1:]
            ssm = (L, batch) + st.ssm.shape[1:]
        return LMCache(k=k, v=v, conv=conv, ssm=ssm, length=())

    def cache_logical(self) -> LMCache:
        """Logical sharding axes for each cache member."""
        cfg = self.cfg
        seq_ax = "seq_shard" if cfg.attn.kv_seq_shard else None
        kv_ax = None if cfg.attn.kv_seq_shard else "kv_heads"
        kv = ("layers", "batch", kv_ax, seq_ax, None) \
            if cfg.family != "ssm" else None
        conv = ssm = None
        if cfg.family in ("ssm", "hybrid"):
            conv = ("layers", "batch", "ssm_inner", None)
            ssm = ("layers", "batch", "heads", None, None)
        return LMCache(k=kv, v=kv, conv=conv, ssm=ssm, length=())

    def init_cache(self, batch: int, max_len: int,
                   dtype=None) -> LMCache:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        L = cfg.num_layers
        max_len = max_len + self.prefix_tokens
        k = v = conv = ssm = None
        if cfg.family != "ssm":
            hd = self.cfg.head_dim
            k = jnp.zeros((L, batch, cfg.attn.num_kv_heads, max_len, hd),
                          dtype)
            v = jnp.zeros_like(k)
        if cfg.family in ("ssm", "hybrid"):
            st = init_ssm_state(cfg, batch, dtype)
            conv = jnp.broadcast_to(st.conv, (L,) + st.conv.shape)
            ssm = jnp.broadcast_to(st.ssm, (L,) + st.ssm.shape)
        return LMCache(k=k, v=v, conv=conv, ssm=ssm, length=jnp.int32(0))

    def _stack_scan(self, h, stack, wins_l, positions, moe, cache, base,
                    emit: bool):
        """Scan a layer stack threading per-layer cache slices.

        ``cache``: LMCache or None.  ``base``: first layer index of this
        stack inside the stacked cache arrays.  Returns (h, per-layer ys).
        """
        cfg = self.cfg
        n = wins_l.shape[0]
        need_kv = cfg.family != "ssm"
        need_ssm = cfg.family in ("ssm", "hybrid")
        dummy = jnp.zeros((n, 1))
        xs = (stack, wins_l,
              cache.k[base: base + n] if cache is not None and need_kv
              else dummy,
              cache.v[base: base + n] if cache is not None and need_kv
              else dummy,
              cache.conv[base: base + n] if cache is not None and need_ssm
              else dummy,
              cache.ssm[base: base + n] if cache is not None and need_ssm
              else dummy)

        no_window = not bool(self.windows.any())

        def sbody(hh, x):
            p, w, kl, vl, cl, sl = x
            if no_window:
                w = 0
            io = LayerIO(
                kv=KVCache(k=kl, v=vl, length=cache.length)
                if cache is not None and need_kv else None,
                ssm=SSMState(conv=cl, ssm=sl)
                if cache is not None and need_ssm else None,
                emit_state=emit)
            hh, _, kv_state, ssm_state = self._layer(
                hh, p, w, positions, moe, io)
            z = jnp.zeros((1,))
            if cache is not None and need_kv:
                ys_kv = (kv_state.k, kv_state.v)
            elif emit and need_kv:
                ys_kv = kv_state          # (k, v)
            else:
                ys_kv = (z, z)
            ys_ssm = (ssm_state.conv, ssm_state.ssm) \
                if ssm_state is not None else (z, z)
            return hh, (ys_kv[0], ys_kv[1], ys_ssm[0], ys_ssm[1])

        if emit is False and cache is None and cfg.num_layers > 2:
            sbody = jax.checkpoint(sbody)
        return jax.lax.scan(sbody, h, xs)

    def decode_step(self, params, cache: LMCache, tokens):
        """tokens [B, T(=1)] -> (logits [B, T, V], new cache)."""
        cfg = self.cfg
        h = embed_lookup(params["embed"], tokens)
        if self.gemma_style:
            h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
        positions = cache.length + jnp.arange(tokens.shape[1])
        wins = jnp.asarray(self.windows)
        front = self.n_front
        parts = []
        if front:
            h, ys = self._stack_scan(h, params["front_layers"], wins[:front],
                                     positions, False, cache, 0, False)
            parts.append(ys)
        h, ys = self._stack_scan(h, params["layers"], wins[front:],
                                 positions, self.is_moe, cache, front, False)
        parts.append(ys)

        need_kv = cfg.family != "ssm"
        need_ssm = cfg.family in ("ssm", "hybrid")
        cat = lambda i: jnp.concatenate([p[i] for p in parts], 0)
        new_cache = LMCache(
            k=cat(0) if need_kv else None,
            v=cat(1) if need_kv else None,
            conv=cat(2) if need_ssm else None,
            ssm=cat(3) if need_ssm else None,
            length=cache.length + tokens.shape[1])
        return self._logits(params, h), new_cache

    def prefill(self, params, tokens, frontend_embeds=None,
                max_len: Optional[int] = None):
        """Prompt pass -> (last-position logits [B, 1, V], filled cache)."""
        cfg = self.cfg
        b = tokens.shape[0]
        h = self._embed_inputs(params, tokens, frontend_embeds)
        s_total = h.shape[1]
        max_len = max_len or s_total
        positions = jnp.arange(s_total)
        wins = jnp.asarray(self.windows)
        front = self.n_front
        parts = []
        if front:
            h, ys = self._stack_scan(h, params["front_layers"], wins[:front],
                                     positions, False, None, 0, True)
            parts.append(ys)
        h, ys = self._stack_scan(h, params["layers"], wins[front:],
                                 positions, self.is_moe, None, front, True)
        parts.append(ys)

        need_kv = cfg.family != "ssm"
        need_ssm = cfg.family in ("ssm", "hybrid")
        cat = lambda i: jnp.concatenate([p[i] for p in parts], 0)
        k_all = v_all = None
        if need_kv:
            k_all, v_all = cat(0), cat(1)
            pad = max_len + self.prefix_tokens - s_total
            if pad > 0:
                padw = ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0))
                k_all = jnp.pad(k_all, padw)
                v_all = jnp.pad(v_all, padw)
        new_cache = LMCache(
            k=k_all, v=v_all,
            conv=cat(2) if need_ssm else None,
            ssm=cat(3) if need_ssm else None,
            length=jnp.int32(s_total))
        return self._logits(params, h[:, -1:]), new_cache
