"""GQA attention: chunked-exact XLA path, Pallas dispatch, decode w/ KV cache.

Three execution paths, one set of semantics (causal, sliding window, logit
softcap, GQA):

* ``full_attention`` — training/prefill.  On TPU dispatches to the Pallas
  flash kernel; elsewhere an exact memory-efficient XLA implementation
  (scan over KV chunks with the online-softmax recurrence) so 32k-token
  shapes lower on the CPU dry-run without materialising [Sq, Sk].
* ``decode_attention`` — one query against a KV cache.
* ``decode_attention_seq_sharded`` — same, with the cache *sequence* sharded
  over the ``model`` mesh axis (for archs whose few KV heads cannot be
  head-sharded, e.g. glm4's 2 KV heads): each shard computes a partial
  softmax and the shards merge with a log-sum-exp reduction (flash-decode
  adapted to shard_map collectives).

Never repeats KV heads in memory: queries reshape to [B, Hkv, G, S, D].
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.models import sharding

NEG = -1e30


class KVCache(NamedTuple):
    k: jax.Array        # [B, Hkv, S, D]
    v: jax.Array        # [B, Hkv, S, D]
    length: jax.Array   # i32 scalar: valid prefix length


def init_cache(batch: int, kv_heads: int, max_len: int, head_dim: int,
               dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, kv_heads, max_len, head_dim), dtype),
        v=jnp.zeros((batch, kv_heads, max_len, head_dim), dtype),
        length=jnp.int32(0),
    )


def cache_update(cache: KVCache, k_new: jax.Array, v_new: jax.Array
                 ) -> KVCache:
    """Append [B, Hkv, T, D] at the current length."""
    t = k_new.shape[2]
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, 0, cache.length, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, 0, cache.length, 0))
    return KVCache(k=k, v=v, length=cache.length + t)


# ---------------------------------------------------------------------------
# training / prefill attention
# ---------------------------------------------------------------------------


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window=0, softcap: float = 0.0,
                   kv_offset: int = 0, chunk: int = 1024,
                   use_flash: Optional[bool] = None) -> jax.Array:
    """q [B, Hq, Sq, D]; k, v [B, Hkv, Sk, D].  ``window`` may be a traced
    scalar (0 = full attention) so alternating local/global layers can share
    one scanned layer body."""
    if use_flash is None:
        use_flash = jax.default_backend() == "tpu"
    if use_flash and isinstance(window, int):
        from repro.kernels.flash_attention.ops import flash_attention
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, kv_offset=kv_offset)
    return _chunked_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, kv_offset=kv_offset,
                              chunk=chunk)


def _chunked_attention(q, k, v, *, causal, window, softcap, kv_offset,
                       chunk) -> jax.Array:
    """Exact online-softmax attention, scanning KV chunks (XLA path).

    optflags (§Perf O2): ``strided_gqa`` lays query heads out as
    [groups, kv_heads] so the group dim carries the head sharding when
    Hkv < mesh; ``bf16_scores`` feeds the two dots bf16 with f32
    accumulation; ``additive_mask`` folds the causal/window mask into one
    broadcast [Sq, chunk] bias instead of materialised per-head selects.
    """
    from repro.models.optflags import flags
    fl = flags()
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    scale = d ** -0.5
    chunk = min(chunk, sk)
    while sk % chunk:        # largest divisor <= requested chunk
        chunk -= 1
    n_chunks = sk // chunk

    cdt = jnp.bfloat16 if fl.bf16_scores else jnp.float32
    if fl.strided_gqa:
        # head h = g_idx * Hkv + kv_idx: outer dim g inherits head sharding
        qf = q.reshape(b, g, hkv, sq, d).astype(cdt) * scale
        eq_s = "bghqd,bhkd->bghqk"
        eq_o = "bghqk,bhkd->bghqd"
    else:
        qf = q.reshape(b, hkv, g, sq, d).astype(cdt) * scale
        eq_s = "bhgqd,bhkd->bhgqk"
        eq_o = "bhgqk,bhkd->bhgqd"
    kc = k.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, d).transpose(2, 0, 1, 3, 4)

    qpos = (jnp.arange(sq) + kv_offset)[:, None]          # [Sq, 1]
    win = jnp.asarray(window)

    # no mask at all for non-causal, windowless attention (encoders):
    # even an all-true mask costs a materialised broadcast per chunk
    need_mask = causal or not (isinstance(window, int) and window == 0)

    def body(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        s = jnp.einsum(eq_s, qf, kj.astype(cdt),
                       preferred_element_type=jnp.float32)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = (j * chunk + jnp.arange(chunk))[None, :]   # [1, chunk]
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if need_mask:
            mask &= jnp.where(win > 0, kpos > qpos - win, True)
            if fl.additive_mask:
                s = s + jnp.where(mask, 0.0, NEG)         # one broadcast
            else:
                s = jnp.where(mask, s, NEG)
        m_cur = s.max(-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - jnp.where(m_new <= NEG / 2, 0.0, m_new))
        if need_mask and not fl.additive_mask:
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(jnp.where(m <= NEG / 2, NEG, m - m_new))
        alpha = jnp.where(m_new <= NEG / 2, 0.0, alpha)
        l_new = l * alpha + p.sum(-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            eq_o, p.astype(cdt), vj.astype(cdt),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    hshape = (b, g, hkv) if fl.strided_gqa else (b, hkv, g)
    m0 = jnp.full(hshape + (sq, 1), NEG, jnp.float32)
    l0 = jnp.zeros(hshape + (sq, 1), jnp.float32)
    a0 = jnp.zeros(hshape + (sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


def _gqa_layout():
    """(reshape order, score einsum, out einsum) per the strided_gqa flag."""
    from repro.models.optflags import flags
    if flags().strided_gqa:
        return True, "bghtd,bhsd->bghts", "bghts,bhsd->bghtd"
    return False, "bhgtd,bhsd->bhgts", "bhgts,bhsd->bhgtd"


def _decode_scores(q, k, eq, *, softcap, scale):
    s = jnp.einsum(eq, q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    return s


def decode_attention(q: jax.Array, cache: KVCache, *, window=0,
                     softcap: float = 0.0) -> jax.Array:
    """q [B, Hq, T, D] (T = new tokens, usually 1) vs the cached prefix.

    Assumes the new tokens' K/V are already appended: valid positions are
    ``< cache.length``; query i sits at absolute position
    ``cache.length - T + i``.
    """
    b, hq, t, d = q.shape
    hkv, s = cache.k.shape[1], cache.k.shape[2]
    g = hq // hkv
    strided, eq_s, eq_o = _gqa_layout()
    qr = q.reshape((b, g, hkv, t, d) if strided else (b, hkv, g, t, d))
    sc = _decode_scores(qr, cache.k, eq_s, softcap=softcap, scale=d ** -0.5)
    qpos = cache.length - t + jnp.arange(t)               # [T]
    kpos = jnp.arange(s)                                  # [S]
    mask = kpos[None, :] <= qpos[:, None]
    win = jnp.asarray(window)
    mask &= jnp.where(win > 0, kpos[None, :] > qpos[:, None] - win, True)
    sc = jnp.where(mask, sc, NEG)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum(eq_o, p, cache.v.astype(jnp.float32))
    return out.reshape(b, hq, t, d).astype(q.dtype)


def decode_attention_seq_sharded(q: jax.Array, cache: KVCache, mesh: Mesh, *,
                                 axis: str = "model", window=0,
                                 softcap: float = 0.0) -> jax.Array:
    """Flash-decode over a sequence-sharded cache.

    The cache's S dim is sharded over ``axis``; each shard computes a
    partial (max, denom, numerator) and shards merge via an LSE reduction —
    three small collectives instead of all-gathering a multi-GB cache.
    """
    hq = q.shape[1]
    hkv, s_global = cache.k.shape[1], cache.k.shape[2]
    g = hq // hkv
    n_shards = mesh.shape[axis]
    s_local = s_global // n_shards
    # batch stays sharded over the data(/pod) axes inside the shard_map
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names
                  and q.shape[0] % mesh.shape[a] == 0)
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    strided, eq_s, eq_o = _gqa_layout()

    def partial_attn(q_l, k_l, v_l, length):
        bl, _, t, d = q_l.shape
        shard = jax.lax.axis_index(axis)
        qr = q_l.reshape((bl, g, hkv, t, d) if strided
                         else (bl, hkv, g, t, d))
        sc = _decode_scores(qr, k_l, eq_s, softcap=softcap, scale=d ** -0.5)
        qpos = length - t + jnp.arange(t)
        kpos = shard * s_local + jnp.arange(s_local)
        mask = kpos[None, :] <= qpos[:, None]
        win = jnp.asarray(window)
        mask &= jnp.where(win > 0, kpos[None, :] > qpos[:, None] - win, True)
        sc = jnp.where(mask, sc, NEG)
        m = sc.max(-1, keepdims=True)                     # [b,hkv,g,t,1]
        m_glob = jax.lax.pmax(m, axis)
        p = jnp.exp(sc - jnp.where(m_glob <= NEG / 2, 0.0, m_glob))
        p = jnp.where(mask, p, 0.0)
        l = jax.lax.psum(p.sum(-1, keepdims=True), axis)
        o = jnp.einsum(eq_o, p, v_l.astype(jnp.float32))
        o = jax.lax.psum(o, axis)
        out = o / jnp.maximum(l, 1e-30)
        return out.reshape(bl, hq, t, d).astype(q_l.dtype)

    q_spec = P(bspec, None, None, None)
    kv_spec = P(bspec, None, axis, None)
    fn = shard_map(partial_attn, mesh=mesh,
                   in_specs=(q_spec, kv_spec, kv_spec, P()),
                   out_specs=q_spec, check_vma=False)
    return fn(q, cache.k, cache.v, cache.length)
