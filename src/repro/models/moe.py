"""Mixture-of-Experts FFN: sort-based capacity routing + shared experts.

TPU-native dispatch (DESIGN.md §5): instead of a GShard one-hot dispatch
tensor (O(T*E*C) memory — infeasible at 384 experts x 32k tokens) tokens are
*sorted by expert id*; each expert receives a contiguous ``[capacity, d]``
tile and all experts batch into one ``[E, C, d] x [E, d, f]`` einsum that the
MXU executes as E aligned matmuls.  Tokens over capacity are dropped (their
residual passes through), the standard capacity-factor contract.

Sharding: experts -> ``model`` axis (EP: 384/16 = 24 experts per column for
kimi-k2), expert weight rows -> ``data`` (FSDP).  XLA inserts the token
all-to-all at the dispatch/combine boundaries.

Losses: switch-style load-balance aux loss + router z-loss, returned to be
added to the LM loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import sharding
from repro.models.layers import ParamDef


def moe_param_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, cfg.d_ff
    e, se = cfg.moe.num_experts, cfg.moe.shared_experts
    defs = {
        "router": ParamDef((d, e), ("embed", None)),
        "wi": ParamDef((e, d, f), ("experts", "embed", None)),
        "wg": ParamDef((e, d, f), ("experts", "embed", None)),
        "wo": ParamDef((e, f, d), ("experts", None, "embed")),
    }
    if se:
        defs.update({
            "shared_wi": ParamDef((d, se * f), ("embed", "ffn")),
            "shared_wg": ParamDef((d, se * f), ("embed", "ffn")),
            "shared_wo": ParamDef((se * f, d), ("ffn", "embed")),
        })
    return defs


def moe_ffn(x: jax.Array, params: Dict, cfg: ModelConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x [T, d] -> (y [T, d], aux_loss scalar).  T = tokens in microbatch."""
    m = cfg.moe
    t, d = x.shape
    e, k = m.num_experts, m.top_k
    cap = int(max(1, t * k / e * m.capacity_factor))

    # --- routing ---------------------------------------------------------
    logits = (x.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # aux losses (switch-transformer style)
    density = jnp.zeros((e,)).at[top_i.reshape(-1)].add(1.0) / (t * k)
    mean_prob = probs.mean(axis=0)
    aux = e * jnp.sum(density * mean_prob)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux_loss = aux + m.router_z_loss * zloss

    # --- sort-based dispatch ----------------------------------------------
    flat_e = top_i.reshape(-1)                              # [T*k]
    flat_t = jnp.repeat(jnp.arange(t), k)                   # [T*k]
    flat_p = top_p.reshape(-1)
    order = jnp.argsort(flat_e)                             # stable
    se_, st_, sp_ = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k, dtype=jnp.int32) - offsets[se_]  # rank in expert
    keep = pos < cap
    slot = jnp.where(keep, se_ * cap + pos, e * cap)        # overflow slot

    from repro.models.optflags import flags
    xb = x.astype(jnp.bfloat16)
    if flags().moe_slot_centric:
        # O1: index from the slot side.  slot -> token (+1 overflow row
        # swallows dropped assignments); unfilled slots hit the zero row.
        tok_of_slot = jnp.full((e * cap + 1,), t, jnp.int32).at[slot].set(
            st_.astype(jnp.int32))[: e * cap]
        w_of_slot = jnp.zeros((e * cap + 1,)).at[slot].set(
            jnp.where(keep, sp_, 0.0))[: e * cap]
        xb_pad = jnp.concatenate([xb, jnp.zeros((1, d), xb.dtype)])
        xe = xb_pad[tok_of_slot].reshape(e, cap, d)
    else:
        buf = jnp.zeros((e * cap + 1, d), xb.dtype).at[slot].set(xb[st_])
        xe = buf[: e * cap].reshape(e, cap, d)
    xe = sharding.constrain(xe, "experts", None, None)

    # --- expert computation (batched einsum = E aligned MXU matmuls) ------
    h = jnp.einsum("ecd,edf->ecf", xe, params["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, params["wg"])
    act = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    out_e = jnp.einsum("ecf,efd->ecd", act, params["wo"])
    out_e = sharding.constrain(out_e, "experts", None, None)

    # --- combine -----------------------------------------------------------
    if flags().moe_slot_centric:
        # scatter-add straight from expert space: one [T, d] partial sum
        # reconciled across the expert shards instead of [T*k, d]
        contrib = out_e.reshape(e * cap, d).astype(jnp.float32) \
            * w_of_slot[:, None]
        y = jnp.zeros((t + 1, d), jnp.float32).at[tok_of_slot].add(
            contrib)[: t]
        y = sharding.constrain(y, "batch", None)
    else:
        flat_out = jnp.concatenate(
            [out_e.reshape(e * cap, d), jnp.zeros((1, d), out_e.dtype)])
        tok_out = flat_out[slot]                            # [T*k, d]
        w = jnp.where(keep, sp_, 0.0).astype(jnp.float32)
        y = jnp.zeros((t, d), jnp.float32).at[st_].add(
            tok_out.astype(jnp.float32) * w[:, None])

    # --- shared (always-on) experts ---------------------------------------
    if m.shared_experts:
        hs = xb @ params["shared_wi"]
        gs = xb @ params["shared_wg"]
        ys = (jax.nn.silu(gs.astype(jnp.float32)).astype(hs.dtype) * hs) \
            @ params["shared_wo"]
        y = y + ys.astype(jnp.float32)

    return y.astype(x.dtype), aux_loss
