"""Logical-axis sharding rules -> mesh PartitionSpecs.

One source of truth for how every tensor lays out on the production mesh
(``data``/``model``(+``pod``), see launch/mesh.py):

* ``batch``  -> ("pod", "data")   pure DP across pods (slow DCN crosses pods
                                  only for gradient all-reduce)
* ``heads`` / ``ffn`` / ``vocab`` / ``experts`` -> "model"  (TP / EP)
* ``embed``  -> "data"            FSDP-style row sharding of large weights;
                                  XLA all-gathers per layer inside the scan
* ``seq``    -> "model"           sequence sharding (long-context KV caches)
* anything else -> replicated

Rules are *divisibility-aware*: an axis that does not divide evenly over its
mesh axes is replicated instead (e.g. glm4's 2 KV heads on a 16-way model
axis).  ``logical_to_mesh`` is used both for parameter ``in_shardings`` and
for ``constrain`` (activation sharding constraints inside jit).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "seq_shard": ("model",),
    "ssm_inner": ("model",),
    # never sharded
    "layers": (), "seq": (), "head_dim": (), "state": (), "capacity": (),
    "conv": (), "patch": (), None: (),
}

# ---------------------------------------------------------------------------
# ambient mesh (set by launchers; None => all constraints are no-ops)
# ---------------------------------------------------------------------------

_MESH: Optional[Mesh] = None
_MANUAL: frozenset = frozenset()


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _MESH
    prev, _MESH = _MESH, mesh
    try:
        yield
    finally:
        _MESH = prev


@contextmanager
def manual_axes(axes):
    """Trace-time marker: we are inside a shard_map manual over ``axes``.

    Activation constraints are suppressed there (a NamedSharding over the
    full mesh would illegally mix Manual with Auto axes); GSPMD still
    propagates the in_specs shardings of params/batch through the body.
    """
    global _MANUAL
    prev, _MANUAL = _MANUAL, _MANUAL | frozenset(axes)
    try:
        yield
    finally:
        _MANUAL = prev


# ---------------------------------------------------------------------------
# logical axes -> PartitionSpec
# ---------------------------------------------------------------------------


def _mesh_axes_for(logical: Optional[str], mesh: Mesh,
                   dim_size: int) -> Union[Tuple[str, ...], None]:
    axes = LOGICAL_RULES.get(logical, ())
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    total = math.prod(mesh.shape[a] for a in axes)
    if dim_size % total != 0:
        # try a prefix that divides (e.g. batch over pod only)
        for cut in range(len(axes) - 1, 0, -1):
            sub = axes[:cut]
            if dim_size % math.prod(mesh.shape[a] for a in sub) == 0:
                return sub
        return None
    return axes


def logical_to_spec(logical_axes: Sequence[Optional[str]], shape,
                    mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a tensor with the given logical axes + shape."""
    mesh = mesh or _MESH
    if mesh is None:
        return P()
    used: set = set()
    parts = []
    for lg, dim in zip(logical_axes, shape):
        axes = _mesh_axes_for(lg, mesh, dim)
        if axes and not (set(axes) & used):
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            parts.append(None)
    return P(*parts)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Sharding constraint by logical axes; no-op without an ambient mesh
    or inside a manual shard_map region."""
    mesh = _MESH
    if mesh is None or _MANUAL or x.ndim != len(logical_axes):
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]], shape,
                   mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or _MESH
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(logical_axes, shape, mesh))


def tree_shardings(spec_tree, shape_tree, mesh: Optional[Mesh] = None):
    """Map a pytree of logical-axis tuples + shapes -> NamedShardings."""
    mesh = mesh or _MESH
    return jax.tree.map(
        lambda axes, shp: named_sharding(axes, shp, mesh),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )
