"""Model zoo: one ``TransformerLM`` covering dense / MoE / SSM / hybrid /
audio-encoder / VLM families, plus ``input_specs`` — the ShapeDtypeStruct
stand-ins (weak-type-correct, shardable, zero allocation) the multi-pod
dry-run lowers against."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import sharding
from repro.models.transformer import LMCache, TransformerLM


def build_model(cfg: ModelConfig, mesh=None) -> TransformerLM:
    return TransformerLM(cfg, mesh=mesh)


def _sds(shape, dtype, logical, mesh):
    sh = sharding.named_sharding(logical, shape, mesh) if mesh else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training/prefill batch stand-ins for one global batch."""
    b, s = shape.global_batch, shape.seq_len
    tok_ax = ("batch", None)
    specs: Dict[str, Any] = {}
    if cfg.family == "audio":
        specs["frontend"] = _sds((b, s, 1024), jnp.bfloat16,
                                 ("batch", None, None), mesh)
        specs["labels"] = _sds((b, s), jnp.int32, tok_ax, mesh)
        specs["mask"] = _sds((b, s), jnp.bool_, tok_ax, mesh)
        return specs
    text = s - cfg.frontend_tokens
    specs["tokens"] = _sds((b, text), jnp.int32, tok_ax, mesh)
    specs["labels"] = _sds((b, text), jnp.int32, tok_ax, mesh)
    if cfg.frontend_tokens:
        specs["frontend"] = _sds((b, cfg.frontend_tokens, 1024), jnp.bfloat16,
                                 ("batch", None, None), mesh)
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh=None
                ) -> LMCache:
    """Abstract decode-cache stand-ins."""
    model = TransformerLM(cfg)
    shapes = model.cache_shapes(batch, max_len)
    logical = model.cache_logical()
    dt = jnp.dtype(cfg.dtype)

    def one(shp, lg, dtype):
        if shp is None:
            return None
        return _sds(shp, dtype, lg, mesh)

    return LMCache(
        k=one(shapes.k, logical.k, dt),
        v=one(shapes.v, logical.v, dt),
        conv=one(shapes.conv, logical.conv, dt),
        ssm=one(shapes.ssm, logical.ssm, jnp.float32),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None):
    """(cache, tokens) stand-ins for one ``serve_step``: a single new token
    against a KV/SSM cache of ``shape.seq_len``."""
    b = shape.global_batch
    cache = cache_specs(cfg, b, shape.seq_len, mesh)
    tokens = _sds((b, 1), jnp.int32, ("batch", None), mesh)
    return cache, tokens


def param_specs(cfg: ModelConfig, mesh=None):
    """ShapeDtypeStructs (with shardings) for the parameter pytree."""
    model = TransformerLM(cfg)
    shapes = model.param_shapes()
    logical = model.param_logical()
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda shp, lg: _sds(shp, dt, lg, mesh), shapes, logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(v, int) for v in x))


def param_shardings(cfg: ModelConfig, mesh):
    model = TransformerLM(cfg)
    return jax.tree.map(
        lambda shp, lg: sharding.named_sharding(lg, shp, mesh),
        model.param_shapes(), model.param_logical(),
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(v, int) for v in x))


__all__ = ["build_model", "TransformerLM", "LMCache", "batch_specs",
           "cache_specs", "decode_specs", "param_specs", "param_shardings"]
