"""Beyond-paper optimization flags (§Perf hillclimbing).

The baseline (O0) is the straightforward implementation whose roofline the
dry-run records first.  Each level adds targeted fixes identified from the
baseline's dominant roofline terms; the dry-run re-runs with ``--opt N``
into a separate results file so before/after is auditable.

O1 — collective-term fixes (MoE giants were collective-bound):
  * ce_onehot: cross-entropy gold-logit via a fused one-hot contraction
    instead of take_along_axis over the vocab-sharded axis (the gather
    forced GSPMD to replicate the full [B,S,V] f32 logits).
  * embed_vocab_only: embedding table sharded on vocab only; the previous
    (vocab, data) layout made the token gather reshard through a full
    replication ("involuntary full rematerialization" warning).
  * moe_slot_centric: MoE dispatch/combine indexed from the *slot* side
    (slot -> token) so the gathers/scatters move [E,C,d] expert tiles and
    one [T,d] partial-sum instead of the baseline's token-side [T*k, d]
    f32 intermediates, whose cross-shard reconciliation all-reduced
    ~15 GB per MoE layer per microbatch on kimi-k2.

O2 — memory-term fixes (attention-bound cells):
  * strided_gqa: reshape query heads as [groups, kv_heads] (head = g*Hkv+k)
    so the group dim inherits the head sharding even when Hkv < mesh;
    with the baseline [kv_heads, groups] split GSPMD replicated attention
    whenever Hkv didn't divide the model axis.
  * bf16_scores: QK^T and PV dots take bf16 inputs with f32 accumulation
    (preferred_element_type) — halves score-tensor traffic, matches MXU.
  * additive_mask: causal/window masking as one broadcast [Sq, chunk]
    additive bias instead of three materialised [B,H,...] where-selects.

O3 — structural fix for mesh-indivisible heads:
  * pad_heads: pad Hq up to a multiple of the model axis (zero-init wo
    rows for the pad heads) so attention shards 16-way instead of
    replicating; ~Hq_pad/Hq extra FLOPs buys a 16x reduction in
    per-device work (phi3: 40->48 heads, +20% flops, -93.75% per-device).
"""
from __future__ import annotations

import os
from dataclasses import dataclass


@dataclass(frozen=True)
class OptFlags:
    ce_onehot: bool = False
    embed_vocab_only: bool = False
    moe_slot_centric: bool = False
    strided_gqa: bool = False
    bf16_scores: bool = False
    additive_mask: bool = False
    pad_heads: bool = False


LEVELS = {
    0: OptFlags(),
    1: OptFlags(ce_onehot=True, embed_vocab_only=True,
                moe_slot_centric=True),
    2: OptFlags(ce_onehot=True, embed_vocab_only=True,
                moe_slot_centric=True, strided_gqa=True,
                bf16_scores=True, additive_mask=True),
    3: OptFlags(ce_onehot=True, embed_vocab_only=True,
                moe_slot_centric=True, strided_gqa=True,
                bf16_scores=True, additive_mask=True, pad_heads=True),
}

_FLAGS = LEVELS[int(os.environ.get("REPRO_OPT_LEVEL", "0"))]


def set_level(level: int) -> OptFlags:
    global _FLAGS
    _FLAGS = LEVELS[level]
    return _FLAGS


def set_flags(flags: OptFlags) -> None:
    global _FLAGS
    _FLAGS = flags


def flags() -> OptFlags:
    return _FLAGS
