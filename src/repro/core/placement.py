"""Request->shard placement policies — the scheduling half of KMP_AFFINITY.

``core/affinity.py`` maps *lanes* to devices for a single search; this
module maps whole *requests* (self-play games, serve queries) to slot-pool
shards when the SearchService pool is sharded over a mesh
(``SearchService(mesh=...)``).  The paper's scatter-vs-compact affinity
experiments (Fig. 9) reappear one level up: where a request lands relative
to the shards decides how many devices are busy and how long each shard's
pending queue grows — exactly the knee the 2015 follow-up study attributes
to work *distribution*, not thread count.

Policies (affinity analogues in parentheses):

* ``round_robin`` (*scatter*): submission ``i`` goes to shard ``i % n``,
  skipping full shards — every device busy as early as possible.
* ``fill_first`` (*compact*): the lowest-indexed shard with queue headroom
  admits everything — maximum per-shard batch utilisation, idle tail
  shards; this is the deliberately-bad placement the benchmarks use to
  show the knee (the device-side rebalance bails it out).
* ``colour_balanced`` (*balanced*): the least-loaded shard admits, ties to
  the lowest index — per-shard in-flight game counts stay within one of
  each other, so each shard's colour-capped admission alternates colours
  exactly like the single-pool dispatcher.
* ``config_affine`` (the 2015 follow-up's resident-search affinity): a
  request sticks to the shard that last hosted its search configuration
  (the ``config_key`` the SearchService derives from the traced
  per-request ``(sims, c_uct, virtual_loss)`` knobs) while that shard has
  headroom, falling back to least-loaded for new or displaced configs.
  With per-slot traced params no shard *needs* same-config batches to
  avoid retracing — this policy exists to study the locality axis the
  Scaling-MCTS paper attributes the 240-thread recovery to.

Placement is pure host-side bookkeeping: it never changes a serve query's
answer (the serve RNG contract makes results placement-independent) and is
deterministic in submission order, so a seeded run places — and therefore
plays — identically every time (tests/test_sharded_service.py pins this).

Streaming estimates: with a deep dispatch pipeline the polled truth lags
the device by up to ``pipeline_depth`` supersteps, so raw in-flight
counts overstate occupancy.  The service feeds the policy a per-class,
per-shard **landed** estimate (results observed complete on device but
not yet polled — non-blocking ring peeks classify each unread row by
its ticket, ``SearchService.peek_landed``); :meth:`PlacementPolicy
.choose` subtracts the request class's landed count when
*comparing* shard loads, while the hard per-shard capacity gate stays on
the raw in-flight count so a device queue can never overflow on an
optimistic estimate.  Estimates are refreshed only by the pipelined
path: at ``pipeline_depth=1`` they are identically zero and placement is
bit-for-bit the PR 4 behaviour.  Because peeks depend on device timing,
streaming-mode placement (and so game colouring) may vary run to run —
the synchronous path keeps the determinism pin above.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

POLICIES = ("round_robin", "fill_first", "colour_balanced", "config_affine")

# request classes tracked independently (full games vs single searches)
CLS_GAME = 0
CLS_SERVE = 1


def place(
    policy: str,
    cursor: int,
    in_flight: np.ndarray,
    capacity: int,
    affine: Optional[int] = None,
    load: Optional[np.ndarray] = None,
    allowed: Optional[np.ndarray] = None,
) -> Optional[int]:
    """Pure placement step: the shard that admits the next request.

    ``cursor`` is the policy's round-robin position (ignored by the other
    policies), ``in_flight`` the per-shard outstanding count for the
    request's class, ``capacity`` the per-shard in-flight cap, ``affine``
    the shard that last hosted this request's search configuration (only
    ``config_affine`` reads it).  ``load`` is the per-shard occupancy
    *estimate* used for load comparisons (in-flight minus landed results
    not yet polled; defaults to ``in_flight`` — the synchronous truth);
    the capacity gate always uses the raw ``in_flight`` so estimates can
    never oversubscribe a device queue.  ``allowed`` is an optional
    ``bool[n_shard]`` candidate mask (the unified multi-bucket
    scheduler's per-bucket shard subset + borrowing rule, PR 10):
    disallowed shards are treated as full, so every policy places only
    within the mask; ``None`` allows every shard (the historical
    behaviour, bit for bit).  Returns ``None`` when every (allowed)
    shard is full.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown placement {policy!r}; want {POLICIES}")
    n = len(in_flight)
    if load is None:
        load = in_flight
    open_ = in_flight < capacity
    if allowed is not None:
        open_ = open_ & np.asarray(allowed, bool)
    if not open_.any():
        return None
    if policy == "round_robin":
        for k in range(n):
            s = (cursor + k) % n
            if open_[s]:
                return s
    if policy == "fill_first":
        return int(np.argmax(open_))            # lowest open shard
    if policy == "config_affine" and affine is not None and open_[affine]:
        return int(affine)                      # sticky while there is room
    # colour_balanced (and affine fallback): least loaded, lowest index
    masked = np.where(open_, load, np.iinfo(np.int64).max)
    return int(np.argmin(masked))


class PlacementPolicy:
    """Stateful wrapper: per-class cursors + in-flight counts for one pool.

    The SearchService calls :meth:`choose` at submission and
    :meth:`release` when the ticket's result is polled; both run in
    submission/poll order, so the assignment sequence is a deterministic
    function of the workload (no RNG involved).
    """

    def __init__(self, policy: str, n_shard: int):
        if policy not in POLICIES:
            raise ValueError(f"unknown placement {policy!r}; want {POLICIES}")
        self.policy = policy
        self.n_shard = n_shard
        self.in_flight = np.zeros((2, n_shard), np.int64)  # [class, shard]
        # device-completed but unpolled, per [class, shard] (the streaming
        # pipeline classifies unread ring rows by ticket)
        self.landed = np.zeros((2, n_shard), np.int64)
        self._cursor = [0, 0]
        self._affine = {}  # config_key -> shard that last hosted it

    def note_landed(self, landed: np.ndarray) -> None:
        """Record device-completed-but-unpolled results per class/shard.

        Fed by the streaming pipeline's non-blocking ring peeks
        (``SearchService.peek_landed``), which classify each unread ring
        row by its ticket; an absolute ``[2, n_shard]`` observation, not
        a delta.  Each :meth:`release` retires one landed result, so the
        estimate decays back to the polled truth between peeks.
        """
        self.landed = np.maximum(np.asarray(landed, np.int64), 0)

    def choose(self, cls: int, capacity: int, config_key=None,
               allowed: Optional[np.ndarray] = None) -> Optional[int]:
        """Admit one request of class ``cls``; returns its shard or None.

        ``config_key`` is any hashable signature of the request's traced
        search configuration (the SearchService passes the per-side
        ``(sims, c_uct, virtual_loss)`` tuple); only ``config_affine``
        consults it.  ``allowed`` restricts candidates to a shard subset
        (``bool[n_shard]``; ``None`` = all — see :func:`place`).  Load
        comparisons run against the in-flight *estimate* (in-flight
        minus landed); the capacity gate stays on the raw count (see the
        module docstring).
        """
        track = self.policy == "config_affine" and config_key is not None
        affine = self._affine.get(config_key) if track else None
        load = self.in_flight[cls] - np.minimum(self.landed[cls],
                                                self.in_flight[cls])
        s = place(self.policy, self._cursor[cls], self.in_flight[cls],
                  capacity, affine, load=load, allowed=allowed)
        if s is None:
            return None
        self.in_flight[cls, s] += 1
        if self.policy == "round_robin":
            self._cursor[cls] = (s + 1) % self.n_shard
        if track:
            # bound the affinity map: long-lived serving processes may see
            # unboundedly many distinct configs; evict oldest-inserted
            self._affine.pop(config_key, None)
            self._affine[config_key] = s
            if len(self._affine) > 1024:
                self._affine.pop(next(iter(self._affine)))
        return s

    def release(self, cls: int, shard: int) -> None:
        """Return a shard's slot when the request's result is polled.

        Also retires one landed-estimate unit: a polled result was, by
        definition, landed.
        """
        self.in_flight[cls, shard] -= 1
        self.landed[cls, shard] = max(self.landed[cls, shard] - 1, 0)
