"""Persistent league: continuous evaluation as a first-class workload.

The paper's methodology — strength measured by self-play tournaments — is
a one-shot cross table in core/tournament.py.  The league turns it into a
*service*: a long-lived scheduler on top of the multiplexed
:class:`~repro.core.service.SearchService` pool that keeps playing until
the ratings are **resolved**, not until a fixed game count runs out.

Three ideas, layered:

* **Elo-driven scheduling.**  After every wave the league refits the
  Bradley–Terry ratings *with covariance*
  (:func:`~repro.core.tournament.elo_estimate`) and schedules the next
  wave only for pairings whose rating difference is still inside ``z``
  standard errors (``EloEstimate.separated``).  Resolved pairings stop
  consuming games; unresolved (or never-played) ones keep getting waves
  until everything is separated at the target confidence or the game
  budget runs out.  ``schedule="round_robin"`` keeps scheduling *every*
  pairing each wave under the same stop test — the control arm
  benchmarks/bench_league.py measures games-to-separation against.

* **Colour-targeted admission.**  Each game is submitted with a forced
  colour (``submit_game(a_black=...)``): the pairing's Black owner comes
  from a per-pairing **colour ledger** (``blacks[i, j]`` = games of
  pairing (i, j) in which ``i`` held Black), restoring the strict
  per-pairing +-1 balance through the multiplexed pool.  The ledger is
  part of the league state, so balance survives restarts.

* **Crash/resume.**  A :class:`~repro.runtime.ft.PreemptionHandler`
  drives checkpoint-at-wave-boundary: after every wave the full league
  state (win matrix, game counts, colour ledger, wave counter, seed) is
  snapshotted to ``state_dir`` via an atomic write-then-rename, and a
  preempted league exits cleanly at the next boundary.  Scheduling is a
  *pure function* of that state — per-game RNG keys derive from
  ``(seed, i, j, game_index)``, sides from the game index, colours from
  the ledger — so a resumed league replays the exact remaining schedule
  and converges to the same cross table bit for bit (the
  tests/test_league.py kill/resume pin).  A torn snapshot (partial
  write, truncated file) fails JSON validation and the loader falls back
  to the previous one.

The wave loop::

    load snapshot (resume) or start empty
    loop:
      fit elo_estimate(win, games)             # ratings + covariance
      pairs <- still-overlapping pairings      # or all, round_robin
      stop if none (converged) / budget gone / preempted
      submit games_per_wave per pair           # key, side, forced colour
      drain the pool; fold results into win/games/ledger
      snapshot state                            # atomic, wave boundary
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
from typing import Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.config import MCTSConfig
from repro.core import stats
from repro.core.mcts import MCTS
from repro.core.service import LANE_TOURNAMENT, SearchService, pad_slots
from repro.core.tournament import (EloEstimate, elo_estimate,
                                   trace_compatible)
from repro.go.board import GoEngine
from repro.runtime.ft import PreemptionHandler

STATE_SCHEMA = "league_state/v1"
SCHEDULES = ("adaptive", "round_robin")


def game_key(seed: int, i: int, j: int, g: int) -> np.ndarray:
    """The RNG key of pairing (i, j)'s ``g``-th game — a pure function.

    Keys never live in mutable RNG state: deriving them from
    ``(seed, i, j, g)`` makes the whole schedule replayable from a
    snapshot, which is what the kill/resume bit-identity rests on.
    """
    rng = np.random.default_rng((int(seed), int(i), int(j), int(g)))
    return rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)


class LeagueResult(NamedTuple):
    """The league's cross table plus its convergence verdict."""
    names: Tuple[str, ...]
    win_matrix: np.ndarray    # f64[P,P] points of row vs column
    games: np.ndarray         # f64[P,P] games per pairing (symmetric)
    blacks: np.ndarray        # i64[P,P] colour ledger: row held Black
    elo: EloEstimate          # ratings + covariance/CI at the target z
    waves: int                # waves completed (including resumed ones)
    games_played: int         # total games in the cross table
    converged: bool           # every pairing separated at confidence z
    stopped: bool             # exited early on preemption

    def table(self) -> str:
        """Human-readable standings with CIs, best first."""
        played = self.games.sum(axis=1).astype(np.int64)
        order = np.argsort(-self.elo.elo)
        width = max(len(n) for n in self.names)
        lines = [f"{'player':<{width}}  elo      ci      games"]
        for p in order:
            lines.append(f"{self.names[p]:<{width}}  "
                         f"{self.elo.elo[p]:<+7.0f}  "
                         f"+-{self.elo.ci[p]:<5.0f} {played[p]}")
        return "\n".join(lines)


class League:
    """Elo-driven, crash-resumable all-play-all league over one pool.

    ``configs`` must be trace-compatible (only the traced fields of
    core/tournament.py may differ): the league exists to keep many
    differently-configured searches resident in **one** compiled
    dispatch, and falls back to nothing — incompatible configs raise.

    ``z`` is the separation confidence multiplier (1.96 = 95%);
    ``budget`` caps total games (``None`` = unbounded); ``state_dir``
    enables wave-boundary snapshots and ``resume=True`` restores the
    newest valid one.  ``preemption`` is the
    :class:`~repro.runtime.ft.PreemptionHandler` polled at wave
    boundaries (default: a fresh handler with **no** signals bound, so
    library use never hijacks the process's handlers — the
    launch/league.py CLI binds SIGTERM/SIGINT).  ``on_wave`` is called
    after every completed wave with the per-wave record dict —
    benchmarks and tests use it to observe (or interrupt) the schedule.
    """

    def __init__(self, engine: GoEngine, configs: Sequence[MCTSConfig],
                 names: Optional[Sequence[str]] = None,
                 z: float = stats.Z95, budget: Optional[int] = None,
                 games_per_wave: int = 2, schedule: str = "adaptive",
                 state_dir: Optional[str] = None, resume: bool = False,
                 slots: int = 0, max_moves: Optional[int] = None,
                 seed: int = 0, superstep: int = 4, mesh=None,
                 placement: str = "round_robin", rebalance: bool = True,
                 multihop: bool = True, pipeline_depth: int = 1,
                 preemption: Optional[PreemptionHandler] = None,
                 on_wave: Optional[Callable[[dict], None]] = None,
                 **mcts_kw):
        if len(configs) < 2:
            raise ValueError("league needs at least 2 configs")
        if names is not None and len(names) != len(configs):
            raise ValueError("names must match configs")
        if not trace_compatible(configs):
            raise ValueError(
                "league configs must be trace-compatible (one compiled "
                "dispatch); static-shape differences need per-pair pools "
                "— use core/tournament.py multiplex=False instead")
        if schedule not in SCHEDULES:
            raise ValueError(f"schedule must be one of {SCHEDULES}")
        if games_per_wave < 1:
            raise ValueError("games_per_wave must be >= 1")
        self.engine = engine
        self.configs = list(configs)
        self.names = tuple(names) if names is not None else tuple(
            f"cfg{i}:{c.lanes}x{c.sims_per_move}"
            for i, c in enumerate(configs))
        self.z = float(z)
        self.budget = budget
        self.games_per_wave = games_per_wave
        self.schedule = schedule
        self.state_dir = state_dir
        self.seed = seed
        self.max_moves = max_moves
        self.superstep = superstep
        self.mesh = mesh
        self.placement = placement
        self.rebalance = rebalance
        self.multihop = multihop
        self.pipeline_depth = pipeline_depth
        self.preemption = preemption or PreemptionHandler(signals=())
        self.on_wave = on_wave
        self.mcts_kw = mcts_kw
        P = len(configs)
        self.pair_list = list(itertools.combinations(range(P), 2))
        self.slots = pad_slots(
            slots or min(self.games_per_wave * len(self.pair_list), 8),
            mesh)
        # league state (restored by resume(), folded by each wave)
        self.win = np.zeros((P, P))
        self.counts = np.zeros((P, P))
        self.blacks = np.zeros((P, P), np.int64)
        self.wave = 0
        self.games_played = 0
        self.history: List[dict] = []
        self.service: Optional[SearchService] = None
        if resume:
            if state_dir is None:
                raise ValueError("resume=True needs a state_dir")
            self._restore()

    # ---------------------------------------------------------- state files

    def _fingerprint(self) -> dict:
        """The schedule-defining knobs a snapshot must match to restore."""
        return {"names": list(self.names), "seed": self.seed,
                "z": self.z, "games_per_wave": self.games_per_wave,
                "schedule": self.schedule,
                "budget": self.budget}

    def _snapshot_path(self, wave: int) -> str:
        return os.path.join(self.state_dir, f"league-{wave:06d}.json")

    def save_state(self) -> str:
        """Atomically snapshot league state; returns the snapshot path.

        Write-then-``os.replace`` means a crash mid-write leaves a
        ``.tmp`` the loader never looks at; a torn file that somehow
        lands at the final name fails ``json.load`` and the loader falls
        back to the previous wave's snapshot.
        """
        os.makedirs(self.state_dir, exist_ok=True)
        path = self._snapshot_path(self.wave)
        payload = {"schema": STATE_SCHEMA,
                   "fingerprint": self._fingerprint(),
                   "wave": self.wave,
                   "games_played": self.games_played,
                   "win": self.win.tolist(),
                   "games": self.counts.tolist(),
                   "blacks": self.blacks.tolist()}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)
        return path

    def _snapshots(self) -> List[str]:
        if not os.path.isdir(self.state_dir):
            return []
        return sorted(f for f in os.listdir(self.state_dir)
                      if f.startswith("league-") and f.endswith(".json"))

    def _restore(self) -> None:
        """Restore the newest valid snapshot (torn files fall through)."""
        for name in reversed(self._snapshots()):
            path = os.path.join(self.state_dir, name)
            try:
                with open(path) as f:
                    payload = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue                      # torn/partial: try the previous
            if payload.get("schema") != STATE_SCHEMA:
                continue
            if payload["fingerprint"] != self._fingerprint():
                raise ValueError(
                    f"snapshot {path} was written by a league with "
                    f"different settings: {payload['fingerprint']} != "
                    f"{self._fingerprint()}")
            P = len(self.configs)
            self.win = np.asarray(payload["win"], np.float64)
            self.counts = np.asarray(payload["games"], np.float64)
            self.blacks = np.asarray(payload["blacks"], np.int64)
            if self.win.shape != (P, P):
                raise ValueError(f"snapshot {path} is for "
                                 f"{self.win.shape[0]} configs, not {P}")
            self.wave = int(payload["wave"])
            self.games_played = int(payload["games_played"])
            return
        # no (valid) snapshot: a fresh league — resume is idempotent

    # ------------------------------------------------------------ scheduling

    def estimate(self) -> EloEstimate:
        """Current ratings + covariance at the league's confidence."""
        return elo_estimate(self.win, self.counts, z=self.z)

    def overlapping(self, est: Optional[EloEstimate] = None
                    ) -> List[Tuple[int, int]]:
        """Pairings not yet separated at the target confidence."""
        est = est or self.estimate()
        return [(i, j) for (i, j) in self.pair_list
                if not est.separated(i, j)]

    def _next_wave_pairs(self, est: EloEstimate) -> List[Tuple[int, int]]:
        if self.schedule == "round_robin":
            # control arm: the stop test is identical (all separated),
            # only the wave keeps funding already-resolved pairings
            return list(self.pair_list) if self.overlapping(est) else []
        return self.overlapping(est)

    def _plan_game(self, i: int, j: int, g: int, n: int) -> dict:
        """Key, side, and forced colour of pairing (i, j)'s game ``g``.

        Black ownership follows the colour ledger (fewest Blacks so far
        takes Black; ties stagger by ``g + n`` so simultaneous pairings
        do not all force the same colour); the A-side alternates with
        the game index.  All inputs live in the snapshot, so the plan is
        replayable.
        """
        lb_i, lb_j = int(self.blacks[i, j]), int(self.blacks[j, i])
        if lb_i != lb_j:
            black = i if lb_i < lb_j else j
        else:
            black = i if (g + n) % 2 == 0 else j
        a = i if g % 2 == 0 else j
        return {"key": game_key(self.seed, i, j, g),
                "a": a, "b": j if a == i else i,
                "black": black, "a_black": black == a}

    def _ensure_service(self) -> SearchService:
        if self.service is not None:
            return self.service
        cfgs = self.configs
        shared = dataclasses.replace(
            cfgs[0], sims_per_move=max(c.sims_per_move for c in cfgs))
        player = MCTS(self.engine, shared, **self.mcts_kw)
        svc = SearchService(self.engine, player, player, self.slots,
                            max_moves=self.max_moves,
                            superstep=self.superstep, mesh=self.mesh,
                            placement=self.placement,
                            rebalance=self.rebalance,
                            multihop=self.multihop,
                            pipeline_depth=self.pipeline_depth)
        # forced colours make the aggregate cap redundant (the ledger
        # holds every pairing at +-1, hence the pool at +-n_pairs), and
        # an active cap could starve a ledger-forced demand — leave it
        # at the no-cap default.  Capacities cover one full wave.
        wave_max = len(self.pair_list) * self.games_per_wave
        svc.reset(seed=self.seed, game_capacity=wave_max,
                  ring_capacity=wave_max + self.slots)
        self.service = svc
        return svc

    def run_wave(self) -> Optional[dict]:
        """Schedule, play, and fold one wave; ``None`` when converged.

        The returned record (also appended to ``history`` and passed to
        ``on_wave``) carries the wave index, the scheduled pairings, the
        games played, and the post-wave separation per scheduled pair.
        """
        est = self.estimate()
        pairs = self._next_wave_pairs(est)
        if not pairs:
            return None
        if self.budget is not None:
            remaining = self.budget - self.games_played
            if remaining <= 0:
                return None
        else:
            remaining = None
        svc = self._ensure_service()
        pair_index = {p: n for n, p in enumerate(self.pair_list)}
        cfgs = self.configs
        meta: Dict[int, dict] = {}
        for (i, j) in pairs:
            n = pair_index[(i, j)]
            for w in range(self.games_per_wave):
                if remaining is not None and len(meta) >= remaining:
                    break
                g = int(self.counts[i, j]) + w
                plan = self._plan_game(i, j, g, n)
                a, b = plan["a"], plan["b"]
                t = svc.submit_game(
                    key=plan["key"], lane=LANE_TOURNAMENT,
                    sims=(cfgs[a].sims_per_move, cfgs[b].sims_per_move),
                    c_uct=(cfgs[a].c_uct, cfgs[b].c_uct),
                    virtual_loss=(cfgs[a].virtual_loss,
                                  cfgs[b].virtual_loss),
                    prior_weight=(cfgs[a].prior_weight,
                                  cfgs[b].prior_weight),
                    a_black=plan["a_black"])
                meta[t] = {"i": i, "j": j, **plan}
        if not meta:
            return None
        for r in svc.drain():
            m = meta[r.ticket]
            i, j, a = m["i"], m["j"], m["a"]
            # +1 = the A-side config won (A owns Black iff a_is_black)
            a_score = r.winner * (1.0 if r.a_is_black else -1.0)
            i_pts = (0.5 + 0.5 * a_score if a == i
                     else 0.5 - 0.5 * a_score)
            self.win[i, j] += i_pts
            self.win[j, i] += 1.0 - i_pts
            self.counts[i, j] += 1
            self.counts[j, i] += 1
            self.blacks[m["black"],
                        j if m["black"] == i else i] += 1
            self.games_played += 1
        self.wave += 1
        est = self.estimate()
        rec = {"wave": self.wave, "pairs": list(pairs),
               "games": len(meta), "games_played": self.games_played,
               "separation": {f"{i},{j}": round(est.separation(i, j), 3)
                              for (i, j) in pairs}}
        self.history.append(rec)
        if self.state_dir is not None:
            self.save_state()
        if self.on_wave is not None:
            self.on_wave(rec)
        return rec

    def run(self, max_waves: Optional[int] = None) -> LeagueResult:
        """Wave until converged, out of budget, preempted, or capped."""
        waves = 0
        while max_waves is None or waves < max_waves:
            if self.preemption.should_stop:
                break
            if self.run_wave() is None:
                break
            waves += 1
        return self.result()

    def result(self) -> LeagueResult:
        """The current cross table and convergence verdict."""
        est = self.estimate()
        converged = (self.games_played > 0
                     and not self.overlapping(est))
        return LeagueResult(
            names=self.names, win_matrix=self.win.copy(),
            games=self.counts.copy(), blacks=self.blacks.copy(),
            elo=est, waves=self.wave, games_played=self.games_played,
            converged=converged, stopped=self.preemption.should_stop)
