"""Distributed MCTS: root parallelism across mesh devices.

The paper is bounded by one shared-memory board (240 threads).  The natural
next rung — which its conclusion calls for — is distributed trees.  We place
``root_trees`` independent tree-parallel searches across the mesh with
``shard_map`` and merge root statistics with a single small ``psum`` (a
[num_actions] vector per tree), the collective analogue of FUEGO's shared
root.  This is the configuration the multi-pod dry-run lowers at 256/512
chips.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.config import MCTSConfig
from repro.core import tree as tree_lib
from repro.core.mcts import MCTS
from repro.go.board import GoEngine, GoState


def distributed_best_move(engine: GoEngine, cfg: MCTSConfig, mesh: Mesh,
                          axis: str = "data", **mcts_kw):
    """Build a jitted ``(root_state, rng) -> action`` running root-parallel
    search sharded over ``axis`` (trees_per_device trees on each device)."""
    n_dev = mesh.shape[axis]
    total_trees = max(cfg.root_trees, n_dev)
    per_dev = max(1, total_trees // n_dev)
    searcher = MCTS(engine, cfg, **mcts_kw)

    def local_search(root: GoState, keys):
        # keys: [per_dev, 2] on this shard; tile the root per local tree
        roots = jax.tree.map(
            lambda x: jnp.broadcast_to(x, keys.shape[:1] + jnp.shape(x)),
            root)
        res = searcher.search_batch(roots, keys)
        visits = res.root_visits.sum(axis=0)
        return visits

    def sharded(root: GoState, keys):
        visits = local_search(root, keys)
        visits = jax.lax.psum(visits, axis)          # merge root statistics
        return tree_lib.select_action(visits, engine.legal_moves(root))

    other_axes = tuple(a for a in mesh.axis_names if a != axis)
    key_spec = P(axis)
    rep = P()

    fn = shard_map(
        sharded, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: rep, _state_spec(engine)), key_spec),
        out_specs=rep,
        check_vma=False,
    )

    @jax.jit
    def run(root: GoState, rng):
        keys = jax.random.split(rng, n_dev * per_dev).reshape(
            n_dev * per_dev, 2)
        return fn(root, keys)

    return run


def _state_spec(engine: GoEngine) -> GoState:
    # pytree skeleton for in_specs construction
    return engine.init_state()


def selfplay_step(engine: GoEngine, cfg: MCTSConfig, mesh: Mesh,
                  axis: str = "data", **mcts_kw):
    """jittable one-move step of distributed self-play: state -> state.

    This is the function ``launch/dryrun.py`` lowers on the production mesh
    for the paper's own application cells.
    """
    move_fn_inner = distributed_best_move(engine, cfg, mesh, axis, **mcts_kw)

    def step(root: GoState, rng):
        action = move_fn_inner(root, rng)
        return engine.play(root, action)

    return step
