"""Unified SearchService: one batched search dispatcher for every consumer.

The Xeon Phi papers' scaling lesson is that MCTS throughput is set by how
search work is *scheduled* onto the hardware, and the 2015 follow-up shows
a work-queue dispatch model recovering the scaling that thread-per-search
loses.  The jax_pallas analogue is a single admission-controlled batch:
every workload — arena self-play (core/arena.py), external best-move
queries (serving/go_service.py), tournament pairings (core/tournament.py)
— submits :class:`SearchRequest` tickets into one device-resident slot
pool, and one jitted ``dispatch`` step advances all ``S`` slots together:

* **Admission** (the device-side refill): empty slots pull requests from
  device-resident pending queues (a pending counter per queue, no host
  round-trip).  Full-game requests are colour-capped exactly like the PR 1
  host queue (alternating colours, at most +-1 imbalance), so device
  refill is bit-for-bit the host refill.  Serve requests are admitted only
  into cells that player A searches on the next step, making a query's
  result independent of slot placement and batch-mates.
* **Search**: the parity-balanced roll-by-half from PR 1 — one
  ``player_a.search_batch`` over half the slots, one ``player_b`` over the
  other, exactly one search per move.  The per-slot ``sims`` budget is a
  *traced* argument (masked loop tail), so mixed budgets share one
  compiled program.
* **Scatter**: finished requests (game over, or a serve query's single
  search) are appended to a device-resident result ring buffer; their
  slots empty and refill on the next step's admission.

The host only (a) flushes submitted requests in fixed-size chunks and
(b) polls the ring buffer — both amortised over ``superstep`` dispatch
steps, cutting the per-step host sync of the PR 1 arena loop to
``~2/superstep`` per move (``host_syncs`` counts them;
benchmarks/bench_service.py proves the reduction).

RNG contract:

* game lanes: a slot splits ``key -> (key, ka, kb)`` once per step like
  ``selfplay.play_game``, so a game with key K is bit-identical to the
  sequential oracle;
* serve lane: the search uses the request key *directly* — a query
  ``(state, key, sims)`` returns exactly
  ``player_a.search_batch(state[None], key[None], sims[None])``.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mcts import MCTS
from repro.go.board import GoEngine, GoState

# Request lanes, tagged by origin.
LANE_ARENA = 0        # arena self-play slot (full game)
LANE_SERVE = 1        # external best-move query (single search)
LANE_TOURNAMENT = 2   # tournament pairing slot (full game)

GAME_LANES = (LANE_ARENA, LANE_TOURNAMENT)
LANE_NAMES = {LANE_ARENA: "arena", LANE_SERVE: "serve",
              LANE_TOURNAMENT: "tournament"}


class SearchRequest(NamedTuple):
    """One pending request (device pytree; leading axis = queue/chunk)."""
    state: GoState        # root position (games start from the empty board)
    key: jax.Array        # u32[2] request RNG key
    lane: jax.Array       # i32 origin tag (LANE_*)
    sims: jax.Array       # i32 playout budget; <=0 = player's configured one
    ticket: jax.Array     # i32 service-assigned id


class SearchResult(NamedTuple):
    """One completed request, scattered back from the ring (host scalars)."""
    ticket: int
    lane: int
    action: int               # move chosen by the final (serve: only) search
    winner: float             # +1 black / -1 white / 0 draw (game lanes)
    moves: int                # moves played (serve: 1)
    tree_nodes: int           # final search's tree size (Fig. 12 metric)
    a_is_black: bool          # game lanes: colour assignment
    root_visits: np.ndarray   # f32[A] final root visit distribution


class _Pending(NamedTuple):
    """Host-buffered submission awaiting flush()."""
    state: GoState
    key: np.ndarray
    lane: int
    sims: int
    ticket: int


class _Slots(NamedTuple):
    """Device-resident slot pool, batched over the S slots."""
    states: GoState       # current position per slot
    keys: jax.Array       # u32[S,2] per-slot RNG chains
    ticket: jax.Array     # i32[S] active request id, -1 = dummy slot
    lane: jax.Array       # i32[S]
    moves: jax.Array      # i32[S] moves played by the active request
    sims: jax.Array       # i32[S] per-request playout budget
    a_black: jax.Array    # bool[S] player A owns Black (game lanes)


class _Queue(NamedTuple):
    """Device-resident circular pending queue (capacity Q)."""
    states: GoState
    keys: jax.Array       # u32[Q,2]
    lane: jax.Array       # i32[Q]
    sims: jax.Array       # i32[Q]
    ticket: jax.Array     # i32[Q]
    size: jax.Array       # i32: total ever enqueued
    head: jax.Array       # i32: total ever admitted (next to admit)


class _Ring(NamedTuple):
    """Device-resident circular result buffer (capacity R)."""
    ticket: jax.Array     # i32[R]
    lane: jax.Array       # i32[R]
    action: jax.Array     # i32[R]
    winner: jax.Array     # f32[R]
    moves: jax.Array      # i32[R]
    nodes: jax.Array      # i32[R]
    a_black: jax.Array    # bool[R]
    visits: jax.Array     # f32[R,A]
    count: jax.Array      # i32: total ever appended


class PoolState(NamedTuple):
    """Everything the jitted dispatch step owns."""
    slots: _Slots
    games: _Queue         # full-game requests (arena + tournament lanes)
    serve: _Queue         # single-search queries
    ring: _Ring
    colour_count: jax.Array   # i32[2]; index 1 = games where A owns Black
    colour_cap: jax.Array     # i32 per-colour admission budget
    parity: jax.Array         # i32 global move parity (0 => Black to move)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _excl_cumsum(mask: jax.Array) -> jax.Array:
    m = mask.astype(jnp.int32)
    return jnp.cumsum(m) - m


def _queue_push(q: _Queue, req: SearchRequest, n: jax.Array) -> _Queue:
    """Append the first ``n`` rows of a fixed-size request chunk."""
    chunk = req.lane.shape[0]
    cap = q.lane.shape[0]
    arange = jnp.arange(chunk, dtype=jnp.int32)
    idx = jnp.where(arange < n, (q.size + arange) % cap, cap)  # cap: dropped

    def put(buf, val):
        return buf.at[idx].set(val, mode="drop")

    return q._replace(
        states=jax.tree.map(put, q.states, req.state),
        keys=put(q.keys, req.key),
        lane=put(q.lane, req.lane),
        sims=put(q.sims, req.sims),
        ticket=put(q.ticket, req.ticket),
        size=q.size + n,
    )


class SearchService:
    """S-slot batched dispatcher bound to an engine and two MCTS players.

    Player A searches the first half-batch at even parity (and, by the
    admission rule, every serve query); games alternate which player owns
    Black under the colour cap.  All static search shapes (lanes, budget,
    board) live in the players — one service, one compiled dispatch.
    """

    def __init__(self, engine: GoEngine, player_a: MCTS, player_b: MCTS,
                 slots: int, max_moves: Optional[int] = None,
                 superstep: int = 4):
        if slots < 2 or slots % 2:
            raise ValueError(f"slots must be even and >= 2, got {slots}")
        if superstep < 1:
            raise ValueError(f"superstep must be >= 1, got {superstep}")
        self.engine = engine
        self.player_a = player_a
        self.player_b = player_b
        self.slots = slots
        self.max_moves = max_moves or engine.max_moves
        self.superstep = superstep
        self._chunk = slots               # flush granularity
        self._init_state = engine.init_state()
        self._dispatch = jax.jit(self._dispatch_impl, static_argnums=(1,))
        self._push_games = jax.jit(self._push_games_impl)
        self._push_serve = jax.jit(self._push_serve_impl)
        self.reset()

    # ------------------------------------------------------------- lifecycle

    def reset(self, seed: int = 0, slot_keys: Optional[np.ndarray] = None,
              colour_cap: Optional[int] = None,
              game_capacity: Optional[int] = None,
              serve_capacity: Optional[int] = None,
              ring_capacity: Optional[int] = None) -> None:
        """Re-initialise the pool, queues, ring, and host bookkeeping.

        ``slot_keys`` seeds the per-slot dummy RNG chains (default: drawn
        from ``default_rng(seed)``, the PR 1 host-queue discipline — the
        same generator then feeds keyless submissions, preserving the
        host path's exact key stream).  Capacities are rounded up to
        powers of two so repeat runs reuse the compiled dispatch.
        """
        S = self.slots
        self._rng = np.random.default_rng(seed)
        if slot_keys is None:
            slot_keys = np.stack([
                self._rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)
                for _ in range(S)])
        slot_keys = np.asarray(slot_keys, np.uint32)
        if slot_keys.shape != (S, 2):
            raise ValueError(f"slot_keys must be [{S}, 2], "
                             f"got {slot_keys.shape}")
        self.game_capacity = _pow2(max(2, game_capacity or 4 * S))
        self.serve_capacity = _pow2(max(2, serve_capacity or 4 * S))
        self.ring_capacity = _pow2(
            ring_capacity
            or (self.game_capacity + self.serve_capacity + S))
        cap = 2 ** 30 if colour_cap is None else int(colour_cap)

        A = self.engine.num_actions
        bc = lambda n: (lambda x: jnp.broadcast_to(x, (n,) + jnp.shape(x)))
        slots = _Slots(
            states=jax.tree.map(bc(S), self._init_state),
            keys=jnp.asarray(slot_keys),
            ticket=jnp.full((S,), -1, jnp.int32),
            lane=jnp.full((S,), -1, jnp.int32),
            moves=jnp.zeros((S,), jnp.int32),
            sims=jnp.zeros((S,), jnp.int32),
            a_black=jnp.arange(S) < S // 2,
        )

        def queue(n):
            return _Queue(
                states=jax.tree.map(bc(n), self._init_state),
                keys=jnp.zeros((n, 2), jnp.uint32),
                lane=jnp.zeros((n,), jnp.int32),
                sims=jnp.zeros((n,), jnp.int32),
                ticket=jnp.full((n,), -1, jnp.int32),
                size=jnp.int32(0),
                head=jnp.int32(0),
            )

        R = self.ring_capacity
        ring = _Ring(
            ticket=jnp.full((R,), -1, jnp.int32),
            lane=jnp.zeros((R,), jnp.int32),
            action=jnp.zeros((R,), jnp.int32),
            winner=jnp.zeros((R,), jnp.float32),
            moves=jnp.zeros((R,), jnp.int32),
            nodes=jnp.zeros((R,), jnp.int32),
            a_black=jnp.zeros((R,), jnp.bool_),
            visits=jnp.zeros((R, A), jnp.float32),
            count=jnp.int32(0),
        )
        self._pool = PoolState(
            slots=slots, games=queue(self.game_capacity),
            serve=queue(self.serve_capacity), ring=ring,
            colour_count=jnp.zeros((2,), jnp.int32),
            colour_cap=jnp.int32(cap), parity=jnp.int32(0))

        self._pending_games: List[_Pending] = []
        self._pending_serve: List[_Pending] = []
        self._next_ticket = 0
        self._ring_read = 0
        self._submitted = {LANE_ARENA: 0, LANE_SERVE: 0, LANE_TOURNAMENT: 0}
        self._completed = dict(self._submitted)
        self.host_syncs = 0           # host<->device round-trips (flush+poll)

    # ------------------------------------------------------------ submission

    def _draw_key(self, key) -> np.ndarray:
        if key is None:
            return self._rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)
        return np.asarray(key, np.uint32).reshape(2)

    def submit_game(self, key=None, lane: int = LANE_ARENA,
                    sims: int = 0) -> int:
        """Queue one full self-play game (A vs B); returns its ticket.

        Colour is assigned at admission by the slot-pool cell, capped to
        the +-1 balance by ``colour_cap`` — exactly the PR 1 host queue.
        """
        if lane not in GAME_LANES:
            raise ValueError(f"game lane must be one of {GAME_LANES}")
        return self._submit(self._pending_games, self._init_state,
                            key, lane, sims)

    def submit_serve(self, state: GoState, key=None, sims: int = 0) -> int:
        """Queue one external best-move query for ``state``; returns its
        ticket.  The single search always runs under player A's config
        with the request key, so the result is a pure function of
        ``(state, key, sims)``."""
        return self._submit(self._pending_serve, state, key,
                            LANE_SERVE, sims)

    def _submit(self, pending: List[_Pending], state: GoState, key,
                lane: int, sims: int) -> int:
        cap = (self.serve_capacity if lane == LANE_SERVE
               else self.game_capacity)
        in_flight = (self._submitted[lane] - self._completed[lane]
                     if lane == LANE_SERVE else
                     sum(self._submitted[ln] - self._completed[ln]
                         for ln in GAME_LANES))
        if in_flight >= cap:
            raise RuntimeError(
                f"{LANE_NAMES[lane]} queue full ({cap} in flight); poll() "
                "results or reset() with a larger capacity")
        ticket = self._next_ticket
        self._next_ticket += 1
        pending.append(_Pending(state=state, key=self._draw_key(key),
                                lane=lane, sims=int(sims), ticket=ticket))
        self._submitted[lane] += 1
        return ticket

    def flush(self) -> None:
        """Push host-buffered submissions into the device queues."""
        pushed = False
        for pending, push in ((self._pending_games, self._push_games),
                              (self._pending_serve, self._push_serve)):
            while pending:
                rows = pending[:self._chunk]
                del pending[:self._chunk]
                self._pool = push(self._pool, self._pack(rows),
                                  jnp.int32(len(rows)))
                pushed = True
        if pushed:
            self.host_syncs += 1

    def _pack(self, rows: List[_Pending]) -> SearchRequest:
        pad = self._chunk - len(rows)
        states = [r.state for r in rows] + [self._init_state] * pad
        return SearchRequest(
            state=jax.tree.map(lambda *xs: jnp.stack(xs), *states),
            key=jnp.asarray(np.stack(
                [r.key for r in rows]
                + [np.zeros(2, np.uint32)] * pad)),
            lane=jnp.asarray([r.lane for r in rows] + [0] * pad, jnp.int32),
            sims=jnp.asarray([r.sims for r in rows] + [0] * pad, jnp.int32),
            ticket=jnp.asarray([r.ticket for r in rows] + [-1] * pad,
                               jnp.int32),
        )

    # ----------------------------------------------------------- device side

    def _push_games_impl(self, pool: PoolState, req: SearchRequest,
                         n: jax.Array) -> PoolState:
        return pool._replace(games=_queue_push(pool.games, req, n))

    def _push_serve_impl(self, pool: PoolState, req: SearchRequest,
                         n: jax.Array) -> PoolState:
        return pool._replace(serve=_queue_push(pool.serve, req, n))

    def _dispatch_impl(self, pool: PoolState, steps: int) -> PoolState:
        def one(_, p):
            return self._advance(self._admit(p))

        return jax.lax.fori_loop(0, steps, one, pool)

    def _admit(self, pool: PoolState) -> PoolState:
        """Device-side refill: fill empty slots from the pending queues.

        Bit-for-bit the PR 1 host admission loop: slots are scanned in
        index order; a game's forced colour is its (slot-half, parity)
        cell, capped per colour; serve queries go first, only into cells
        player A searches next step.
        """
        S, h = self.slots, self.slots // 2
        sl, gq, sq = pool.slots, pool.games, pool.serve
        Qg, Qs = self.game_capacity, self.serve_capacity
        empty = sl.ticket < 0
        cellA = (jnp.arange(S) < h) == (pool.parity % 2 == 0)

        # serve lane: FIFO into A-searched cells
        elig_s = empty & cellA
        rank_s = _excl_cumsum(elig_s)
        adm_s = elig_s & (rank_s < (sq.size - sq.head))
        pos_s = (sq.head + rank_s) % Qs

        # game lanes: colour-capped FIFO over the remaining empties
        empty_g = empty & ~adm_s
        budget = pool.colour_cap - pool.colour_count          # i32[2]
        rank_c = jnp.where(cellA, _excl_cumsum(empty_g & cellA),
                           _excl_cumsum(empty_g & ~cellA))
        elig_g = empty_g & (rank_c < budget[cellA.astype(jnp.int32)])
        rank_g = _excl_cumsum(elig_g)
        adm_g = elig_g & (rank_g < (gq.size - gq.head))
        pos_g = (gq.head + rank_g) % Qg

        def sel(mask, new, old):
            m = mask.reshape((S,) + (1,) * (old.ndim - 1))
            return jnp.where(m, new, old)

        def merge(cur, sbuf, gbuf):
            return sel(adm_s, sbuf[pos_s], sel(adm_g, gbuf[pos_g], cur))

        refilled = adm_s | adm_g
        slots = _Slots(
            states=jax.tree.map(merge, sl.states, sq.states, gq.states),
            keys=merge(sl.keys, sq.keys, gq.keys),
            ticket=merge(sl.ticket, sq.ticket, gq.ticket),
            lane=merge(sl.lane, sq.lane, gq.lane),
            moves=jnp.where(refilled, 0, sl.moves),
            sims=merge(sl.sims, sq.sims, gq.sims),
            a_black=jnp.where(adm_s, True,
                              jnp.where(adm_g, cellA, sl.a_black)),
        )
        colour_count = pool.colour_count + jnp.stack([
            (adm_g & ~cellA).sum(), (adm_g & cellA).sum()])
        return pool._replace(
            slots=slots,
            games=gq._replace(head=gq.head + adm_g.sum()),
            serve=sq._replace(head=sq.head + adm_s.sum()),
            colour_count=colour_count.astype(jnp.int32))

    def _advance(self, pool: PoolState) -> PoolState:
        """One move in every slot: the parity-balanced half-batch search."""
        S, h = self.slots, self.slots // 2
        sl = pool.slots
        shift = jnp.where(pool.parity % 2 == 0, 0, h)
        idx = (jnp.arange(S, dtype=jnp.int32) + shift) % S    # involution

        st = jax.tree.map(lambda x: x[idx], sl.states)
        keys_p = sl.keys[idx]
        k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys_p)
        new_keys, ka, kb = k3[:, 0], k3[:, 1], k3[:, 2]
        sims_p = sl.sims[idx]
        is_serve = (sl.lane == LANE_SERVE) & (sl.ticket >= 0)
        # serve contract: the query key drives its (single) search directly
        ka = jnp.where(is_serve[idx][:, None], keys_p, ka)

        head = jax.tree.map(lambda x: x[:h], st)
        tail = jax.tree.map(lambda x: x[h:], st)
        res_a = self.player_a.search_batch(head, ka[:h], sims_p[:h])
        res_b = self.player_b.search_batch(tail, kb[h:], sims_p[h:])
        actions = jnp.concatenate([res_a.action, res_b.action])
        nodes = jnp.concatenate([res_a.tree.size, res_b.tree.size])
        visits = jnp.concatenate([res_a.root_visits, res_b.root_visits])

        new_st = jax.vmap(self.engine.play)(st, actions)

        # un-permute with the same involution gather
        new_st = jax.tree.map(lambda x: x[idx], new_st)
        new_keys = new_keys[idx]
        actions = actions[idx]
        nodes = nodes[idx]
        visits = visits[idx]

        live = sl.ticket >= 0
        moves_new = sl.moves + jnp.where(live, 1, 0)
        game_done = live & ~is_serve & (new_st.done
                                        | (moves_new >= self.max_moves))
        finished = is_serve | game_done
        winner = jax.vmap(self.engine.result)(new_st)

        ring = self._append_ring(pool.ring, finished, sl, actions, winner,
                                 moves_new, nodes, visits)
        slots = _Slots(
            states=new_st, keys=new_keys,
            ticket=jnp.where(finished, -1, sl.ticket),
            lane=sl.lane, moves=moves_new, sims=sl.sims,
            a_black=sl.a_black)
        return pool._replace(slots=slots, ring=ring,
                             parity=pool.parity + 1)

    def _append_ring(self, ring: _Ring, finished, sl: _Slots, actions,
                     winner, moves, nodes, visits) -> _Ring:
        R = self.ring_capacity
        off = ring.count + _excl_cumsum(finished)
        widx = jnp.where(finished, off % R, R)                 # R: dropped

        def put(buf, val):
            return buf.at[widx].set(val, mode="drop")

        return ring._replace(
            ticket=put(ring.ticket, sl.ticket),
            lane=put(ring.lane, sl.lane),
            action=put(ring.action, actions),
            winner=put(ring.winner, winner),
            moves=put(ring.moves, moves),
            nodes=put(ring.nodes, nodes),
            a_black=put(ring.a_black, sl.a_black),
            visits=put(ring.visits, visits),
            count=ring.count + finished.sum(),
        )

    # --------------------------------------------------------------- polling

    def dispatch(self, steps: Optional[int] = None) -> None:
        """Run ``steps`` (default ``superstep``) moves without host sync."""
        self._pool = self._dispatch(self._pool, int(steps or self.superstep))

    def poll(self) -> List[SearchResult]:
        """Drain newly finished requests from the result ring.

        Transfers scale with *new* results, not ring capacity: one scalar
        sync reads the append counter, and only when it moved does a
        second sync gather the unread rows (so an idle poll costs one
        scalar round-trip and no ``[R, A]`` visits traffic).
        """
        ring = self._pool.ring
        count = int(jax.device_get(ring.count))
        self.host_syncs += 1
        new = count - self._ring_read
        if new == 0:
            return []
        if new > self.ring_capacity:
            raise RuntimeError(
                f"result ring overflowed ({new} unread > capacity "
                f"{self.ring_capacity}); poll() more often or reset() "
                "with a larger ring_capacity")
        idx = jnp.asarray([i % self.ring_capacity
                           for i in range(self._ring_read, count)])
        ticket, lane, action, winner, moves, nodes, a_black, visits = \
            jax.device_get(jax.tree.map(
                lambda buf: buf[idx],
                (ring.ticket, ring.lane, ring.action, ring.winner,
                 ring.moves, ring.nodes, ring.a_black, ring.visits)))
        self.host_syncs += 1
        out = []
        for j in range(new):
            rec = SearchResult(
                ticket=int(ticket[j]), lane=int(lane[j]),
                action=int(action[j]), winner=float(winner[j]),
                moves=int(moves[j]), tree_nodes=int(nodes[j]),
                a_is_black=bool(a_black[j]),
                root_visits=np.array(visits[j]))
            self._completed[rec.lane] += 1
            out.append(rec)
        self._ring_read = count
        return out

    @property
    def outstanding(self) -> int:
        """Submitted (including still-pending) but not yet completed."""
        return sum(self._submitted.values()) - sum(self._completed.values())

    def drain(self, max_steps: Optional[int] = None) -> List[SearchResult]:
        """Flush, then dispatch+poll until every submission completes."""
        self.flush()
        budget = max_steps or (self.outstanding * (self.max_moves + 2)
                               + 2 * self.slots + 16)
        out: List[SearchResult] = []
        steps = 0
        while self.outstanding > 0:
            if steps > budget:
                raise RuntimeError(
                    f"SearchService.drain stalled: {self.outstanding} "
                    f"requests still outstanding after {steps} steps")
            self.dispatch()
            steps += self.superstep
            out.extend(self.poll())
        return out
