"""Unified SearchService: one batched search dispatcher for every consumer.

The Xeon Phi papers' scaling lesson is that MCTS throughput is set by how
search work is *scheduled* onto the hardware, and the 2015 follow-up shows
a work-queue dispatch model recovering the scaling that thread-per-search
loses.  The jax_pallas analogue is a single admission-controlled batch:
every workload — arena self-play (core/arena.py), external best-move
queries (serving/go_service.py), tournament pairings (core/tournament.py)
— submits :class:`SearchRequest` tickets into one device-resident slot
pool, and one jitted ``dispatch`` step advances all ``S`` slots together:

* **Admission** (the device-side refill): empty slots pull requests from
  device-resident pending queues (a pending counter per queue, no host
  round-trip).  Full-game requests are colour-capped exactly like the PR 1
  host queue (alternating colours, at most +-1 imbalance), so device
  refill is bit-for-bit the host refill; a game may additionally carry a
  **forced colour** (``submit_game(a_black=...)``) and is then admitted
  only into a matching cell — the colour-targeted admission the league's
  per-pairing +-1 ledger (core/league.py) rides on.  Serve requests are
  admitted only into cells that player A searches on the next step, making
  a query's result independent of slot placement and batch-mates.
* **Search**: the parity-balanced roll-by-half from PR 1 — one
  ``player_a.search_batch`` over half the slots, one ``player_b`` over the
  other, exactly one search per move.  The per-slot ``sims`` budget and
  the per-slot, per-side ``(c_uct, vl_weight)`` UCT knobs are *traced*
  arguments (masked loop tail; per-lane scalar broadcast), so mixed
  budgets **and mixed search configurations** share one compiled program
  — the 2015 follow-up's lesson that task-level parallelism scales only
  when differently-configured searches stay resident without re-setup.
* **Scatter**: finished requests (game over, or a serve query's single
  search) are appended to a device-resident result ring buffer; their
  slots empty and refill on the next step's admission.

The host only (a) flushes submitted requests in fixed-size chunks and
(b) polls the ring buffer — both amortised over ``superstep`` dispatch
steps, cutting the per-step host sync of the PR 1 arena loop to
``~2/superstep`` per move (``host_syncs`` counts them;
benchmarks/bench_service.py proves the reduction).

Streaming (``pipeline_depth > 1``): the host<->device boundary is double
buffered.  Every queue and the result ring are functionally updated by
the jitted dispatch, so each issued superstep leaves behind an immutable
*back buffer* of the ring while the device keeps appending to the fresh
*front* buffers; :meth:`SearchService.dispatch_async` captures that back
buffer as a :class:`RingView` completion handle, and the
:class:`~repro.core.streaming.DispatchPipeline` keeps up to
``pipeline_depth`` supersteps in flight, reconciling each view as it
lands.  Because a view's buffers are never touched by later supersteps,
reconciling superstep ``i`` blocks only until *its* computation finishes
(a raw ``device_get`` on the snapshot — an enqueued gather would queue
behind the whole in-flight window), so host-side result processing,
request packing, and placement overlap with device compute —
``host_blocked_s`` measures exactly the time that overlap removes.
Results complete **out of superstep order** across shards and lanes; the
ordering contract is explicit in the pytree types: every
:class:`SearchResult` is identified by its ``ticket`` (never by arrival
position) and stamps ``finished_step``, the device dispatch step that
completed it.  ``pipeline_depth=1`` *is* the synchronous PR 4 path,
bit for bit (pinned in tests/test_pipeline.py).

RNG contract:

* game lanes: a slot splits ``key -> (key, ka, kb)`` once per step like
  ``selfplay.play_game``, so a game with key K is bit-identical to the
  sequential oracle;
* serve lane: the search uses the request key *directly* — a query
  ``(state, key, sims)`` returns exactly
  ``player_a.search_batch(state[None], key[None], sims[None])``.

Sharding (``mesh=``): the pool splits into ``n_shard`` fully independent
sub-pools — each shard owns ``slots / n_shard`` slots plus its *own*
pending queues, result ring, colour counter, and parity — and the jitted
dispatch runs under ``shard_map`` (repro/compat.py) so every shard steps
on its own device with no per-step collective.  A host-side
:class:`~repro.core.placement.PlacementPolicy` decides which shard admits
each submission (the paper's KMP_AFFINITY axis applied to requests), and
an optional once-per-superstep rebalance ``ppermute``\\ s surplus pending
games around the shard ring so one hot shard doesn't become the paper's
32-thread knee.  With one shard the body degenerates to the exact
single-device program, so ``mesh`` over one device is bit-identical to
``mesh=None`` (pinned in tests/test_sharded_service.py).
"""
from __future__ import annotations

import functools
import time
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec

from repro.compat import donate_jit, shard_map
from repro.core.mcts import MCTS, SearchParams
from repro.core.placement import CLS_GAME, CLS_SERVE, PlacementPolicy
from repro.go.board import GoEngine, GoState

# Request lanes, tagged by origin.
LANE_ARENA = 0        # arena self-play slot (full game)
LANE_SERVE = 1        # external best-move query (single search)
LANE_TOURNAMENT = 2   # tournament pairing slot (full game)

GAME_LANES = (LANE_ARENA, LANE_TOURNAMENT)
LANE_NAMES = {LANE_ARENA: "arena", LANE_SERVE: "serve",
              LANE_TOURNAMENT: "tournament"}


class SearchRequest(NamedTuple):
    """One pending request (device pytree; leading axis = queue/chunk).

    ``sims`` / ``c_uct`` / ``vl`` / ``pw`` are **per-side pairs**: column
    0 configures searches run by player A (the serve-lane player), column
    1 those run by player B.  All four are traced through the dispatch —
    a pool multiplexes arbitrarily many (c_uct, virtual_loss, sims,
    prior_weight) configurations with one compiled program.  ``pw`` is
    the evaluation-lane blend weight; it only takes effect on sides
    whose player carries an evaluator (elsewhere that side's scoring
    keeps the static no-eval program).

    ``komi`` (PR 10) is the request's *scoring* komi — one traced f32
    per request (both sides score the same game), defaulting to the
    engine's static value.  It feeds every playout outcome and the
    game-lane winner, so one compiled dispatch serves every komi bucket
    (the unified multi-bucket scheduler contract, core/scheduler.py).
    """
    state: GoState        # root position (games start from the empty board)
    key: jax.Array        # u32[2] request RNG key
    lane: jax.Array       # i32 origin tag (LANE_*)
    sims: jax.Array       # i32[2] playout budget/side; <=0 = configured one
    c_uct: jax.Array      # f32[2] UCT exploration constant per side
    vl: jax.Array         # f32[2] virtual-loss weight per side
    pw: jax.Array         # f32[2] eval-lane prior blend weight per side
    komi: jax.Array       # f32 scoring komi (traced; engine default)
    colour: jax.Array     # i32 forced colour: 1 A=Black, 0 A=White, -1 free
    ticket: jax.Array     # i32 service-assigned id


class SearchResult(NamedTuple):
    """One completed request, scattered back from the ring (host scalars).

    Ordering contract (the streaming-pipeline invariant): results are
    identified by ``ticket``, **never** by arrival position.  With
    ``pipeline_depth > 1`` completions land out of superstep order —
    shards drain independently and a long game outlives the serve
    queries admitted after it — so the only order guarantees are (a)
    FIFO per shard within one poll and (b) ``finished_step`` is the
    device dispatch step (since reset) that completed the request, a
    total order *within* a shard.  Consumers key results by ticket
    (Arena/Tournament/GoService all do).
    """
    ticket: int
    lane: int
    action: int               # move chosen by the final (serve: only) search
    winner: float             # +1 black / -1 white / 0 draw (game lanes)
    moves: int                # moves played (serve: 1)
    tree_nodes: int           # final search's tree size (Fig. 12 metric)
    a_is_black: bool          # game lanes: colour assignment
    root_visits: np.ndarray   # f32[A] final root visit distribution
    finished_step: int = -1   # dispatch step (since reset) of completion


class _Pending(NamedTuple):
    """Host-buffered submission awaiting flush().

    ``deadline`` is host-only metadata (absolute ``time.monotonic``
    seconds, ``None`` = no SLO): it never reaches the device, so carrying
    it cannot retrace the dispatch — late requests are dropped *before*
    they flush (:meth:`SearchService.shed_expired`), and deadline-driven
    budget cuts ride the already-traced ``sims`` columns.
    """
    state: GoState
    key: np.ndarray
    lane: int
    sims: tuple           # (A-side, B-side) playout budgets
    c_uct: tuple          # (A-side, B-side) exploration constants
    vl: tuple             # (A-side, B-side) virtual-loss weights
    pw: tuple             # (A-side, B-side) eval-lane prior blend weights
    komi: float           # scoring komi (engine default unless overridden)
    ticket: int
    shard: int
    deadline: Optional[float] = None
    colour: int = -1      # forced colour: 1 A=Black, 0 A=White, -1 free


class _Slots(NamedTuple):
    """Device-resident slot pool, batched over the S slots."""
    states: GoState       # current position per slot
    keys: jax.Array       # u32[S,2] per-slot RNG chains
    ticket: jax.Array     # i32[S] active request id, -1 = dummy slot
    lane: jax.Array       # i32[S]
    moves: jax.Array      # i32[S] moves played by the active request
    sims: jax.Array       # i32[S,2] per-request playout budget per side
    c_uct: jax.Array      # f32[S,2] per-request c_uct per side (traced)
    vl: jax.Array         # f32[S,2] per-request vl weight per side (traced)
    pw: jax.Array         # f32[S,2] per-request prior blend per side (traced)
    komi: jax.Array       # f32[S] per-request scoring komi (traced)
    a_black: jax.Array    # bool[S] player A owns Black (game lanes)


class _Queue(NamedTuple):
    """Device-resident circular pending queue (capacity Q)."""
    states: GoState
    keys: jax.Array       # u32[Q,2]
    lane: jax.Array       # i32[Q]
    sims: jax.Array       # i32[Q,2]
    c_uct: jax.Array      # f32[Q,2]
    vl: jax.Array         # f32[Q,2]
    pw: jax.Array         # f32[Q,2]
    komi: jax.Array       # f32[Q]
    colour: jax.Array     # i32[Q] forced colour demand (-1 = free)
    ticket: jax.Array     # i32[Q]
    size: jax.Array       # i32: total ever enqueued
    head: jax.Array       # i32: total ever admitted (next to admit)


class _Ring(NamedTuple):
    """Device-resident circular result buffer (capacity R).

    Functionally updated each dispatch step, so a host-held reference to
    a superstep's ring is an immutable back buffer (see
    :class:`RingView`): rows are ticket-tagged and ``step``-stamped so
    completions stay identifiable however far out of superstep order the
    host reads them.
    """
    ticket: jax.Array     # i32[R]
    lane: jax.Array       # i32[R]
    action: jax.Array     # i32[R]
    winner: jax.Array     # f32[R]
    moves: jax.Array      # i32[R]
    nodes: jax.Array      # i32[R]
    a_black: jax.Array    # bool[R]
    visits: jax.Array     # f32[R,A]
    step: jax.Array       # i32[R] dispatch step that completed the row
    count: jax.Array      # i32: total ever appended


class PoolState(NamedTuple):
    """Everything the jitted dispatch step owns (one shard's worth).

    The jit boundary splits this into a donatable *work* half (``ring``
    replaced by ``None``) and the ring: supersteps may reuse the work
    buffers in place on backends with donation, while every ring the
    host snapshotted stays immutable (``compat.donate_jit``).
    """
    slots: _Slots
    games: _Queue         # full-game requests (arena + tournament lanes)
    serve: _Queue         # single-search queries
    ring: Optional[_Ring]     # None inside the jit's donated work half
    colour_count: jax.Array   # i32[2]; index 1 = games where A owns Black
    colour_cap: jax.Array     # i32 per-colour admission budget
    parity: jax.Array         # i32 global move parity (0 => Black to move)
    occ_sum: jax.Array        # i32 sum over steps of occupied slots
    occ_steps: jax.Array      # i32 dispatch steps run (occupancy denominator)
    eval_sum: jax.Array       # i32 sum over steps of live eval-guided slots
    hop_idx: jax.Array        # i32 rebalance hop-schedule cursor


class RingView(NamedTuple):
    """Completion handle for one issued superstep (a ring back buffer).

    ``dispatch_async`` returns the result ring exactly as the issued
    superstep leaves it; later supersteps append to *fresh* buffers, so
    polling this view blocks only until its own superstep finishes.
    ``epoch`` invalidates views across :meth:`SearchService.reset`.
    """
    ring: _Ring
    steps: int            # dispatch steps this superstep ran
    epoch: int            # service reset() generation that issued it


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def pad_slots(slots: int, mesh=None) -> int:
    """Round ``slots`` up so every mesh shard gets an even share >= 2.

    The helper consumers (Tournament, GoService) use to pick a pool size
    that satisfies the SearchService divisibility check for ``mesh``.
    """
    n = 1 if mesh is None else int(np.prod(mesh.devices.shape))
    per = 2 * n
    return max(per, slots + (-slots) % per)


def _excl_cumsum(mask: jax.Array) -> jax.Array:
    m = mask.astype(jnp.int32)
    return jnp.cumsum(m) - m


def _queue_push(q: _Queue, req: SearchRequest, n: jax.Array) -> _Queue:
    """Append the first ``n`` rows of a fixed-size request chunk."""
    chunk = req.lane.shape[0]
    cap = q.lane.shape[0]
    arange = jnp.arange(chunk, dtype=jnp.int32)
    idx = jnp.where(arange < n, (q.size + arange) % cap, cap)  # cap: dropped

    def put(buf, val):
        return buf.at[idx].set(val, mode="drop")

    return q._replace(
        states=jax.tree.map(put, q.states, req.state),
        keys=put(q.keys, req.key),
        lane=put(q.lane, req.lane),
        sims=put(q.sims, req.sims),
        c_uct=put(q.c_uct, req.c_uct),
        vl=put(q.vl, req.vl),
        pw=put(q.pw, req.pw),
        komi=put(q.komi, req.komi),
        colour=put(q.colour, req.colour),
        ticket=put(q.ticket, req.ticket),
        size=q.size + n,
    )


class SearchService:
    """S-slot batched dispatcher bound to an engine and two MCTS players.

    Player A searches the first half-batch at even parity (and, by the
    admission rule, every serve query); games alternate which player owns
    Black under the colour cap.  All static search shapes (lanes, budget
    bound, tree capacity, board) live in the players — one service, one
    compiled dispatch.

    Traced-vs-static contract: ``slots``, ``superstep``, the mesh shape,
    the players' ``MCTSConfig`` shapes, and whether a player carries an
    evaluator are **static** (changing them retraces); every per-request
    knob — ``sims``, ``c_uct``, ``virtual_loss``, ``prior_weight``, each
    an (A-side, B-side) pair — is **traced**, so one pool multiplexes
    arbitrarily many tournament configurations with exactly one compiled
    dispatch (pinned by the compile-count tests in tests/test_multiplex.py
    and tests/test_evaluator.py).  Submitting the players' configured
    values (the default) is bit-identical to the PR 3 static path, and
    ``prior_weight=0`` slots of a guided pool are bit-identical to an
    unguided pool's.

    ``mesh`` (a one-axis device mesh, see ``compat.make_service_mesh``)
    shards the pool: each of the axis's ``n_shard`` devices owns
    ``slots / n_shard`` slots with private queues and ring; ``placement``
    names the host policy routing submissions to shards (core/placement.py)
    and ``rebalance`` enables the once-per-superstep cross-shard ppermute
    of surplus pending games (``multihop`` doubles the ppermute hop
    distance each superstep — 1, 2, 4, ... — so a ``fill_first`` backlog
    drains in O(log shards) supersteps; ``multihop=False`` keeps the PR 3
    one-hop ring).  Capacities passed to :meth:`reset` are *per shard*.

    ``pipeline_depth`` sets how many supersteps the
    :class:`~repro.core.streaming.DispatchPipeline` keeps in flight when
    draining: ``1`` is the synchronous flush -> dispatch -> poll loop
    (bit-identical to the pre-streaming dispatcher, pinned in
    tests/test_pipeline.py); ``K > 1`` overlaps host flush/poll/placement
    with device supersteps.  The depth never changes the compiled
    program — only when the host reads it.
    """

    def __init__(self, engine: GoEngine, player_a: MCTS, player_b: MCTS,
                 slots: int, max_moves: Optional[int] = None,
                 superstep: int = 4, mesh=None,
                 mesh_axis: Optional[str] = None,
                 placement: str = "round_robin", rebalance: bool = True,
                 multihop: bool = True, pipeline_depth: int = 1):
        if mesh is not None:
            axes = tuple(mesh.axis_names)
            if len(axes) != 1:
                raise ValueError(
                    f"service mesh must have exactly one axis, got {axes}; "
                    "build one with repro.compat.make_service_mesh")
            axis = mesh_axis or axes[0]
            if axis not in axes:
                raise ValueError(f"mesh_axis {axis!r} not in {axes}")
            n_shard = mesh.shape[axis]
        else:
            axis, n_shard = None, 1
        if slots < 2 * n_shard or slots % (2 * n_shard):
            raise ValueError(
                f"slots must be an even multiple of the {n_shard} shard(s) "
                f"(each shard needs an even count >= 2), got {slots}")
        if superstep < 1:
            raise ValueError(f"superstep must be >= 1, got {superstep}")
        if pipeline_depth < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1, got {pipeline_depth}")
        self.engine = engine
        self.player_a = player_a
        self.player_b = player_b
        self.slots = slots
        self.max_moves = max_moves or engine.max_moves
        self.superstep = superstep
        self.mesh = mesh
        self.placement = placement
        self.rebalance = rebalance
        self.multihop = multihop
        self.pipeline_depth = int(pipeline_depth)
        self.n_shard = n_shard
        self._axis = axis
        self._shard_slots = slots // n_shard
        # rebalance hop schedule: [1] (PR 3 ring) or doubling 1, 2, 4, ...
        if n_shard > 1 and rebalance:
            if multihop:
                self._hops, h = [], 1
                while h < n_shard:
                    self._hops.append(h)
                    h *= 2
            else:
                self._hops = [1]
        else:
            self._hops = []
        PlacementPolicy(placement, n_shard)      # validate the policy name
        # unified-scheduler hook (core/scheduler.py): maps a request's
        # (komi, class) to the bool[n_shard] shard mask its bucket may
        # occupy; None = every shard (the historical behaviour)
        self._shard_filter = None
        self._chunk = slots               # flush granularity
        self._init_state = engine.init_state()
        self._dispatch = donate_jit(self._dispatch_impl,
                                    donate_argnums=(0,), static_argnums=(2,))
        self._push_games = jax.jit(self._push_games_impl)
        self._push_serve = jax.jit(self._push_serve_impl)
        if mesh is not None:
            self._dispatch_mesh = donate_jit(self._dispatch_mesh_impl,
                                             donate_argnums=(0,),
                                             static_argnums=(2,))
            self._push_games_mesh = jax.jit(functools.partial(
                self._push_mesh_impl, which="games"))
            self._push_serve_mesh = jax.jit(functools.partial(
                self._push_mesh_impl, which="serve"))
        self._epoch = -1
        self.reset()

    # ------------------------------------------------------------- lifecycle

    def reset(self, seed: int = 0, slot_keys: Optional[np.ndarray] = None,
              colour_cap: Optional[int] = None,
              game_capacity: Optional[int] = None,
              serve_capacity: Optional[int] = None,
              ring_capacity: Optional[int] = None) -> None:
        """Re-initialise the pool, queues, ring, and host bookkeeping.

        ``slot_keys`` seeds the per-slot dummy RNG chains (default: drawn
        from ``default_rng(seed)``, the PR 1 host-queue discipline — the
        same generator then feeds keyless submissions, preserving the
        host path's exact key stream).  Capacities are rounded up to
        powers of two so repeat runs reuse the compiled dispatch; under a
        mesh every capacity (and the colour cap) applies *per shard*, and
        shard ``s`` takes the ``s``-th contiguous block of slot keys.
        """
        S = self.slots
        self._rng = np.random.default_rng(seed)
        if slot_keys is None:
            slot_keys = np.stack([
                self._rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)
                for _ in range(S)])
        slot_keys = np.asarray(slot_keys, np.uint32)
        if slot_keys.shape != (S, 2):
            raise ValueError(f"slot_keys must be [{S}, 2], "
                             f"got {slot_keys.shape}")
        self.game_capacity = _pow2(max(2, game_capacity or 4 * S))
        self.serve_capacity = _pow2(max(2, serve_capacity or 4 * S))
        self.ring_capacity = _pow2(
            ring_capacity
            or (self.game_capacity + self.serve_capacity + S))
        # the rebalance writes into queue rows the host never fills, so a
        # rebalancing pool doubles the device-side game queue and reserves
        # the first game_capacity rows' worth of space for host pushes
        self._game_qcap = (2 * self.game_capacity
                           if self.n_shard > 1 and self.rebalance
                           else self.game_capacity)
        cap = 2 ** 30 if colour_cap is None else int(colour_cap)

        Sps = self._shard_slots
        pools = [self._fresh_pool(slot_keys[s * Sps:(s + 1) * Sps], cap)
                 for s in range(self.n_shard)]
        if self.mesh is None:
            self._pool = pools[0]
        else:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *pools)
            self._pool = jax.device_put(
                stacked, NamedSharding(self.mesh, PartitionSpec(self._axis)))

        self._pending_games: List[_Pending] = []
        self._pending_serve: List[_Pending] = []
        self._next_ticket = 0
        self._ring_read = np.zeros(self.n_shard, np.int64)
        self._placement = PlacementPolicy(self.placement, self.n_shard)
        self._assigned = {}           # ticket -> (request class, shard)
        self._submitted = {LANE_ARENA: 0, LANE_SERVE: 0, LANE_TOURNAMENT: 0}
        self._completed = dict(self._submitted)
        self._shed = dict(self._submitted)
        self.host_syncs = 0           # host<->device round-trips (flush+poll)
        self.host_blocked_s = 0.0     # time spent waiting on the device
        self.last_drain_stats = {}    # DispatchPipeline.stats() of last drain
        self._epoch += 1              # invalidates outstanding RingViews

    def _fresh_pool(self, slot_keys: np.ndarray, colour_cap: int) -> PoolState:
        """One shard's empty PoolState (the whole pool when unsharded)."""
        S = self._shard_slots
        A = self.engine.num_actions
        bc = lambda n: (lambda x: jnp.broadcast_to(x, (n,) + jnp.shape(x)))
        # dummy slots still search every step; give them the players'
        # configured knobs so their (discarded) results stay finite
        cfg_cu, cfg_vl, cfg_pw = self._default_params()
        slots = _Slots(
            states=jax.tree.map(bc(S), self._init_state),
            keys=jnp.asarray(slot_keys),
            ticket=jnp.full((S,), -1, jnp.int32),
            lane=jnp.full((S,), -1, jnp.int32),
            moves=jnp.zeros((S,), jnp.int32),
            sims=jnp.zeros((S, 2), jnp.int32),
            c_uct=jnp.broadcast_to(jnp.asarray(cfg_cu, jnp.float32), (S, 2)),
            vl=jnp.broadcast_to(jnp.asarray(cfg_vl, jnp.float32), (S, 2)),
            pw=jnp.broadcast_to(jnp.asarray(cfg_pw, jnp.float32), (S, 2)),
            komi=jnp.full((S,), self.engine.komi, jnp.float32),
            a_black=jnp.arange(S) < S // 2,
        )

        def queue(n):
            return _Queue(
                states=jax.tree.map(bc(n), self._init_state),
                keys=jnp.zeros((n, 2), jnp.uint32),
                lane=jnp.zeros((n,), jnp.int32),
                sims=jnp.zeros((n, 2), jnp.int32),
                c_uct=jnp.zeros((n, 2), jnp.float32),
                vl=jnp.zeros((n, 2), jnp.float32),
                pw=jnp.zeros((n, 2), jnp.float32),
                komi=jnp.full((n,), self.engine.komi, jnp.float32),
                colour=jnp.full((n,), -1, jnp.int32),
                ticket=jnp.full((n,), -1, jnp.int32),
                size=jnp.int32(0),
                head=jnp.int32(0),
            )

        R = self.ring_capacity
        ring = _Ring(
            ticket=jnp.full((R,), -1, jnp.int32),
            lane=jnp.zeros((R,), jnp.int32),
            action=jnp.zeros((R,), jnp.int32),
            winner=jnp.zeros((R,), jnp.float32),
            moves=jnp.zeros((R,), jnp.int32),
            nodes=jnp.zeros((R,), jnp.int32),
            a_black=jnp.zeros((R,), jnp.bool_),
            visits=jnp.zeros((R, A), jnp.float32),
            step=jnp.zeros((R,), jnp.int32),
            count=jnp.int32(0),
        )
        return PoolState(
            slots=slots, games=queue(self._game_qcap),
            serve=queue(self.serve_capacity), ring=ring,
            colour_count=jnp.zeros((2,), jnp.int32),
            colour_cap=jnp.int32(colour_cap), parity=jnp.int32(0),
            occ_sum=jnp.int32(0), occ_steps=jnp.int32(0),
            eval_sum=jnp.int32(0), hop_idx=jnp.int32(0))

    # ------------------------------------------------------------ submission

    def _allowed_shards(self, komi: float, cls: int):
        """Shard-subset mask for one submission (``None`` = every shard).

        The :class:`~repro.core.scheduler.BucketScheduler` installs
        ``_shard_filter`` to enforce per-bucket partitions with headroom
        borrowing; unset, placement sees all shards — bit-identical to
        the pre-bucket service.
        """
        if self._shard_filter is None:
            return None
        return self._shard_filter(komi, cls)

    def _draw_key(self, key) -> np.ndarray:
        if key is None:
            return self._rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)
        return np.asarray(key, np.uint32).reshape(2)

    def _default_params(self):
        """The players' static (c_uct, vl, pw) pairs — per-request defaults.

        The prior-blend default is a player's configured ``prior_weight``
        only when that player carries an evaluator; an unguided player
        defaults to 0 so its slots count (and score) as unguided however
        the config field is set.
        """
        return ((self.player_a.cfg.c_uct, self.player_b.cfg.c_uct),
                (self.player_a.cfg.virtual_loss,
                 self.player_b.cfg.virtual_loss),
                (self.player_a.cfg.prior_weight
                 if self.player_a.evaluator is not None else 0.0,
                 self.player_b.cfg.prior_weight
                 if self.player_b.evaluator is not None else 0.0))

    @staticmethod
    def _pair(value, default, cast):
        """Normalise a per-request knob to an (A-side, B-side) pair."""
        if value is None:
            return (cast(default[0]), cast(default[1]))
        if np.ndim(value) == 0:
            return (cast(value), cast(value))
        a, b = value
        return (cast(a), cast(b))

    def submit_game(self, key=None, lane: int = LANE_ARENA, sims=0,
                    c_uct=None, virtual_loss=None,
                    prior_weight=None, a_black=None, komi=None) -> int:
        """Queue one full self-play game (A vs B); returns its ticket.

        Colour is assigned at admission by the slot-pool cell, capped to
        the +-1 balance by ``colour_cap`` — exactly the PR 1 host queue.
        ``a_black`` overrides that free assignment with a **forced**
        colour (colour-targeted admission): ``True`` admits the game
        only into a cell where player A owns Black, ``False`` only into
        a White cell, and ``None`` keeps the free cell-assigned colour.
        Admission stays strictly FIFO — a forced game whose colour has
        no matching empty cell this step blocks the game queue until the
        parity flips (at most one dispatch step) — and forced colours
        still count against ``colour_cap``, so a submitter forcing more
        games of one colour than the cap allows deadlocks its own queue
        (the league's per-pairing ledger keeps demands inside the cap).

        ``sims`` / ``c_uct`` / ``virtual_loss`` / ``prior_weight``
        configure this game's two searches and are **traced** through
        the dispatch (no recompile across values — the tournament-
        multiplexing contract).  Each accepts a scalar (both sides) or
        an ``(a_side, b_side)`` pair; ``None`` (and ``sims <= 0``) means
        the players' configured values, which is bit-identical to the
        pre-traced path.  ``prior_weight`` is the evaluation-lane blend:
        it only affects sides whose player has an evaluator, and ``0``
        makes that side's search bit-identical to the unguided program.
        ``komi`` overrides the engine's static komi for this game's
        scoring (playout outcomes and the reported winner) — traced, so
        mixed-komi games share the one compiled dispatch.
        """
        if lane not in GAME_LANES:
            raise ValueError(f"game lane must be one of {GAME_LANES}")
        colour = -1 if a_black is None else int(bool(a_black))
        return self._submit(self._pending_games, self._init_state,
                            key, lane, sims, c_uct, virtual_loss,
                            prior_weight, colour=colour, komi=komi)

    def submit_serve(self, state: GoState, key=None, sims=0,
                     c_uct=None, virtual_loss=None, prior_weight=None,
                     deadline: Optional[float] = None, komi=None) -> int:
        """Queue one external best-move query for ``state``; returns its
        ticket.  The single search always runs under player A with the
        request key, so the result is a pure function of
        ``(state, key, sims, c_uct, virtual_loss, prior_weight, komi)``
        — placement- and batch-mate-independent.  ``c_uct`` /
        ``virtual_loss`` / ``prior_weight`` / ``komi`` are traced
        per-query knobs defaulting to player A's config (komi: the
        engine's).

        ``deadline`` (absolute ``time.monotonic`` seconds, ``None`` = no
        SLO) is host-only metadata consumed by :meth:`shed_expired`: a
        query whose deadline passes while it is still host-buffered is
        shed instead of flushed.  It never reaches the device, so it can
        never retrace the dispatch.
        """
        return self._submit(self._pending_serve, state, key,
                            LANE_SERVE, sims, c_uct, virtual_loss,
                            prior_weight, deadline=deadline, komi=komi)

    def _submit(self, pending: List[_Pending], state: GoState, key,
                lane: int, sims, c_uct, virtual_loss, prior_weight=None,
                deadline: Optional[float] = None, colour: int = -1,
                komi=None) -> int:
        cls = CLS_SERVE if lane == LANE_SERVE else CLS_GAME
        cap = (self.serve_capacity if cls == CLS_SERVE
               else self.game_capacity)
        cfg_cu, cfg_vl, cfg_pw = self._default_params()
        sims = self._pair(sims, (0, 0), int)
        cu = self._pair(c_uct, cfg_cu, float)
        vl = self._pair(virtual_loss, cfg_vl, float)
        pw = self._pair(prior_weight, cfg_pw, float)
        km = float(self.engine.komi if komi is None else komi)
        shard = self._placement.choose(cls, cap,
                                       config_key=(sims, cu, vl, pw),
                                       allowed=self._allowed_shards(km, cls))
        if shard is None:
            raise RuntimeError(
                f"{LANE_NAMES[lane]} queue full ({cap} in flight per "
                "shard); poll() results or reset() with a larger capacity")
        ticket = self._next_ticket
        self._next_ticket += 1
        pending.append(_Pending(state=state, key=self._draw_key(key),
                                lane=lane, sims=sims, c_uct=cu, vl=vl,
                                pw=pw, komi=km, ticket=ticket, shard=shard,
                                deadline=deadline, colour=colour))
        self._assigned[ticket] = (cls, shard)
        self._submitted[lane] += 1
        return ticket

    def flush(self) -> None:
        """Push host-buffered submissions into the device queues."""
        pushed = False
        for pending, push, mpush in (
                (self._pending_games, self._push_games,
                 getattr(self, "_push_games_mesh", None)),
                (self._pending_serve, self._push_serve,
                 getattr(self, "_push_serve_mesh", None))):
            while pending:
                rows = pending[:self._chunk]
                del pending[:self._chunk]
                req, shards = self._pack(rows)
                if self.mesh is None:
                    self._pool = push(self._pool, req, jnp.int32(len(rows)))
                else:
                    self._pool = mpush(self._pool, req, shards)
                pushed = True
        if pushed:
            self.host_syncs += 1

    def _pack(self, rows: List[_Pending]):
        pad = self._chunk - len(rows)
        states = [r.state for r in rows] + [self._init_state] * pad
        req = SearchRequest(
            state=jax.tree.map(lambda *xs: jnp.stack(xs), *states),
            key=jnp.asarray(np.stack(
                [r.key for r in rows]
                + [np.zeros(2, np.uint32)] * pad)),
            lane=jnp.asarray([r.lane for r in rows] + [0] * pad, jnp.int32),
            sims=jnp.asarray([r.sims for r in rows] + [(0, 0)] * pad,
                             jnp.int32),
            c_uct=jnp.asarray([r.c_uct for r in rows] + [(0., 0.)] * pad,
                              jnp.float32),
            vl=jnp.asarray([r.vl for r in rows] + [(0., 0.)] * pad,
                           jnp.float32),
            pw=jnp.asarray([r.pw for r in rows] + [(0., 0.)] * pad,
                           jnp.float32),
            komi=jnp.asarray([r.komi for r in rows]
                             + [self.engine.komi] * pad, jnp.float32),
            colour=jnp.asarray([r.colour for r in rows] + [-1] * pad,
                               jnp.int32),
            ticket=jnp.asarray([r.ticket for r in rows] + [-1] * pad,
                               jnp.int32),
        )
        shards = jnp.asarray([r.shard for r in rows] + [-1] * pad,
                             jnp.int32)
        return req, shards

    # ----------------------------------------------------------- device side

    def _push_games_impl(self, pool: PoolState, req: SearchRequest,
                         n: jax.Array) -> PoolState:
        return pool._replace(games=_queue_push(pool.games, req, n))

    def _push_serve_impl(self, pool: PoolState, req: SearchRequest,
                         n: jax.Array) -> PoolState:
        return pool._replace(serve=_queue_push(pool.serve, req, n))

    def _dispatch_impl(self, work: PoolState, ring: _Ring, steps: int):
        """``steps`` supersteps over one shard's pool.

        The jit boundary splits the pool into the donatable *work* half
        (``work.ring is None``) and the result ring: work buffers may be
        reused in place across calls (``compat.donate_jit``), while every
        ring is a fresh output so host-held :class:`RingView` snapshots
        stay valid however many supersteps run after them.
        """
        def one(_, p):
            return self._advance(self._admit(p))

        pool = jax.lax.fori_loop(0, steps, one, work._replace(ring=ring))
        return pool._replace(ring=None), pool.ring

    def _dispatch_mesh_impl(self, work: PoolState, ring: _Ring, steps: int):
        """The sharded dispatch: every device steps its own sub-pool.

        Each shard's PoolState rides the mesh axis (leading axis of every
        leaf); the body peels it off and runs the *same* per-shard program
        as the single-device dispatch, so one shard is bit-identical to
        ``mesh=None``.  The rebalance (the only cross-shard traffic) runs
        once per dispatch call, before the superstep's moves.
        """
        spec = PartitionSpec(self._axis)

        def body(w, r):
            local = jax.tree.map(lambda x: x[0], w._replace(ring=r))
            if self._hops:
                local = self._rebalance_impl(local)
            out_w, out_r = self._dispatch_impl(
                local._replace(ring=None), local.ring, steps)
            out = jax.tree.map(lambda x: x[None],
                               out_w._replace(ring=out_r))
            return out._replace(ring=None), out.ring

        return shard_map(body, mesh=self.mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec), check_vma=False)(work, ring)

    def _push_mesh_impl(self, pool: PoolState, req: SearchRequest,
                        shards: jax.Array, *, which: str) -> PoolState:
        """Broadcast one flush chunk; each shard keeps its own rows.

        The chunk is replicated to every device; a shard stably compacts
        the rows placed on it to the front and appends only those, so
        per-shard FIFO order is submission order (and with one shard the
        result is bit-identical to the unsharded push).
        """
        spec = PartitionSpec(self._axis)

        def body(p, req, shards):
            local = jax.tree.map(lambda x: x[0], p)
            me = lax.axis_index(self._axis)
            mine = (shards == me) & (req.ticket >= 0)
            order = jnp.argsort(jnp.where(mine, 0, 1), stable=True)
            req_s = jax.tree.map(lambda x: x[order], req)
            q = _queue_push(getattr(local, which), req_s,
                            mine.sum().astype(jnp.int32))
            local = local._replace(**{which: q})
            return jax.tree.map(lambda x: x[None], local)

        return shard_map(
            body, mesh=self.mesh,
            in_specs=(spec, PartitionSpec(), PartitionSpec()),
            out_specs=spec, check_vma=False)(pool, req, shards)

    def _rebalance_impl(self, pool: PoolState) -> PoolState:
        """Rebalance surplus pending games along the shard ring.

        Runs inside the shard_map body, once per dispatch call.  The hop
        distance follows the schedule in ``self._hops``: ``[1]`` is the
        PR 3 one-hop ring; with ``multihop`` the distance doubles every
        superstep (1, 2, 4, ...), so a ``fill_first`` backlog reaches
        every shard in O(log shards) supersteps instead of one ring
        position per superstep — the 2015 follow-up's work-distribution
        fix applied to the donation topology.  Each schedule entry is a
        *static* permutation (``ppermute`` needs one), selected by the
        traced ``hop_idx`` cursor via ``lax.switch``.
        """
        hops = self._hops
        if len(hops) == 1:
            out = self._rebalance_hop(pool, hops[0])
        else:
            out = lax.switch(
                pool.hop_idx % len(hops),
                [functools.partial(self._rebalance_hop, hop=h)
                 for h in hops],
                pool)
        return out._replace(hop_idx=pool.hop_idx + 1)

    def _rebalance_hop(self, pool: PoolState, hop: int) -> PoolState:
        """One donation round at a fixed hop distance.

        Shard ``i`` donates up to ``slots/n_shard`` of its most recent
        pending games to shard ``i+hop`` when its backlog exceeds that
        shard's — two scalar ``ppermute``\\ s (backlog + headroom) decide
        the count, one chunk ``ppermute`` moves the requests.  Donations
        are capped by the receiver's rebalance headroom (queue rows
        beyond the host's ``game_capacity`` share), so a host flush can
        never overflow a queue the rebalance topped up.
        """
        n = self.n_shard
        gq = pool.games
        Qg = gq.lane.shape[0]
        K = self._shard_slots
        from_next = [((i + hop) % n, i) for i in range(n)]
        to_next = [(i, (i + hop) % n) for i in range(n)]

        backlog = gq.size - gq.head
        headroom = (Qg - self.game_capacity) - backlog
        nxt_backlog = lax.ppermute(backlog, self._axis, from_next)
        nxt_headroom = lax.ppermute(headroom, self._axis, from_next)
        d = jnp.clip((backlog - nxt_backlog) // 2, 0, K)
        d = jnp.minimum(d, jnp.maximum(nxt_headroom, 0))

        # pop the d most recently queued requests (rows size-d .. size-1)
        idx = (gq.size - d + jnp.arange(K, dtype=jnp.int32)) % Qg
        chunk = SearchRequest(
            state=jax.tree.map(lambda x: x[idx], gq.states),
            key=gq.keys[idx], lane=gq.lane[idx], sims=gq.sims[idx],
            c_uct=gq.c_uct[idx], vl=gq.vl[idx], pw=gq.pw[idx],
            komi=gq.komi[idx], colour=gq.colour[idx],
            ticket=gq.ticket[idx])
        got = jax.tree.map(lambda x: lax.ppermute(x, self._axis, to_next),
                           chunk)
        got_n = lax.ppermute(d, self._axis, to_next)
        games = _queue_push(gq._replace(size=gq.size - d), got, got_n)
        return pool._replace(games=games)

    def _admit(self, pool: PoolState) -> PoolState:
        """Device-side refill: fill empty slots from the pending queues.

        Bit-for-bit the PR 1 host admission loop: slots are scanned in
        index order; a game's forced colour is its (slot-half, parity)
        cell, capped per colour; serve queries go first, only into cells
        player A searches next step.
        """
        sl, gq, sq = pool.slots, pool.games, pool.serve
        S = sl.ticket.shape[0]
        h = S // 2
        Qg, Qs = gq.lane.shape[0], sq.lane.shape[0]
        empty = sl.ticket < 0
        cellA = (jnp.arange(S) < h) == (pool.parity % 2 == 0)

        # serve lane: FIFO into A-searched cells
        elig_s = empty & cellA
        rank_s = _excl_cumsum(elig_s)
        adm_s = elig_s & (rank_s < (sq.size - sq.head))
        pos_s = (sq.head + rank_s) % Qs

        # game lanes: colour-capped FIFO over the remaining empties,
        # honouring per-request forced colours (colour-targeted
        # admission).  A sequential greedy walks the queue in FIFO
        # order: entry k takes the first remaining eligible cell whose
        # colour matches its demand (a free demand takes any cell), and
        # an unmatchable entry blocks the rest of the queue — strict
        # FIFO, never reordering.  With no forced colours this is the
        # rank mapping (entry k -> the k-th eligible cell) exactly, so
        # free pools admit bit-identically to the pre-colour dispatch.
        empty_g = empty & ~adm_s
        budget = pool.colour_cap - pool.colour_count          # i32[2]
        rank_c = jnp.where(cellA, _excl_cumsum(empty_g & cellA),
                           _excl_cumsum(empty_g & ~cellA))
        elig_g = empty_g & (rank_c < budget[cellA.astype(jnp.int32)])
        backlog_g = gq.size - gq.head

        def admit_one(k, carry):
            taken, assign, blocked = carry
            demand = gq.colour[(gq.head + k) % Qg]
            cand = elig_g & ~taken & ((demand < 0)
                                      | (cellA == (demand > 0)))
            cell = jnp.argmax(cand)
            want = (k < backlog_g) & ~blocked
            take = want & cand.any()
            taken = taken.at[cell].set(taken[cell] | take)
            assign = assign.at[cell].set(jnp.where(take, k, assign[cell]))
            return taken, assign, blocked | (want & ~take)

        _, assign, _ = lax.fori_loop(
            0, S, admit_one,
            (jnp.zeros((S,), jnp.bool_), jnp.full((S,), -1, jnp.int32),
             jnp.bool_(False)))
        adm_g = assign >= 0
        pos_g = (gq.head + jnp.maximum(assign, 0)) % Qg

        def sel(mask, new, old):
            m = mask.reshape((S,) + (1,) * (old.ndim - 1))
            return jnp.where(m, new, old)

        def merge(cur, sbuf, gbuf):
            return sel(adm_s, sbuf[pos_s], sel(adm_g, gbuf[pos_g], cur))

        refilled = adm_s | adm_g
        slots = _Slots(
            states=jax.tree.map(merge, sl.states, sq.states, gq.states),
            keys=merge(sl.keys, sq.keys, gq.keys),
            ticket=merge(sl.ticket, sq.ticket, gq.ticket),
            lane=merge(sl.lane, sq.lane, gq.lane),
            moves=jnp.where(refilled, 0, sl.moves),
            sims=merge(sl.sims, sq.sims, gq.sims),
            c_uct=merge(sl.c_uct, sq.c_uct, gq.c_uct),
            vl=merge(sl.vl, sq.vl, gq.vl),
            pw=merge(sl.pw, sq.pw, gq.pw),
            komi=merge(sl.komi, sq.komi, gq.komi),
            a_black=jnp.where(adm_s, True,
                              jnp.where(adm_g, cellA, sl.a_black)),
        )
        colour_count = pool.colour_count + jnp.stack([
            (adm_g & ~cellA).sum(), (adm_g & cellA).sum()])
        return pool._replace(
            slots=slots,
            games=gq._replace(head=gq.head + adm_g.sum()),
            serve=sq._replace(head=sq.head + adm_s.sum()),
            colour_count=colour_count.astype(jnp.int32))

    def _advance(self, pool: PoolState) -> PoolState:
        """One move in every slot: the parity-balanced half-batch search.

        After the involution gather the head half is always the slots
        player A moves in, so A's search reads the requests' side-0
        (sims, c_uct, vl, pw) columns and B's the side-1 columns — the
        traced per-slot knobs that let one compiled dispatch host mixed
        configs.  A side's ``pw`` column reaches its search only when
        that player carries an evaluator (a static Python check: the
        guided and unguided players compile different scoring programs,
        but within a guided player the blend weight — and so any
        guided/unguided slot mix — is pure data).
        """
        sl = pool.slots
        S = sl.ticket.shape[0]
        h = S // 2
        shift = jnp.where(pool.parity % 2 == 0, 0, h)
        idx = (jnp.arange(S, dtype=jnp.int32) + shift) % S    # involution

        st = jax.tree.map(lambda x: x[idx], sl.states)
        keys_p = sl.keys[idx]
        k3 = jax.vmap(lambda k: jax.random.split(k, 3))(keys_p)
        new_keys, ka, kb = k3[:, 0], k3[:, 1], k3[:, 2]
        sims_p = sl.sims[idx]
        cu_p = sl.c_uct[idx]
        vl_p = sl.vl[idx]
        pw_p = sl.pw[idx]
        km_p = sl.komi[idx]
        is_serve = (sl.lane == LANE_SERVE) & (sl.ticket >= 0)
        # serve contract: the query key drives its (single) search directly
        ka = jnp.where(is_serve[idx][:, None], keys_p, ka)

        a_eval = self.player_a.evaluator is not None
        b_eval = self.player_b.evaluator is not None
        head = jax.tree.map(lambda x: x[:h], st)
        tail = jax.tree.map(lambda x: x[h:], st)
        res_a = self.player_a.search_batch(
            head, ka[:h], sims_p[:h, 0],
            params=SearchParams(cu_p[:h, 0], vl_p[:h, 0],
                                pw_p[:h, 0] if a_eval else None,
                                km_p[:h]))
        res_b = self.player_b.search_batch(
            tail, kb[h:], sims_p[h:, 1],
            params=SearchParams(cu_p[h:, 1], vl_p[h:, 1],
                                pw_p[h:, 1] if b_eval else None,
                                km_p[h:]))
        actions = jnp.concatenate([res_a.action, res_b.action])
        nodes = jnp.concatenate([res_a.tree.size, res_b.tree.size])
        visits = jnp.concatenate([res_a.root_visits, res_b.root_visits])

        new_st = jax.vmap(self.engine.play)(st, actions)

        # un-permute with the same involution gather
        new_st = jax.tree.map(lambda x: x[idx], new_st)
        new_keys = new_keys[idx]
        actions = actions[idx]
        nodes = nodes[idx]
        visits = visits[idx]

        live = sl.ticket >= 0
        moves_new = sl.moves + jnp.where(live, 1, 0)
        game_done = live & ~is_serve & (new_st.done
                                        | (moves_new >= self.max_moves))
        finished = is_serve | game_done
        winner = jax.vmap(self.engine.result)(new_st, sl.komi)

        # eval-batch occupancy: live slots whose *searching* side this
        # step was guided (pw > 0 under a player with an evaluator) —
        # the useful fraction of the superstep's net-forward rows
        live_p = live[idx]
        guided_a = (live_p[:h] & (pw_p[:h, 0] > 0)) if a_eval \
            else jnp.zeros((h,), jnp.bool_)
        guided_b = (live_p[h:] & (pw_p[h:, 1] > 0)) if b_eval \
            else jnp.zeros((h,), jnp.bool_)

        ring = self._append_ring(pool.ring, finished, sl, actions, winner,
                                 moves_new, nodes, visits, pool.occ_steps)
        slots = _Slots(
            states=new_st, keys=new_keys,
            ticket=jnp.where(finished, -1, sl.ticket),
            lane=sl.lane, moves=moves_new, sims=sl.sims,
            c_uct=sl.c_uct, vl=sl.vl, pw=sl.pw, komi=sl.komi,
            a_black=sl.a_black)
        return pool._replace(slots=slots, ring=ring,
                             parity=pool.parity + 1,
                             occ_sum=pool.occ_sum + live.sum(),
                             occ_steps=pool.occ_steps + 1,
                             eval_sum=(pool.eval_sum + guided_a.sum()
                                       + guided_b.sum()))

    def _append_ring(self, ring: _Ring, finished, sl: _Slots, actions,
                     winner, moves, nodes, visits, step) -> _Ring:
        R = ring.ticket.shape[0]
        off = ring.count + _excl_cumsum(finished)
        widx = jnp.where(finished, off % R, R)                 # R: dropped

        def put(buf, val):
            return buf.at[widx].set(val, mode="drop")

        return ring._replace(
            ticket=put(ring.ticket, sl.ticket),
            lane=put(ring.lane, sl.lane),
            action=put(ring.action, actions),
            winner=put(ring.winner, winner),
            moves=put(ring.moves, moves),
            nodes=put(ring.nodes, nodes),
            a_black=put(ring.a_black, sl.a_black),
            visits=put(ring.visits, visits),
            step=put(ring.step, jnp.full_like(sl.ticket, step)),
            count=ring.count + finished.sum(),
        )

    # --------------------------------------------------------------- polling

    def _get(self, x):
        """Blocking device fetch, accounted in ``host_blocked_s``."""
        t0 = time.perf_counter()
        out = jax.device_get(x)
        self.host_blocked_s += time.perf_counter() - t0
        return out

    @property
    def epoch(self) -> int:
        """reset() generation counter — stamps and invalidates RingViews."""
        return self._epoch

    def dispatch(self, steps: Optional[int] = None) -> None:
        """Run ``steps`` (default ``superstep``) moves without host sync."""
        fn = self._dispatch if self.mesh is None else self._dispatch_mesh
        work, ring = fn(self._pool._replace(ring=None), self._pool.ring,
                        int(steps or self.superstep))
        self._pool = work._replace(ring=ring)

    def dispatch_async(self, steps: Optional[int] = None) -> RingView:
        """Issue one superstep and return its completion handle.

        The dispatch itself never blocks (JAX async dispatch); the
        returned :class:`RingView` snapshots the ring as this superstep
        leaves it, so ``poll(view=...)`` later blocks only until *this*
        superstep's computation lands — the double-buffered read side of
        the streaming pipeline.
        """
        steps = int(steps or self.superstep)
        self.dispatch(steps)
        return RingView(ring=self._pool.ring, steps=steps,
                        epoch=self._epoch)

    _RING_FIELDS = ("ticket", "lane", "action", "winner", "moves", "nodes",
                    "a_black", "visits", "step")

    def poll(self, view: Optional[RingView] = None) -> List[SearchResult]:
        """Drain newly finished requests from the result rings.

        Without ``view`` (the synchronous path) transfers scale with
        *new* results, not ring capacity: one sync reads the append
        counter(s), and only when one moved does a second sync gather
        the unread rows of *every* shard in one ``device_get`` (so an
        idle poll costs one scalar round-trip, no ``[R, A]`` visits
        traffic, and ``host_syncs`` stays an honest count of blocking
        transfers).

        With ``view`` (a :meth:`dispatch_async` handle) the unread rows
        come from that superstep's snapshot via a *raw* transfer of the
        ring buffers, sliced host-side: enqueueing a device gather on
        the snapshot would queue behind every in-flight superstep and
        re-serialise the pipeline, whereas the raw fetch waits only for
        the snapshot's own producer.  Shard rings drain in shard order,
        FIFO within each; across polls only the ticket identifies a
        result (see :class:`SearchResult`).
        """
        if view is not None and view.epoch != self._epoch:
            raise RuntimeError(
                "stale RingView: the service was reset() after this "
                "superstep was issued")
        ring = self._pool.ring if view is None else view.ring
        counts = np.atleast_1d(np.asarray(self._get(ring.count)))
        self.host_syncs += 1
        news = {}
        for s in range(self.n_shard):
            new = int(counts[s]) - int(self._ring_read[s])
            if new <= 0:
                continue        # <0: an out-of-order view, already drained
            if new > self.ring_capacity:
                raise RuntimeError(
                    f"result ring overflowed ({new} unread > capacity "
                    f"{self.ring_capacity}); poll() more often or reset() "
                    "with a larger ring_capacity")
            news[s] = new
        if not news:
            return []
        bufs = tuple(getattr(ring, f) for f in self._RING_FIELDS)
        rows = {}
        if view is None:
            gathers = {}
            for s in news:
                sb = bufs if self.mesh is None \
                    else jax.tree.map(lambda buf: buf[s], bufs)
                idx = jnp.asarray(
                    [i % self.ring_capacity
                     for i in range(int(self._ring_read[s]), int(counts[s]))])
                gathers[s] = jax.tree.map(lambda buf: buf[idx], sb)
            rows = self._get(gathers)           # one blocking transfer
        else:
            whole = self._get(bufs)             # raw back-buffer read
            for s in news:
                sb = whole if self.mesh is None \
                    else tuple(b[s] for b in whole)
                idx = np.asarray(
                    [i % self.ring_capacity
                     for i in range(int(self._ring_read[s]), int(counts[s]))])
                rows[s] = tuple(np.asarray(b)[idx] for b in sb)
        self.host_syncs += 1
        out: List[SearchResult] = []
        for s in sorted(rows):
            ticket, lane, action, winner, moves, nodes, a_black, visits, \
                step = rows[s]
            for j in range(news[s]):
                rec = SearchResult(
                    ticket=int(ticket[j]), lane=int(lane[j]),
                    action=int(action[j]), winner=float(winner[j]),
                    moves=int(moves[j]), tree_nodes=int(nodes[j]),
                    a_is_black=bool(a_black[j]),
                    root_visits=np.array(visits[j]),
                    finished_step=int(step[j]))
                self._completed[rec.lane] += 1
                cls, assigned = self._assigned.pop(rec.ticket)
                self._placement.release(cls, assigned)
                out.append(rec)
            self._ring_read[s] = counts[s]
        return out

    def peek_landed(self) -> bool:
        """Non-blocking refresh of the placement occupancy estimate.

        When the newest superstep's ring is already materialised, feed
        the per-(class, shard) completed-but-unpolled counts to the
        placement policy as its *landed* estimate — unpolled ring rows
        are classified by looking their tickets up in the host's
        assignment map — so submissions placed between reconciles see
        estimated in-flight occupancy rather than the stale polled
        truth.  Returns whether the estimate was refreshed.

        Requires a real ``jax.Array.is_ready``: on JAX builds without it
        the peek is skipped entirely — the conservative direction *here*
        (a blocking read every pump would re-serialise the pipeline;
        ``compat.array_is_ready``'s ``True`` fallback suits callers who
        were about to block anyway, not this one).  Estimates depend on
        device timing, so in streaming workloads placement (and
        therefore game colouring) may vary run to run — the synchronous
        path stays deterministic (see core/placement.py).
        """
        ring = self._pool.ring
        is_ready = getattr(ring.count, "is_ready", None)
        if is_ready is None or not is_ready():
            return False
        # outputs of one executable materialise together: count ready
        # means the ticket column is (at worst trivially) ready too
        counts = np.atleast_1d(np.asarray(jax.device_get(ring.count)))
        tickets = np.asarray(jax.device_get(ring.ticket))
        if self.mesh is None:
            tickets = tickets[None]
        landed = np.zeros((2, self.n_shard), np.int64)
        R = self.ring_capacity
        for s in range(self.n_shard):
            # clamp to the last R rows: older unread rows are already
            # lost to wrap-around (poll() will raise overflow for them)
            start = max(int(self._ring_read[s]), int(counts[s]) - R)
            for i in range(start, int(counts[s])):
                assigned = self._assigned.get(int(tickets[s, i % R]))
                if assigned is not None:
                    landed[assigned[0], s] += 1
        self._placement.note_landed(landed)
        return True

    def shard_occupancy(self) -> np.ndarray:
        """Mean fraction of occupied slots per shard since reset().

        A diagnostic read (one device transfer, not counted in
        ``host_syncs``): ``occ_sum / (occ_steps * slots_per_shard)`` —
        the benchmark's per-shard utilisation column, and the sharded
        analogue of the paper's core-utilisation regions.
        """
        occ, steps = jax.device_get((self._pool.occ_sum,
                                     self._pool.occ_steps))
        occ = np.atleast_1d(np.asarray(occ)).astype(np.float64)
        steps = np.atleast_1d(np.asarray(steps)).astype(np.float64)
        return occ / np.maximum(steps * self._shard_slots, 1.0)

    def eval_occupancy(self) -> np.ndarray:
        """Mean fraction of slots doing *guided* search per shard.

        The evaluation-lane analogue of :meth:`shard_occupancy`: of all
        slot-steps since reset(), the fraction whose searching side was
        live and eval-guided (``prior_weight > 0`` under a player with
        an evaluator).  Because every slot's search contributes a fixed
        ``lanes``-row stripe to the superstep's net-forward batch, this
        is exactly the useful fraction of eval-batch rows — the
        benchmark's occupancy column (benchmarks/bench_eval.py gates on
        it staying >= 0.5 at the default pool size).
        """
        ev, steps = jax.device_get((self._pool.eval_sum,
                                    self._pool.occ_steps))
        ev = np.atleast_1d(np.asarray(ev)).astype(np.float64)
        steps = np.atleast_1d(np.asarray(steps)).astype(np.float64)
        return ev / np.maximum(steps * self._shard_slots, 1.0)

    def shed_expired(self, now: Optional[float] = None) -> List[int]:
        """Drop expired host-pending serve requests before they flush.

        The load-shedding half of the serving tier's deadline contract:
        a query whose ``deadline`` (set at :meth:`submit_serve`) has
        passed is removed from the host buffer, its placement slot is
        released, and its ticket is returned — it never reaches the
        device, so a shed request costs zero device work.  Requests
        already flushed to the device queues are past the point of no
        return and always complete (the front door records those as
        deadline *misses*, not sheds).  Shed tickets count into the
        accounting identity ``submitted == completed + in_flight +
        shed`` (see :meth:`accounting`); tests/test_server.py pins the
        pool staying consistent across the shed path.
        """
        now = time.monotonic() if now is None else now
        shed: List[int] = []
        keep: List[_Pending] = []
        for p in self._pending_serve:
            if p.deadline is not None and now >= p.deadline:
                cls, shard = self._assigned.pop(p.ticket)
                self._placement.release(cls, shard)
                self._shed[p.lane] += 1
                shed.append(p.ticket)
            else:
                keep.append(p)
        self._pending_serve[:] = keep
        return shed

    @property
    def shed_total(self) -> int:
        """Requests explicitly shed (never dispatched) since reset()."""
        return sum(self._shed.values())

    @property
    def outstanding(self) -> int:
        """Submitted (including still-pending) but neither completed
        nor shed."""
        return (sum(self._submitted.values())
                - sum(self._completed.values())
                - sum(self._shed.values()))

    def accounting(self) -> tuple:
        """``(submitted, completed, in_flight)`` request totals.

        ``in_flight`` counts tickets between submission and poll (host
        pending + device queued/active + landed-but-unpolled); shed
        requests (see :meth:`shed_expired`) leave ``in_flight``
        immediately, so the full identity is ``submitted == completed +
        in_flight + shed_total`` — the pipeline asserts it at every
        reconcile (tests/test_pipeline.py and tests/test_server.py pin
        it).
        """
        return (sum(self._submitted.values()),
                sum(self._completed.values()),
                len(self._assigned))

    def drain(self, max_steps: Optional[int] = None) -> List[SearchResult]:
        """Flush, then dispatch+poll until every submission completes.

        Runs through a :class:`~repro.core.streaming.DispatchPipeline`
        at this service's ``pipeline_depth``: depth 1 reproduces the
        lock-step flush -> dispatch -> poll loop exactly; deeper
        pipelines keep that many supersteps in flight and overlap the
        host I/O with device compute.  The pipeline's counters land in
        ``last_drain_stats``.
        """
        from repro.core.streaming import DispatchPipeline
        pipe = DispatchPipeline(self)
        out = pipe.run_until_drained(max_steps)
        self.last_drain_stats = pipe.stats()
        return out
