"""Match statistics — the paper's Heinz-2001 confidence machinery.

The paper: "a statistical method based on [Heinz 2001] is used to calculate
95%-level confidence lower and upper bounds on the real winning rate", with
two draws counted as one loss plus one win (i.e. a draw scores 1/2).
"""
from __future__ import annotations

import math
from typing import NamedTuple


class WinRate(NamedTuple):
    games: int
    score: float        # wins + draws/2
    rate: float         # score / games
    lo: float           # 95% CI lower bound
    hi: float           # 95% CI upper bound

    def __str__(self) -> str:
        return (f"{self.rate * 100:5.1f}% [{self.lo * 100:5.1f}, "
                f"{self.hi * 100:5.1f}] over {self.games} games")


Z95 = 1.96
Z90 = 1.645


def win_rate(wins: int, losses: int, draws: int = 0, z: float = Z95) -> WinRate:
    """Paper's estimator: w = x/n with the normal-approximation interval
    ``w ± z * sqrt(w(1-w)/n)``; draws count as half a win."""
    n = wins + losses + draws
    if n == 0:
        return WinRate(0, 0.0, 0.5, 0.0, 1.0)
    w = (wins + 0.5 * draws) / n
    half = z * math.sqrt(max(w * (1.0 - w), 0.0) / n)
    return WinRate(n, wins + 0.5 * draws, w,
                   max(0.0, w - half), min(1.0, w + half))


def games_for_margin(margin: float, p: float = 0.5, z: float = Z95) -> int:
    """How many games to shrink the CI half-width below ``margin``."""
    return int(math.ceil(z * z * p * (1 - p) / (margin * margin)))
