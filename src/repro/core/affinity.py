"""Lane-to-device placement policies — the KMP_AFFINITY analogue.

The paper tunes ``KMP_AFFINITY in {compact, balanced, scatter}`` and finds
FUEGO's strength is sensitive to it (Fig. 9): *compact* fills each core's 4
SMT slots before using the next core (maximising cache sharing, leaving cores
idle), *scatter* round-robins threads across cores (maximising core
utilisation, thrashing shared caches), *balanced* blocks threads evenly.

The TPU analogue assigns MCTS work units (root-parallel trees or playout
lanes) to mesh devices.  The policy changes (a) how many devices are busy and
(b) which collectives the lowered program needs — the structural quantities
we measure in lieu of cache traffic.
"""
from __future__ import annotations

import numpy as np

POLICIES = ("compact", "balanced", "scatter")


def lane_to_device(policy: str, lanes: int, devices: int,
                   slots_per_device: int = 4) -> np.ndarray:
    """Device index for each lane under a policy.

    ``slots_per_device`` mirrors the Phi's 4 SMT threads/core: *compact*
    saturates a device before moving on, *scatter* round-robins, *balanced*
    splits lanes into equal contiguous blocks across all devices.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown affinity {policy!r}; want {POLICIES}")
    idx = np.arange(lanes)
    if policy == "compact":
        dev = idx // slots_per_device
        return np.minimum(dev, devices - 1)
    if policy == "scatter":
        return idx % devices
    # balanced: ceil-even contiguous blocks over all devices
    per = -(-lanes // devices)
    return idx // per


def device_load(assignment: np.ndarray, devices: int) -> np.ndarray:
    """Lanes per device — the utilisation profile the paper plots regions of."""
    return np.bincount(assignment, minlength=devices)


def utilisation(assignment: np.ndarray, devices: int) -> float:
    """Fraction of devices with work — 'core utilisation' analogue."""
    return float((device_load(assignment, devices) > 0).mean())


def imbalance(assignment: np.ndarray, devices: int) -> float:
    """max/mean load over busy devices — the paper's asymmetric-region
    (2-vs-3 threads/core) degradation shows up as imbalance > 1."""
    load = device_load(assignment, devices)
    busy = load[load > 0]
    if busy.size == 0:
        return 0.0
    return float(busy.max() / busy.mean())
