"""Batched self-play arena: a thin client of the SearchService dispatcher.

The seed harness (``selfplay.play_game``) ran **both** players' full MCTS
searches every move and discarded the non-mover's — half the compute wasted
— and vmapped whole games, so one long game stalled its entire batch.  PR 1
restructured the work loop (the Xeon Phi papers' lesson: throughput at
scale comes from the loop shape, not more lanes): G games advance one move
per jitted step, a parity-indexed roll-by-half puts each player's games in
a static half-batch (one search per move), and finished slots refill from
a pending queue.

This PR moves the pending-queue refill *onto the device*
(core/service.py): the arena submits its games to a
:class:`~repro.core.service.SearchService` pool, whose jitted dispatch
admits, searches, and scatters results into a device-resident ring buffer
— the host polls once per ``superstep`` moves instead of syncing every
step.  ``refill="host"`` keeps the PR 1 host-queue loop as the measured
baseline (benchmarks/bench_service.py) and as the bit-for-bit oracle for
the device refill (tests/test_service.py).

RNG is oracle-compatible on both paths: every slot carries its own key
chain and splits ``key -> (key, ka, kb)`` once per step exactly like
``play_game``, so a game seeded with key K plays the identical move
sequence in the arena and in the sequential oracle — the equivalence
tests pin this.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mcts import MCTS
from repro.core.service import LANE_ARENA, SearchService
from repro.go.board import GoEngine, GoState


class SlotState(NamedTuple):
    """Device-resident arena state, batched over the G slots (host path)."""
    states: GoState     # game states, leading axis G
    keys: jax.Array     # u32[G, 2] per-game RNG chains


class StepRecord(NamedTuple):
    """Per-step observables consumed by the host bookkeeping (host path)."""
    done: jax.Array     # bool[G]  game over after this step
    winner: jax.Array   # f32[G]   engine.result of the post-step state
    action: jax.Array   # i32[G]   move just played
    nodes: jax.Array    # i32[G]   mover's final search-tree size


class GameResult(NamedTuple):
    """One finished game (host-side scalars)."""
    winner: float       # +1 black / -1 white / 0 draw
    moves: int
    tree_nodes: int     # mover's tree size on the final move (Fig. 12)
    a_is_black: bool


class Arena:
    """G-slot arena stepping two MCTS players through concurrent games.

    ``refill="device"`` (default) drives games through the SearchService
    slot pool; ``refill="host"`` runs the PR 1 per-step host-queue loop.
    Both play bit-identical games.

    ``mesh``/``placement``/``rebalance``/``multihop`` shard the backing
    pool over a one-axis device mesh (see core/service.py): games are
    placed onto per-device sub-pools by the host policy, each device
    steps its own slots, and self-play throughput scales past one
    device.  ``pipeline_depth`` streams the drain (that many supersteps
    in flight, host I/O overlapped with device compute) — the result set
    is depth-invariant because games are ticket-keyed.
    """

    def __init__(self, engine: GoEngine, player_a: MCTS, player_b: MCTS,
                 slots: int, max_moves: Optional[int] = None,
                 refill: str = "device", superstep: int = 2,
                 mesh=None, placement: str = "round_robin",
                 rebalance: bool = True, multihop: bool = True,
                 pipeline_depth: int = 1):
        if slots < 2 or slots % 2:
            raise ValueError(f"slots must be even and >= 2, got {slots}")
        if refill not in ("device", "host"):
            raise ValueError(f"refill must be 'device' or 'host', "
                             f"got {refill!r}")
        if mesh is not None and refill == "host":
            raise ValueError("mesh= requires refill='device' (the host-queue"
                             " baseline is single-device by construction)")
        self.engine = engine
        self.player_a = player_a
        self.player_b = player_b
        self.slots = slots
        self.max_moves = max_moves or engine.max_moves
        self.refill = refill
        self.superstep = superstep
        self.mesh = mesh
        self.placement = placement
        self.rebalance = rebalance
        self.multihop = multihop
        self.pipeline_depth = pipeline_depth
        self._service: Optional[SearchService] = None   # built on first use
        self._step = jax.jit(self._step_impl)
        self._refill = jax.jit(self._refill_impl)
        self.host_syncs = 0     # host<->device round-trips of the last run
        self.host_blocked_s = 0.0   # device-wait time of the last run

    @property
    def service(self) -> SearchService:
        """The backing dispatcher (lazy: refill="host" never builds it)."""
        if self._service is None:
            self._service = SearchService(
                self.engine, self.player_a, self.player_b, self.slots,
                max_moves=self.max_moves, superstep=self.superstep,
                mesh=self.mesh, placement=self.placement,
                rebalance=self.rebalance, multihop=self.multihop,
                pipeline_depth=self.pipeline_depth)
        return self._service

    # ----------------------------------------------- host-queue device side
    # The PR 1 step/refill kernels, kept as the host-refill baseline.

    def _step_impl(self, slot: SlotState, parity: jax.Array):
        """Advance every slot one move; one search per slot.

        ``parity`` is the global move parity (0 => Black to move).  The
        roll-by-half gather puts A-to-move slots first; since G = 2h the
        same gather inverts itself after the searches.
        """
        G, h = self.slots, self.slots // 2
        shift = jnp.where(parity % 2 == 0, 0, h)
        idx = (jnp.arange(G, dtype=jnp.int32) + shift) % G   # involution

        st = jax.tree.map(lambda x: x[idx], slot.states)
        k3 = jax.vmap(lambda k: jax.random.split(k, 3))(slot.keys[idx])
        new_keys, ka, kb = k3[:, 0], k3[:, 1], k3[:, 2]

        head = jax.tree.map(lambda x: x[:h], st)
        tail = jax.tree.map(lambda x: x[h:], st)
        res_a = self.player_a.search_batch(head, ka[:h])
        res_b = self.player_b.search_batch(tail, kb[h:])
        actions = jnp.concatenate([res_a.action, res_b.action])
        nodes = jnp.concatenate([res_a.tree.size, res_b.tree.size])

        new_st = jax.vmap(self.engine.play)(st, actions)

        # un-permute with the same involution gather
        new_st = jax.tree.map(lambda x: x[idx], new_st)
        new_keys = new_keys[idx]
        actions = actions[idx]
        nodes = nodes[idx]

        winner = jax.vmap(self.engine.result)(new_st)
        rec = StepRecord(done=new_st.done, winner=winner, action=actions,
                         nodes=nodes)
        return SlotState(states=new_st, keys=new_keys), rec

    def _refill_impl(self, slot: SlotState, mask: jax.Array,
                     fresh_keys: jax.Array) -> SlotState:
        """Reset masked slots to fresh games with the given keys."""
        init = self.engine.init_state()

        def reset_leaf(buf, iv):
            m = mask.reshape((self.slots,) + (1,) * (buf.ndim - 1))
            return jnp.where(m, iv, buf)

        states = jax.tree.map(reset_leaf, slot.states, init)
        keys = jnp.where(mask[:, None], fresh_keys, slot.keys)
        return SlotState(states=states, keys=keys)

    def _initial_slots(self, keys: jax.Array) -> SlotState:
        init = self.engine.init_state()
        states = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (self.slots,) + jnp.shape(x)), init)
        return SlotState(states=states, keys=keys)

    # --------------------------------------------------------------- client

    @staticmethod
    def _check_keys(games: int, game_keys) -> Optional[np.ndarray]:
        if game_keys is None:
            return None
        game_keys = np.asarray(game_keys, np.uint32)
        if game_keys.shape != (games, 2):
            raise ValueError(f"game_keys must be [games, 2], got "
                             f"{game_keys.shape}")
        return game_keys

    def play_games(self, games: int, seed: int = 0,
                   game_keys: Optional[jax.Array] = None,
                   prior_weight=None) -> List[GameResult]:
        """Play ``games`` full games, refilling finished slots from the
        pending queue until the queue drains.

        A game admitted to slot ``s`` when the *next* step has parity ``p``
        must give Black to the player owning that (slot-half, parity) cell
        — that keeps the half-batch dispatch invariant.  Colour balance is
        the paper's (alternating colours, at most ±1 imbalance): admission
        is capped per colour, so a slot whose forced colour is exhausted
        idles one step and admits at the opposite parity instead.

        ``game_keys`` optionally fixes each game's root RNG key (u32[games,
        2], admission order) — used by the oracle-equivalence tests;
        otherwise keys come from a host-side chain of ``seed``.

        ``prior_weight`` (scalar or (a_side, b_side) pair, device-refill
        only) threads the evaluation-lane blend to every game — traced,
        so a guided-vs-unguided match reuses the unmodified pool trace;
        ``None`` means each player's configured default.
        """
        game_keys = self._check_keys(games, game_keys)
        if self.refill == "host":
            if prior_weight is not None:
                raise ValueError(
                    "prior_weight= needs refill='device' (the host-queue "
                    "baseline predates the evaluation lane)")
            return self._play_games_hostqueue(games, seed, game_keys)
        svc = self.service
        svc.reset(seed=seed, colour_cap=(games + 1) // 2,
                  game_capacity=games,
                  ring_capacity=games + self.slots)
        tickets = [svc.submit_game(
            key=None if game_keys is None else game_keys[i],
            lane=LANE_ARENA, prior_weight=prior_weight)
            for i in range(games)]
        recs = {r.ticket: r for r in svc.drain()}
        self.host_syncs = svc.host_syncs
        self.host_blocked_s = svc.host_blocked_s
        return [GameResult(winner=recs[t].winner, moves=recs[t].moves,
                           tree_nodes=recs[t].tree_nodes,
                           a_is_black=recs[t].a_is_black) for t in tickets]

    # ----------------------------------------------------- host-queue loop

    def _play_games_hostqueue(self, games: int, seed: int,
                              game_keys: Optional[np.ndarray]
                              ) -> List[GameResult]:
        """The PR 1 loop: per-step host admission + per-step result sync."""
        G, h = self.slots, self.slots // 2
        host_rng = np.random.default_rng(seed)
        self.host_syncs = 0
        self.host_blocked_s = 0.0   # per-step syncs; not separately timed

        def draw_key(i: int) -> np.ndarray:
            if game_keys is not None:
                return game_keys[i]
            return host_rng.integers(0, 2 ** 32, size=(2,), dtype=np.uint32)

        game_id = np.full(G, -1)            # -1: dummy slot (result discarded)
        a_black = np.array([s < h for s in range(G)])
        nmoves = np.zeros(G, np.int64)
        last_nodes = np.zeros(G, np.int64)
        colour_cap = (games + 1) // 2        # per-colour admission budget
        colour_count = {True: 0, False: 0}
        next_game = 0
        keys0 = np.stack([host_rng.integers(0, 2 ** 32, size=(2,),
                                            dtype=np.uint32)
                          for _ in range(G)])
        slot = self._initial_slots(jnp.asarray(keys0))

        results: List[Optional[GameResult]] = [None] * games
        finished = 0
        parity = 0
        while finished < games:
            # admit pending games into empty slots whose forced colour
            # still has budget; a blocked slot waits for the parity flip
            refill_mask = np.zeros(G, bool)
            fresh = np.zeros((G, 2), np.uint32)
            for s in range(G):
                if game_id[s] >= 0 or next_game >= games:
                    continue
                colour = (s < h) == (parity % 2 == 0)
                if colour_count[colour] >= colour_cap:
                    continue
                colour_count[colour] += 1
                game_id[s] = next_game
                a_black[s] = colour
                nmoves[s] = 0
                last_nodes[s] = 0
                fresh[s] = draw_key(next_game)
                refill_mask[s] = True
                next_game += 1
            if refill_mask.any():
                slot = self._refill(slot, jnp.asarray(refill_mask),
                                    jnp.asarray(fresh))
                self.host_syncs += 1
            slot, rec = self._step(slot, jnp.int32(parity))
            parity ^= 1
            done = np.asarray(rec.done)
            winner = np.asarray(rec.winner)
            nodes = np.asarray(rec.nodes)
            self.host_syncs += 1

            for s in range(G):
                if game_id[s] < 0:
                    continue
                nmoves[s] += 1
                last_nodes[s] = int(nodes[s])
                if done[s] or nmoves[s] >= self.max_moves:
                    results[game_id[s]] = GameResult(
                        winner=float(winner[s]), moves=int(nmoves[s]),
                        tree_nodes=int(last_nodes[s]),
                        a_is_black=bool(a_black[s]))
                    finished += 1
                    game_id[s] = -1
        assert all(r is not None for r in results)
        return results  # type: ignore[return-value]
