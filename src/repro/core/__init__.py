"""The paper's primary contribution: parallel MCTS (tree/root/leaf modes,
virtual loss, lock-free-analogue scatter backups) + the self-play
effective-speedup experimental harness, TPU-native (see DESIGN.md §2)."""
from repro.core.mcts import MCTS, SearchResult, make_mcts
from repro.core.tree import Tree, init_tree, init_tree_batch, \
    root_action_visits
from repro.core.arena import Arena, GameResult
from repro.core import stats, affinity, selfplay

__all__ = ["MCTS", "SearchResult", "make_mcts", "Tree", "init_tree",
           "init_tree_batch", "root_action_visits", "Arena", "GameResult",
           "stats", "affinity", "selfplay"]
