"""The paper's primary contribution: parallel MCTS (tree/root/leaf modes,
virtual loss, lock-free-analogue scatter backups) behind one batched
search dispatcher, TPU-native (see DESIGN.md §2).

Public API
==========

====================  =====================================================
``MCTS``              search driver; public surface is ``search_batch``
                      (per-game traced ``sims`` budget + traced
                      ``SearchParams`` (c_uct, vl_weight)) and
                      ``init_tree_batch`` — the pre-service five-method
                      surface (and its ``SearchResult`` alias) is gone
``SearchParams``      traced per-search UCT knobs; one compiled search
                      serves any mix of configurations
``SearchService``     the unified dispatcher (core/service.py): a
                      device-resident slot pool with origin-tagged lanes
                      (``LANE_ARENA`` / ``LANE_SERVE`` /
                      ``LANE_TOURNAMENT``), device-side refill, and a
                      result ring buffer; ``submit_* -> flush -> dispatch
                      -> poll``, or streamed via ``DispatchPipeline``
``DispatchPipeline``  streaming drain loop (core/streaming.py): keeps
                      ``pipeline_depth`` supersteps in flight and
                      reconciles ring back buffers as they land
``SearchRequest``     pending-request pytree (state, key, lane, per-side
                      sims / c_uct / vl pairs, ticket)
``SearchResult``      completed-request host record scattered back from
                      the ring; ticket-tagged and order-independent
                      (``finished_step`` stamps device completion time)
``Arena``             self-play client of the service (``refill="host"``
                      keeps the PR 1 host-queue loop as baseline/oracle)
``Tournament``        all-play-all cross table multiplexed through one
                      service pool (per-slot traced configs, win matrix
                      + Elo); per-pair pools for static-shape-diverse
                      configs
``SearchOutput``      raw per-search output of ``MCTS.search_batch``
``Tree`` helpers      ``init_tree`` / ``init_tree_batch`` /
                      ``root_action_visits`` / ``select_action``
====================  =====================================================

External best-move queries are served by
:class:`repro.serving.go_service.GoService` on top of ``SearchService``.
"""
from repro.core.mcts import MCTS, SearchOutput, SearchParams, make_mcts
from repro.core.tree import Tree, init_tree, init_tree_batch, \
    root_action_visits, select_action
from repro.core.arena import Arena, GameResult
from repro.core.service import (LANE_ARENA, LANE_SERVE, LANE_TOURNAMENT,
                                SearchRequest, SearchResult, SearchService)
from repro.core.streaming import DispatchPipeline
from repro.core.tournament import Tournament, TournamentResult
from repro.core import stats, affinity, selfplay

__all__ = ["MCTS", "SearchOutput", "SearchParams", "SearchResult",
           "SearchRequest",
           "SearchService", "DispatchPipeline",
           "LANE_ARENA", "LANE_SERVE", "LANE_TOURNAMENT",
           "make_mcts", "Tree", "init_tree", "init_tree_batch",
           "root_action_visits", "select_action", "Arena", "GameResult",
           "Tournament", "TournamentResult", "stats", "affinity",
           "selfplay"]
