"""Parallel MCTS: tree / root / leaf parallelism with virtual loss.

This is the paper's algorithm (FUEGO-style tree parallelisation with virtual
loss and lock-free backups, Chaslot et al. 2008 / Enzenberger & Müller 2010)
reformulated for a SIMD machine:

* A "thread" is a **lane**.  One search *iteration* selects ``lanes`` leaves
  from the shared tree, runs all their playouts as a single ``vmap`` batch,
  and backs all results up with exact ``scatter-add``.
* Virtual loss is applied **sequentially within an iteration** via
  ``lax.scan`` over lanes: lane *i* selects under the statistics plus the
  in-flight virtual losses of lanes *< i*, exactly the decorrelation the Phi
  threads got from seeing each other's in-flight descents.  Lanes also see
  nodes expanded by earlier lanes of the same iteration.
* Backups clear the virtual loss (FUEGO removes it at backup time).

With a fixed *time* budget the paper's "2× threads" player performs 2× the
playouts per move at the price of staler selection statistics — the search
overhead the self-play experiments measure.  Here: iterations are the time
analogue and ``lanes`` the thread count, so ``sims/move = iterations x lanes``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import MCTSConfig
from repro.core import tree as tree_lib
from repro.core.tree import Tree, UNVISITED
from repro.go.board import GoEngine, GoState

BIG = 1e9
FPU = 10.0  # first-play urgency: unvisited edges are searched eagerly


class SearchOutput(NamedTuple):
    """Raw output of one move search (batched over games by search_batch)."""
    tree: Tree
    action: jax.Array          # chosen move (argmax root visits)
    root_visits: jax.Array     # f32[A] visit distribution at the root
    root_values: jax.Array     # f32[A] mean black-perspective values


class SearchParams(NamedTuple):
    """Traced per-search UCT knobs (the tournament-multiplexing contract).

    Passed to :meth:`MCTS.search_batch` as ``f32[G]`` arrays (one value per
    game; inside a search the scalar broadcasts over every lane and tree
    level), or left ``None`` to use this player's static ``MCTSConfig``
    values.  All fields are *traced*: changing them never recompiles, and
    passing arrays equal to the config constants is bit-identical to
    ``params=None`` (pinned in tests/test_multiplex.py).

    ``prior_w`` is the evaluation-lane UCT<->PUCT blend weight (PR 7).
    Its *presence* selects the blended scoring program (one compiled
    dispatch then serves guided ``w > 0`` and unguided ``w = 0`` slots);
    its *values* are traced.  ``None`` keeps the seed's static scoring
    path — except under an ``evaluator``, where it defaults to the
    config's ``prior_weight``.  ``w = 0`` rows are bit-identical to the
    static no-eval search (tests/test_evaluator.py pins this).

    ``komi`` (PR 10) multiplexes scoring the same way: ``None`` keeps the
    engine's static komi (the historical program, bit for bit); an
    ``f32[G]`` array threads a traced per-game komi into every playout
    outcome, so one compiled dispatch serves every komi bucket.  Like
    ``prior_w``, presence selects the program (a pytree-structure
    change); values never recompile, and an array equal to the engine
    constant is value-bit-identical to ``None`` (half-integer komis are
    exact in f32, and komi never touches the RNG stream).
    """
    c_uct: jax.Array           # f32[G] exploration constant
    vl_weight: jax.Array       # f32[G] virtual-loss weight in the Q term
    prior_w: Optional[jax.Array] = None  # f32[G] eval-lane prior blend
    komi: Optional[jax.Array] = None     # f32[G] traced per-game komi


class MCTS:
    """Search driver bound to an engine + config (methods jit/vmap-safe).

    Public API (everything else is private; the pre-service five-method
    surface — ``search`` / ``search_root_parallel`` / ``best_move`` /
    ``jit_best_move`` — was removed once every caller routed through
    ``search_batch`` or the SearchService/GoService dispatchers):

    ==================  ======================================================
    ``search_batch``    one full move search per game over a leading game
                        axis, with a traced per-game ``sims`` budget and
                        traced per-game ``SearchParams`` (c_uct, vl_weight,
                        prior_w)
    ``init_tree_batch`` batch of per-game tree arenas under this player's
                        engine / capacity / priors
    ==================  ======================================================

    Recompile contract: the config fixes the compiled search *shape*
    (lanes, iteration bound, tree capacity, board); ``sims`` and
    ``SearchParams`` are data.  One MCTS player therefore serves
    arbitrarily many (c_uct, virtual_loss, sims, prior_weight)
    configurations with a single trace — the SearchService multiplexing
    contract (docs/ARCHITECTURE.md).

    Evaluation lane (PR 7): pass ``evaluator=`` (an
    :class:`repro.core.evaluator.EvalService`) to run every iteration's
    selected leaves through a jitted policy/value net.  Roots and leaves
    then carry net priors, edge scoring blends UCT with PUCT under the
    traced ``prior_w`` weight, and net values mix into playout returns.
    The evaluator's params are baked into the compiled search as
    constants — rebuild the player (and any service above it) after a
    training step updates them.
    """

    def __init__(self, engine: GoEngine, cfg: MCTSConfig,
                 prior_fn=None, value_fn=None, use_puct: bool = False,
                 max_depth: int = 64, evaluator=None, fused: bool = False):
        self.engine = engine
        self.cfg = cfg
        self.fused = fused            # route search_batch through mcts_step
        self.evaluator = evaluator    # optional EvalService (core/evaluator.py)
        if evaluator is not None:
            if value_fn is not None:
                raise ValueError(
                    "evaluator and value_fn are mutually exclusive: the "
                    "evaluator's value head already mixes into playout "
                    "returns (weight = value_weight * prior_w)")
            if prior_fn is None:
                prior_fn = evaluator.prior_fn
            # children get a uniform prior at allocation; the batched
            # leaf evaluation of the same iteration overwrites it (the
            # scatter in _simulate) — per-node prior_fn calls inside the
            # sequential lane scan would serialise the net
            self._expand_prior_fn = None
        else:
            self._expand_prior_fn = prior_fn
        self.prior_fn = prior_fn      # optional policy hook: state, legal -> prior
        self.value_fn = value_fn      # optional value hook replacing playouts
        self.use_puct = use_puct
        self.max_depth = max_depth
        if cfg.parallelism == "tree":
            div = cfg.lanes * max(1, cfg.leaf_playouts)
        elif cfg.parallelism == "leaf":
            div = max(1, cfg.leaf_playouts)
        else:  # root: each tree gets the full iteration budget / root_trees
            div = (max(1, cfg.root_trees)
                   * cfg.lanes * max(1, cfg.leaf_playouts))
        self._sims_divisor = div      # sims -> iterations conversion
        self.iterations = max(1, cfg.sims_per_move // div)

    # ------------------------------------------------------------------ select

    def _edge_scores(self, t: Tree, node, player, rng,
                     params: Optional[SearchParams] = None) -> jax.Array:
        """UCT/PUCT score for every action at ``node`` under virtual loss.

        Routed through ``kernels.uct_select.ops`` — the Pallas kernel on
        TPU, its oracle elsewhere — so search and kernel share one call
        site (see kernels/uct_select/kernel.py).  ``params`` carries the
        traced per-search (c_uct, vl_weight) scalars; ``None`` uses the
        static config values (bit-identical when the values agree).
        """
        from repro.kernels.uct_select.ops import uct_scores
        c, vlw, pw = self._resolve_params(params)
        kids = t.children[node]
        has_child = kids != UNVISITED
        cidx = jnp.maximum(kids, 0)
        parent_n = t.visit[node] + t.vloss[node]
        score = uct_scores(
            t.visit[cidx][None], t.value[cidx][None], t.vloss[cidx][None],
            t.prior[node][None], t.legal[node][None], has_child[None],
            parent_n[None], player[None],
            c_uct=c, vl_weight=vlw, prior_w=pw,
            use_puct=self.use_puct)[0]
        # random tie-break (the asynchronous-thread nondeterminism analogue)
        return score + jax.random.uniform(rng, score.shape) * 1e-3

    def _resolve_params(self, params: Optional[SearchParams]):
        """The traced (c_uct, vl_weight, prior_w) triple.

        Defaults come from the config; ``prior_w`` resolves to ``None``
        (static scoring program, the seed path) unless an evaluator is
        bound or the caller threads an explicit blend weight.
        """
        if params is None:
            pw = self.cfg.prior_weight if self.evaluator is not None else None
            return self.cfg.c_uct, self.cfg.virtual_loss, pw
        pw = params.prior_w
        if pw is None and self.evaluator is not None:
            pw = self.cfg.prior_weight
        return params.c_uct, params.vl_weight, pw

    def _select_lane(self, t: Tree, rng,
                     params: Optional[SearchParams] = None):
        """Walk root->leaf under UCT+virtual-loss; expand one node.

        Returns (tree, path i32[max_depth] node ids (-1 pad), playout node).
        """
        path0 = jnp.full((self.max_depth,), UNVISITED, jnp.int32).at[0].set(0)

        def cond(c):
            node, depth, _, _, stop = c
            return (~stop) & (depth < self.max_depth - 1)

        def body(c):
            node, depth, path, key, _ = c
            key, sub = jax.random.split(key)
            player = tree_lib.node_state(t, node).to_play.astype(jnp.float32)
            scores = self._edge_scores(t, node, player, sub, params)
            act = jnp.argmax(scores).astype(jnp.int32)
            child = t.children[node, act]
            # descend only through materialised, expandable children
            nxt = jnp.where(child == UNVISITED, node, child)
            stop = (child == UNVISITED) | t.terminal[child] \
                | ~t.expanded[jnp.maximum(child, 0)]
            depth = depth + jnp.where(child == UNVISITED, 0, 1)
            path = path.at[depth].set(nxt)
            # smuggle chosen action out via stop case
            return (jnp.where(stop & (child == UNVISITED), node, nxt),
                    depth, path, key, stop), act

        # hand-rolled while that also yields the last action
        def loop(carry):
            state, act = carry
            state, act = body(state)
            return (state, act)

        state = (jnp.int32(0), jnp.int32(0), path0, rng, jnp.bool_(False))
        act = jnp.int32(self.engine.pass_action)

        def wcond(carry):
            (node, depth, path, key, stop), _ = carry
            return (~stop) & (depth < self.max_depth - 1)

        (state, act) = jax.lax.while_loop(wcond, loop, (state, act))
        node, depth, path, key, stop = state

        # expand if we stopped at an unmaterialised edge of a non-terminal,
        # sufficiently-visited node
        can_expand = (t.children[node, act] == UNVISITED) \
            & ~t.terminal[node] \
            & (t.visit[node] + t.vloss[node] >= self.cfg.expand_threshold) \
            & t.expanded[node]

        def do_expand(t):
            t2, idx = tree_lib.allocate(self.engine, t, node, act,
                                        self._expand_prior_fn)
            return t2, idx

        t, new_idx = jax.lax.cond(
            can_expand, do_expand, lambda t: (t, node), t)
        depth = depth + jnp.where(can_expand & (new_idx != node), 1, 0)
        path = path.at[depth].set(new_idx)

        # apply virtual loss along the path (visible to later lanes)
        valid = path != UNVISITED
        safe = jnp.maximum(path, 0)
        t = t._replace(vloss=t.vloss.at[safe].add(
            jnp.where(valid, 1.0, 0.0)))
        return t, path, new_idx

    # --------------------------------------------------------------- simulate

    def _simulate(self, t: Tree, rng,
                  params: Optional[SearchParams] = None) -> Tree:
        """One iteration: ``lanes`` selects -> batched playouts -> backup.

        The traced ``params`` scalars broadcast over every lane: each of
        the ``lanes`` sequential selects scores edges under the same
        per-search (c_uct, vl_weight) pair.

        Under an ``evaluator`` the iteration also forms the evaluation
        batch: the ``lanes`` selected leaf states go through the policy/
        value net as one fixed-shape ``[L]`` forward (``[G, L]`` after the
        ``search_batch`` vmap — the superstep eval batch), the policy
        head's priors are scattered back over the leaves' prior rows, and
        the value head mixes into the playout returns with traced weight
        ``value_weight * prior_w`` (AlphaGo's lambda; terminal leaves keep
        their exact game result).  ``prior_w = 0`` leaves the returns
        bit-identical to the playout-only path.
        """
        L, P = self.cfg.lanes, max(1, self.cfg.leaf_playouts)
        keys = jax.random.split(rng, L + 1)

        def lane(t, key):
            t, path, leaf = self._select_lane(t, key, params)
            return t, (path, leaf)

        t, (paths, leaves) = jax.lax.scan(lane, t, keys[:L])

        # batched playouts: [L, P]
        pkeys = jax.random.split(keys[L], L * P).reshape(L, P, 2)
        leaf_states = jax.tree.map(lambda x: x[leaves], t.states)
        komi = None if params is None else params.komi
        if self.value_fn is not None:
            vals = jax.vmap(self.value_fn)(leaf_states)          # [L]
            vals = jnp.repeat(vals[:, None], P, axis=1)
        elif komi is None:
            vals = jax.vmap(
                lambda st, ks: jax.vmap(
                    lambda k: self.engine.playout_value(st, k))(ks)
            )(leaf_states, pkeys)                                 # [L, P]
        else:
            # traced per-search komi (a scalar here: search_batch's vmap
            # peeled the game axis); broadcasts over every lane/playout
            vals = jax.vmap(
                lambda st, ks: jax.vmap(
                    lambda k: self.engine.playout_value(st, k, komi))(ks)
            )(leaf_states, pkeys)                                 # [L, P]
        val_sum = vals.sum(axis=1)                                # black persp.

        prior = t.prior
        if self.evaluator is not None:
            # the superstep eval batch: one net forward over all L leaves
            net_prior, net_val = self.evaluator.policy_value(
                leaf_states, t.legal[leaves])
            _, _, pw = self._resolve_params(params)
            mix = jnp.asarray(pw, jnp.float32) * self.evaluator.value_weight
            # terminal leaves keep the exact game result; elsewhere blend
            # net value (already a sum-equivalent: x P playouts' worth)
            mix = jnp.where(t.terminal[leaves], 0.0, mix)          # [L]
            val_sum = (1.0 - mix) * val_sum + mix * (net_val * P)
            # duplicate leaf indices write identical rows (same state)
            prior = prior.at[leaves].set(net_prior)

        # exact scatter-add backup over all lanes at once
        flat = paths.reshape(-1)
        ok = flat != UNVISITED
        safe = jnp.maximum(flat, 0)
        w = jnp.where(ok, 1.0, 0.0)
        vrep = jnp.repeat(val_sum, self.max_depth)
        t = t._replace(
            visit=t.visit.at[safe].add(w * P),
            value=t.value.at[safe].add(jnp.where(ok, vrep, 0.0)),
            vloss=jnp.zeros_like(t.vloss),   # FUEGO: remove at backup
            prior=prior,
        )
        return t

    # ----------------------------------------------------------------- search

    def _iterations_for(self, sims: jax.Array) -> jax.Array:
        """Traced iteration budget for a per-request ``sims`` knob.

        ``sims <= 0`` means "this player's configured budget".  The static
        ``self.iterations`` stays the compiled loop bound; smaller budgets
        mask the tail iterations instead of recompiling (the ServeEngine
        temperature treatment applied to the search loop — changing a
        request's playout budget must not retrace the dispatcher).
        """
        sims = jnp.asarray(sims, jnp.int32)
        it = jnp.clip(sims // self._sims_divisor, 1, self.iterations)
        return jnp.where(sims > 0, it, jnp.int32(self.iterations))

    def _search(self, root: GoState, rng,
                sims: Optional[jax.Array] = None,
                params: Optional[SearchParams] = None) -> SearchOutput:
        """One full move search from ``root`` (single game).

        With ``sims=None`` this is the seed's exact static loop.  With a
        traced ``sims``, iterations ``>= iterations_for(sims)`` become
        no-ops via a select — bit-identical to the static loop whenever
        the requested budget equals the configured one, which the service
        oracle-equivalence tests pin.  ``params`` (traced per-search
        scalars after the search_batch vmap) likewise reproduces the
        ``None`` path bit-for-bit when it carries the config constants.
        """
        t = tree_lib.init_tree(self.engine, root, self.cfg.max_nodes,
                               None if self.prior_fn is None
                               else self.prior_fn(root,
                                                  self.engine.legal_moves(root)))
        keys = jax.random.split(rng, self.iterations)

        if sims is None:
            def it(i, t):
                return self._simulate(t, keys[i], params)
        else:
            iters = self._iterations_for(sims)

            def it(i, t):
                t2 = self._simulate(t, keys[i], params)
                live = i < iters
                # Mask only the search statistics and the allocation
                # cursor: a dead iteration must not move visit/value mass
                # (so the root distribution, chosen action, and reported
                # tree size equal a truncated search's exactly), but its
                # node *writes* are harmless — they land at or beyond the
                # reverted cursor with zero visits, which every live read
                # ignores.  Selecting two [N] arrays and a scalar instead
                # of the whole tree keeps the masked loop's overhead out
                # of the dispatch hot path.
                return t2._replace(
                    visit=jnp.where(live, t2.visit, t.visit),
                    value=jnp.where(live, t2.value, t.value),
                    size=jnp.where(live, t2.size, t.size))

        t = jax.lax.fori_loop(0, self.iterations, it, t)
        visits = tree_lib.root_action_visits(t)
        action = tree_lib.select_action(visits, t.legal[0])
        return SearchOutput(tree=t, action=action, root_visits=visits,
                            root_values=tree_lib.root_action_values(t))

    def search_batch(self, roots: GoState, rngs: jax.Array,
                     sims: Optional[jax.Array] = None,
                     params: Optional[SearchParams] = None) -> SearchOutput:
        """Batched move search: one independent tree per game.

        ``roots`` is a ``GoState`` batched over a leading game axis and
        ``rngs`` is ``u32[G, 2]`` — per-game RNG so any game's search is
        bit-identical to an unbatched search with the same key.  This is
        the hot path of the SearchService dispatcher (core/service.py):
        all G trees advance one full move search as a single vmapped
        program.

        Traced-vs-static contract (what does and does not recompile):

        * **static** — everything baked into this player's ``MCTSConfig``
          shape: ``lanes``, ``max_nodes``, ``sims_per_move`` (the compiled
          loop bound), board size, ``parallelism`` — plus the batch size
          ``G``.  Changing any of these retraces.
        * **traced** — ``sims`` (optional ``i32[G]`` per-game playout
          budget: ``<= 0`` selects the configured ``sims_per_move``;
          positive values are capped by it) and ``params`` (optional
          :class:`SearchParams` of ``f32[G]`` per-game ``c_uct`` /
          ``vl_weight`` / ``prior_w``).  Changing their *values* never
          recompiles, and passing the configured constants is
          bit-identical to ``None``.  The one structural exception is
          ``prior_w``: ``None`` vs array selects the scoring *program*
          (static vs blended — a pytree-structure change, so the two
          programs are separate jit cache entries), while its values —
          any per-game mix of guided/unguided weights — stay traced.

        Players built with ``fused=True`` route through the
        ``kernels/mcts_step`` superstep (:meth:`_search_fused_batch`) —
        a documented search *variant* with deferred expansion;
        ``fused=False`` (the default) is this exact historical program,
        bit for bit (tests/test_mcts_step.py pins both).
        """
        sims = None if sims is None else jnp.asarray(sims, jnp.int32)
        if params is None:
            if self.fused:
                return self._search_fused_batch(roots, rngs, sims)
            if sims is None:
                return jax.vmap(self._search)(roots, rngs)
            return jax.vmap(self._search)(roots, rngs, sims)
        params = SearchParams(jnp.asarray(params.c_uct, jnp.float32),
                              jnp.asarray(params.vl_weight, jnp.float32),
                              None if params.prior_w is None
                              else jnp.asarray(params.prior_w, jnp.float32),
                              None if params.komi is None
                              else jnp.asarray(params.komi, jnp.float32))
        if self.fused:
            return self._search_fused_batch(roots, rngs, sims, params)
        if sims is None:
            return jax.vmap(
                lambda r, k, p: self._search(r, k, None, p))(
                    roots, rngs, params)
        return jax.vmap(self._search)(roots, rngs, sims, params)

    # ---------------------------------------------------- fused superstep

    def _expand_batch(self, t: Tree, paths, depth, leaf, act, can_exp):
        """Grow every game's tree for all lanes at once (deferred expansion).

        The fused kernel selects over a frozen children table, so lanes
        that picked the same ``(leaf, action)`` edge collapse onto one new
        node: the first such lane allocates, the rest share its child.
        Slots come from an exclusive cumsum over the unique expansions;
        lanes whose slot would overflow the arena keep their parent as
        the playout node (the unfused ``allocate`` full-arena behaviour).
        Masked scatters use the out-of-bounds sentinel ``N`` — dropped by
        XLA scatter semantics — instead of a per-lane ``cond``, and the
        engine step runs as **one** vmapped ``[G, L]`` batch where the
        unfused lane scan played ``L`` sequential moves per game.

        Returns ``(tree, extended paths, playout leaves i32[G, L])``.
        """
        g, lanes = leaf.shape
        n = t.visit.shape[1]
        gi = jnp.arange(g)[:, None]
        li = jnp.arange(lanes, dtype=jnp.int32)[None, :]
        same = (leaf[:, :, None] == leaf[:, None, :]) \
            & (act[:, :, None] == act[:, None, :])
        rep = jnp.argmax(same & can_exp[:, None, :], axis=-1).astype(jnp.int32)
        uniq = can_exp & (rep == li)
        u32 = uniq.astype(jnp.int32)
        slots = t.size[:, None] + jnp.cumsum(u32, axis=1) - u32
        alloc = uniq & (slots < n)

        parents = jax.vmap(
            lambda st, i: jax.tree.map(lambda x: x[i], st))(t.states, leaf)
        childs = jax.vmap(jax.vmap(self.engine.play))(parents, act)
        legal = jax.vmap(jax.vmap(self.engine.legal_moves))(childs)
        if self._expand_prior_fn is not None:
            raw = jax.vmap(jax.vmap(self._expand_prior_fn))(childs, legal)
            prior = jax.vmap(jax.vmap(tree_lib.normalize_prior))(raw, legal)
        else:
            prior = jax.vmap(jax.vmap(tree_lib.uniform_prior))(legal)

        oob = jnp.where(alloc, slots, n)
        t = t._replace(
            children=t.children.at[
                gi, jnp.where(alloc, leaf, n), act].set(slots),
            parent=t.parent.at[gi, oob].set(leaf),
            action=t.action.at[gi, oob].set(act),
            legal=t.legal.at[gi, oob].set(legal),
            prior=t.prior.at[gi, oob].set(prior),
            expanded=t.expanded.at[gi, oob].set(~childs.done),
            terminal=t.terminal.at[gi, oob].set(childs.done),
            states=jax.tree.map(lambda buf, v: buf.at[gi, oob].set(v),
                                t.states, childs),
            size=t.size + alloc.sum(axis=1).astype(jnp.int32),
        )

        rep_alloc = jnp.take_along_axis(alloc, rep, axis=1)
        rep_slot = jnp.take_along_axis(slots, rep, axis=1)
        leaves = jnp.where(can_exp & rep_alloc, rep_slot, leaf)
        ext = (leaves != leaf).astype(jnp.int32)
        paths = paths.at[gi, li, depth + ext].set(leaves)
        return t, paths, leaves

    def _simulate_fused(self, t: Tree, keys, c, vlw, pw, komi=None) -> Tree:
        """One fused iteration over every game: kernel select -> batched
        expansion -> playouts/eval -> kernel backup.

        The ``kernels/mcts_step`` counterpart of :meth:`_simulate`:
        selection and backup run as single fused ops over the ``[G, ...]``
        tree slabs (Pallas on TPU, oracle on CPU) instead of a lane scan,
        and expansion/playouts batch over ``[G, L]``.  ``keys`` is
        ``u32[G, 2]``; ``c`` / ``vlw`` / ``pw`` are the resolved traced
        knobs (scalar or ``[G]``).
        """
        from repro.kernels.mcts_step.ops import mcts_backup, mcts_select
        lanes, p = self.cfg.lanes, max(1, self.cfg.leaf_playouts)
        g = t.visit.shape[0]
        gi = jnp.arange(g)[:, None]
        sub = jax.vmap(lambda k: jax.random.split(k, 2))(keys)   # [G, 2, 2]
        seeds = sub[:, 0, 0]                                     # u32[G]
        pkeys = jax.vmap(
            lambda k: jax.random.split(k, lanes * p))(sub[:, 1])
        pkeys = pkeys.reshape(g, lanes, p, 2)

        player = t.states.to_play.astype(jnp.float32)            # [G, N]
        paths, depth, leaf, act, can_exp, vloss = mcts_select(
            t.visit, t.value, t.vloss, t.prior, t.legal, t.children,
            t.expanded, t.terminal, player, seeds,
            c_uct=c, vl_weight=vlw, prior_w=pw,
            lanes=lanes, max_depth=self.max_depth,
            expand_threshold=int(self.cfg.expand_threshold),
            use_puct=self.use_puct)
        t = t._replace(vloss=vloss)
        t, paths, leaves = self._expand_batch(
            t, paths, depth, leaf, act, can_exp)

        leaf_states = jax.vmap(
            lambda st, i: jax.tree.map(lambda x: x[i], st))(t.states, leaves)
        if self.value_fn is not None:
            vals = jax.vmap(jax.vmap(self.value_fn))(leaf_states)  # [G, L]
            val_sum = vals * p
        elif komi is None:
            one = lambda st, ks: jax.vmap(                         # noqa: E731
                lambda k: self.engine.playout_value(st, k))(ks)
            vals = jax.vmap(jax.vmap(one))(leaf_states, pkeys)     # [G, L, P]
            val_sum = vals.sum(axis=-1)
        else:
            km = jnp.broadcast_to(jnp.asarray(komi, jnp.float32), (g,))
            one = lambda st, ks, kv: jax.vmap(                     # noqa: E731
                lambda k: self.engine.playout_value(st, k, kv))(ks)
            vals = jax.vmap(
                lambda ls, pk, kv: jax.vmap(
                    lambda st, ks: one(st, ks, kv))(ls, pk)
            )(leaf_states, pkeys, km)                              # [G, L, P]
            val_sum = vals.sum(axis=-1)

        prior = t.prior
        if self.evaluator is not None:
            net_prior, net_val = jax.vmap(self.evaluator.policy_value)(
                leaf_states, t.legal[gi, leaves])
            mix = jnp.broadcast_to(jnp.asarray(pw, jnp.float32), (g,))[:, None]
            mix = mix * self.evaluator.value_weight
            mix = jnp.where(t.terminal[gi, leaves], 0.0, mix)      # [G, L]
            val_sum = (1.0 - mix) * val_sum + mix * (net_val * p)
            prior = prior.at[gi, leaves].set(net_prior)

        visit, value = mcts_backup(t.visit, t.value, paths, val_sum,
                                   playouts=float(p))
        return t._replace(visit=visit, value=value,
                          vloss=jnp.zeros_like(t.vloss), prior=prior)

    def _search_fused_batch(self, roots: GoState, rngs: jax.Array,
                            sims: Optional[jax.Array] = None,
                            params: Optional[SearchParams] = None
                            ) -> SearchOutput:
        """Batched move search through the fused superstep kernels.

        Same signature/contract as the vmapped :meth:`_search` path of
        :meth:`search_batch` (traced ``sims`` masking, traced ``params``)
        — but a deliberate algorithm *variant*, not a bit-identical
        replacement: lanes see earlier lanes' virtual losses yet not
        their expansions (ref.py documents the deferred-expansion
        semantics), and tie-breaks come from the counter-based hash.
        """
        t = self.init_tree_batch(roots)
        keys = jax.vmap(
            lambda k: jax.random.split(k, self.iterations))(rngs)  # [G, I, 2]
        c, vlw, pw = self._resolve_params(params)
        komi = None if params is None else params.komi
        iters = None if sims is None else jax.vmap(self._iterations_for)(sims)

        def it(i, t):
            t2 = self._simulate_fused(t, keys[:, i], c, vlw, pw, komi)
            if iters is None:
                return t2
            live = (i < iters)[:, None]
            return t2._replace(
                visit=jnp.where(live, t2.visit, t.visit),
                value=jnp.where(live, t2.value, t.value),
                size=jnp.where(live[:, 0], t2.size, t.size))

        t = jax.lax.fori_loop(0, self.iterations, it, t)
        visits = jax.vmap(tree_lib.root_action_visits)(t)
        action = jax.vmap(tree_lib.select_action)(visits, t.legal[:, 0])
        return SearchOutput(tree=t, action=action, root_visits=visits,
                            root_values=jax.vmap(tree_lib.root_action_values)(t))

    def init_tree_batch(self, roots: GoState) -> Tree:
        """Batch of per-game tree arenas under this player's engine/config.

        Applies the player's ``prior_fn`` (when set) to every root, so
        service consumers never touch ``tree_lib`` directly.
        """
        priors = None
        if self.prior_fn is not None:
            legal = jax.vmap(self.engine.legal_moves)(roots)
            priors = jax.vmap(self.prior_fn)(roots, legal)
        return tree_lib.init_tree_batch(self.engine, roots,
                                        self.cfg.max_nodes, priors)

    # ------------------------------------------------------ internal variants

    def _search_root_parallel(self, root: GoState, rng) -> SearchOutput:
        """Root parallelism: ``root_trees`` independent searches, vote merge."""
        R = max(1, self.cfg.root_trees)
        keys = jax.random.split(rng, R)
        res = jax.vmap(lambda k: self._search(root, k))(keys)
        visits = res.root_visits.sum(axis=0)
        values = res.root_values.mean(axis=0)
        action = tree_lib.select_action(visits, self.engine.legal_moves(root))
        tree0 = jax.tree.map(lambda x: x[0], res.tree)
        return SearchOutput(tree=tree0, action=action, root_visits=visits,
                            root_values=values)

    def _best_move(self, root: GoState, rng) -> jax.Array:
        if self.cfg.parallelism == "root":
            return self._search_root_parallel(root, rng).action
        return self._search(root, rng).action

    @functools.partial(jax.jit, static_argnums=0)
    def _jit_best_move(self, root: GoState, rng) -> jax.Array:
        return self._best_move(root, rng)


def make_mcts(engine: GoEngine, cfg: MCTSConfig, **kw) -> MCTS:
    """Build an :class:`MCTS` player, normalising leaf-parallel configs."""
    if cfg.parallelism == "leaf":
        # leaf parallelism: a single selection lane, many playouts per leaf
        cfg = cfg if cfg.lanes == 1 else cfg.__class__(
            **{**cfg.__dict__, "lanes": 1,
               "leaf_playouts": max(cfg.leaf_playouts, cfg.lanes)})
    return MCTS(engine, cfg, **kw)
