"""Structure-of-arrays MCTS tree arena.

The Xeon Phi study's FUEGO shares one pointer-linked tree between up to 240
threads.  The TPU-native analogue is a fixed-capacity structure-of-arrays
arena: node statistics live in flat arrays, edges in a ``children[node,
action]`` table, and every "thread" (lane) operation becomes a vectorised
gather/scatter.  Lost-update races of the lock-free original become exact
deterministic ``scatter-add`` backups (see DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.go.board import GoEngine, GoState

UNVISITED = -1  # children-table sentinel: edge not yet materialised


class Tree(NamedTuple):
    """One search tree over a ``max_nodes`` arena (vmap for batches)."""
    visit: jax.Array      # f32[N]    real visit counts
    value: jax.Array      # f32[N]    black-perspective outcome sums
    vloss: jax.Array      # f32[N]    in-flight virtual-loss counts
    prior: jax.Array      # f32[N,A]  per-action priors (uniform or policy)
    children: jax.Array   # i32[N,A]  node index per edge, UNVISITED if none
    parent: jax.Array     # i32[N]
    action: jax.Array     # i32[N]    action taken from parent into this node
    legal: jax.Array      # bool[N,A] legal action mask at each node
    expanded: jax.Array   # bool[N]   node may be descended through
    terminal: jax.Array   # bool[N]
    states: GoState       # node game states, batched over N
    size: jax.Array       # i32 scalar: next free slot


def _tile_state(state: GoState, n: int) -> GoState:
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,) + jnp.shape(x)), state)


def init_tree(engine: GoEngine, root: GoState, max_nodes: int,
              root_prior: jax.Array | None = None) -> Tree:
    """Arena with the root installed at slot 0.

    A caller-supplied ``root_prior`` (the ``MCTS.prior_fn`` root path) is
    normalised over the root's *legal* moves before it is stored — the
    selection kernels assume priors are a distribution over legal actions,
    and a policy net emits mass on illegal points (see
    :func:`normalize_prior`).
    """
    n, a = max_nodes, engine.num_actions
    legal0 = engine.legal_moves(root)
    if root_prior is None:
        root_prior = uniform_prior(legal0)
    else:
        root_prior = normalize_prior(root_prior, legal0)
    states = _tile_state(root, n)
    return Tree(
        visit=jnp.zeros((n,), jnp.float32).at[0].set(1.0),
        value=jnp.zeros((n,), jnp.float32),
        vloss=jnp.zeros((n,), jnp.float32),
        prior=jnp.zeros((n, a), jnp.float32).at[0].set(root_prior),
        children=jnp.full((n, a), UNVISITED, jnp.int32),
        parent=jnp.full((n,), UNVISITED, jnp.int32),
        action=jnp.full((n,), UNVISITED, jnp.int32),
        legal=jnp.zeros((n, a), jnp.bool_).at[0].set(legal0),
        expanded=jnp.zeros((n,), jnp.bool_).at[0].set(~root.done),
        terminal=jnp.zeros((n,), jnp.bool_).at[0].set(root.done),
        states=states,
        size=jnp.int32(1),
    )


def init_tree_batch(engine: GoEngine, roots: GoState, max_nodes: int,
                    root_priors: jax.Array | None = None) -> Tree:
    """Batch of independent arenas, one per leading-axis root state.

    The per-game counterpart of :func:`init_tree` used by batched search
    (``MCTS.search_batch``) and the self-play arena: every game gets its own
    ``max_nodes`` arena, stacked on a leading game axis.
    """
    if root_priors is None:
        return jax.vmap(lambda r: init_tree(engine, r, max_nodes))(roots)
    return jax.vmap(lambda r, p: init_tree(engine, r, max_nodes, p))(
        roots, root_priors)


def uniform_prior(legal: jax.Array) -> jax.Array:
    m = legal.astype(jnp.float32)
    return m / jnp.maximum(m.sum(-1, keepdims=True), 1.0)


def normalize_prior(prior: jax.Array, legal: jax.Array) -> jax.Array:
    """Mask ``prior`` to the legal moves and renormalise to sum 1.

    The contract every stored tree prior satisfies (root install and
    child allocation both route through here): zero mass on illegal
    actions, unit mass over legal ones, with a uniform fallback when the
    raw prior leaves (numerically) nothing on any legal move — a policy
    head that concentrated all its mass on illegal points must not
    produce a zero/NaN prior row.
    """
    p = jnp.where(legal, prior.astype(jnp.float32), 0.0)
    s = p.sum(-1, keepdims=True)
    return jnp.where(s > 1e-12, p / jnp.maximum(s, 1e-12),
                     uniform_prior(legal))


def node_state(tree: Tree, idx) -> GoState:
    return jax.tree.map(lambda x: x[idx], tree.states)


def write_state(states: GoState, idx, st: GoState) -> GoState:
    return jax.tree.map(lambda buf, v: buf.at[idx].set(v), states, st)


def allocate(engine: GoEngine, tree: Tree, parent, action,
             prior_fn=None) -> tuple[Tree, jax.Array]:
    """Materialise the child of ``(parent, action)``.

    Returns the updated tree and the new node index.  If the arena is full,
    no node is created and ``parent`` is returned (the lane then plays out
    from the parent — mirrors FUEGO refusing to grow past its memory bound).
    """
    full = tree.size >= tree.visit.shape[0]
    idx = jnp.where(full, parent, tree.size).astype(jnp.int32)

    parent_state = node_state(tree, parent)
    child_state = engine.play(parent_state, action)
    legal = engine.legal_moves(child_state)
    prior = normalize_prior(prior_fn(child_state, legal), legal) \
        if prior_fn else uniform_prior(legal)

    def do_alloc(t: Tree) -> Tree:
        return t._replace(
            children=t.children.at[parent, action].set(idx),
            parent=t.parent.at[idx].set(parent),
            action=t.action.at[idx].set(action),
            legal=t.legal.at[idx].set(legal),
            prior=t.prior.at[idx].set(prior),
            expanded=t.expanded.at[idx].set(~child_state.done),
            terminal=t.terminal.at[idx].set(child_state.done),
            states=write_state(t.states, idx, child_state),
            size=t.size + 1,
        )

    tree = jax.lax.cond(full, lambda t: t, do_alloc, tree)
    return tree, idx


def select_action(visits: jax.Array, legal: jax.Array) -> jax.Array:
    """Most-visited legal action, falling back to any legal move.

    The fallback covers tiny budgets where no legal child was explored
    (visits all zero under the mask).  Shared by ``MCTS`` and the
    distributed root-merge so every consumer picks moves identically.
    """
    masked = jnp.where(legal, visits, -1.0)
    action = jnp.argmax(masked).astype(jnp.int32)
    fallback = jnp.argmax(legal).astype(jnp.int32)
    return jnp.where(masked[action] > 0, action, fallback)


def root_action_visits(tree: Tree) -> jax.Array:
    """Visit count per root action (0 where no child)."""
    kids = tree.children[0]
    v = jnp.where(kids == UNVISITED, 0.0,
                  tree.visit[jnp.maximum(kids, 0)])
    return v


def root_action_values(tree: Tree) -> jax.Array:
    """Black-perspective mean value per root action."""
    kids = tree.children[0]
    ok = kids != UNVISITED
    idx = jnp.maximum(kids, 0)
    return jnp.where(ok, tree.value[idx] / jnp.maximum(tree.visit[idx], 1.0),
                     0.0)
