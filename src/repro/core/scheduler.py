"""Unified multi-bucket scheduler: one mesh, one pump, adaptive depth.

The per-komi ``GoService`` buckets of PR 6-9 re-created the Xeon Phi
papers' scheduling pathology in miniature: K komi buckets meant K slot
pools, K compiled dispatches, and K serialized host pump loops — cold
buckets held idle device slots while hot ones shed.  PR 10 collapses
them: the dispatch's per-slot **traced komi column**
(:class:`~repro.core.service.SearchRequest`) lets one compiled program
score every bucket, so all buckets can share one mesh-wide
:class:`~repro.core.service.SearchService` pool — and this module owns
the single pump/reconcile stream over it.

:class:`BucketScheduler` wraps exactly one
:class:`~repro.core.streaming.DispatchPipeline` (several pipelines over
one service would race the ring cursor) and adds:

* **bucket registry** — komi -> bucket, registered on first submission.
  Under a mesh, shards are partitioned round-robin over the registered
  buckets (bucket ``b`` of ``B`` owns shards ``s`` with ``s % B == b``);
  the partition is re-derived when a bucket registers, which is safe
  because it is pure host-side placement (the serve RNG contract makes
  answers placement-independent).
* **headroom borrowing** — a bucket's placement mask is its own
  partition **plus the partitions of currently idle buckets** (zero
  outstanding requests).  An idle bucket lends its shards; the moment it
  submits again it stops being idle, so *new* placements reclaim its
  shards on demand while borrowed work already in flight drains
  naturally.  ``borrowing=False`` pins every bucket strictly inside its
  partition (the bit-identity test configuration).
* **adaptive pipeline depth** — a :class:`DepthController` raises or
  lowers the in-flight superstep window from observed reconcile blocking
  and the landed-estimate lag (``SearchService.peek_landed``), clamped
  to a static ``max_depth`` so depth changes never create a new trace
  (depth is host read timing, never a compiled shape).

With one bucket, ``borrowing`` irrelevant, and a fixed depth, the
scheduler's pump/reconcile is *exactly* one pipeline's — results and
``host_syncs`` bit-identical to the per-bucket path (pinned in
tests/test_scheduler.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.placement import CLS_GAME, CLS_SERVE
from repro.core.streaming import DispatchPipeline


class DepthController:
    """Raise/lower a pipeline's in-flight window from observed timing.

    The control signal is the host's **blocking wait** at each reconcile
    (how long the oldest superstep's ring took to land after the host
    asked) plus the **landed lag** (results finished on device but not
    yet polled, from the placement policy's landed estimate):

    * wait ~ 0 with landed results backing up means the device runs
      ahead of the host — a deeper window keeps it fed, so raise;
    * wait above ``hi_wait_s`` means the device is the bottleneck and
      extra in-flight supersteps only add queueing latency, so lower;
    * anything between is the deadband: hold.

    A move needs ``patience`` *consecutive* same-direction signals, and
    the wait is EWMA-smoothed — together the hysteresis that makes the
    depth converge on a steady workload instead of oscillating
    (tests/test_scheduler.py pins clamp + convergence).  The clamp
    ``[min_depth, max_depth]`` is static: the controller only changes
    when the host reads, never what the device runs, so no depth value
    can create a new jit trace.
    """

    def __init__(self, min_depth: int = 1, max_depth: int = 4,
                 lo_wait_s: float = 2e-4, hi_wait_s: float = 2e-2,
                 ewma: float = 0.3, patience: int = 2):
        if not 1 <= min_depth <= max_depth:
            raise ValueError(
                f"need 1 <= min_depth <= max_depth, got "
                f"[{min_depth}, {max_depth}]")
        if not 0.0 <= lo_wait_s < hi_wait_s:
            raise ValueError(
                f"need 0 <= lo_wait_s < hi_wait_s, got "
                f"[{lo_wait_s}, {hi_wait_s}]")
        self.min_depth = int(min_depth)
        self.max_depth = int(max_depth)
        self.lo_wait_s = float(lo_wait_s)
        self.hi_wait_s = float(hi_wait_s)
        self.ewma = float(ewma)
        self.patience = max(1, int(patience))
        self.wait_ewma_s = 0.0
        self.adjustments = 0          # depth changes applied (telemetry)
        self._streak = 0              # signed run of one-direction signals

    def observe(self, depth: int, blocked_s: float, landed_lag: int) -> int:
        """One reconcile's evidence; returns the (possibly new) depth."""
        self.wait_ewma_s += self.ewma * (blocked_s - self.wait_ewma_s)
        if self.wait_ewma_s < self.lo_wait_s and landed_lag > 0:
            want = 1                                # device ahead: deepen
        elif self.wait_ewma_s > self.hi_wait_s:
            want = -1                               # device behind: shrink
        else:
            want = 0                                # deadband: hold
        if want == 0 or (self._streak != 0
                         and (want > 0) != (self._streak > 0)):
            self._streak = want
            return depth
        self._streak += want
        if abs(self._streak) < self.patience:
            return depth
        self._streak = 0
        new = int(np.clip(depth + want, self.min_depth, self.max_depth))
        if new != depth:
            self.adjustments += 1
        return new


class _Bucket:
    """Host bookkeeping for one komi bucket inside the shared pool."""

    __slots__ = ("komi", "index", "outstanding", "submitted", "completed")

    def __init__(self, komi: float, index: int):
        self.komi = komi
        self.index = index            # registration order (partition key)
        self.outstanding = 0
        self.submitted = 0
        self.completed = 0


class BucketScheduler:
    """One pump/reconcile stream serving every komi bucket of one pool.

    Replaces ``GoService._pipes`` (one pipeline per bucket) with a
    single :class:`DispatchPipeline` over the shared service — host
    blocked time per move no longer scales with bucket count.  The
    scheduler installs itself as the service's ``_shard_filter`` so
    placement enforces the per-bucket shard partitions (with borrowing)
    at submission time; it never touches the device program.

    ``depth`` fixes the initial window; ``adaptive=True`` lets a
    :class:`DepthController` move it inside ``[1, max_depth]``
    (``max_depth`` defaults to ``depth``).  ``steps`` is the superstep
    length, as for the pipeline.
    """

    def __init__(self, service, depth: Optional[int] = None,
                 steps: Optional[int] = None, adaptive: bool = False,
                 max_depth: Optional[int] = None, borrowing: bool = True):
        self.service = service
        self.pipe = DispatchPipeline(service, depth=depth, steps=steps)
        self.borrowing = bool(borrowing)
        self.max_depth = int(max_depth if max_depth is not None
                             else self.pipe.depth)
        if self.max_depth < self.pipe.depth:
            raise ValueError(
                f"max_depth {self.max_depth} < initial depth "
                f"{self.pipe.depth}")
        self.controller = (DepthController(max_depth=self.max_depth)
                           if adaptive else None)
        self._buckets: Dict[float, _Bucket] = {}
        self._ticket_bucket: Dict[int, float] = {}   # inner ticket -> komi
        service._shard_filter = self._allowed

    # ------------------------------------------------------------- registry

    def bucket(self, komi: float) -> _Bucket:
        """Get-or-register the bucket for ``komi`` (registration order
        fixes its shard partition slot)."""
        komi = float(komi)
        b = self._buckets.get(komi)
        if b is None:
            b = _Bucket(komi, len(self._buckets))
            self._buckets[komi] = b
        return b

    @property
    def buckets(self) -> Dict[float, _Bucket]:
        return self._buckets

    def _partition(self, index: int) -> np.ndarray:
        """Shard ownership mask of the bucket at registration ``index``.

        Round-robin over registered buckets: with ``B`` buckets and
        ``n`` shards, bucket ``b`` owns shards ``s % B == b``.  With
        more buckets than shards the partitions overlap (shard
        ``b % n``), so every bucket always owns at least one shard.
        """
        n = self.service.n_shard
        nb = max(1, len(self._buckets))
        mask = (np.arange(n) % nb) == (index % nb)
        if not mask.any():                     # more buckets than shards
            mask = np.zeros(n, bool)
            mask[index % n] = True
        return mask

    def _allowed(self, komi: float, cls: int) -> Optional[np.ndarray]:
        """The service's placement mask hook for one submission.

        Own partition, plus — when borrowing — the partitions of every
        currently idle bucket.  Unregistered komis (the engine default
        reaching a game lane, say) see every shard.
        """
        del cls
        b = self._buckets.get(float(komi))
        if b is None or self.service.n_shard == 1:
            return None
        mask = self._partition(b.index)
        if self.borrowing:
            for other in self._buckets.values():
                if other is not b and other.outstanding == 0:
                    mask = mask | self._partition(other.index)
        return mask

    # ----------------------------------------------------------- submission

    def submit_serve(self, komi: float, state, **kw) -> int:
        """Submit one serve query into ``komi``'s bucket; returns the
        service ticket.  All keyword arguments flow to
        ``SearchService.submit_serve`` (key, sims, knobs, deadline)."""
        b = self.bucket(komi)
        ticket = self.service.submit_serve(state, komi=b.komi, **kw)
        self._note_submitted(b, ticket)
        return ticket

    def submit_game(self, komi: float, **kw) -> int:
        """Submit one full game scored at ``komi``; returns the ticket."""
        b = self.bucket(komi)
        ticket = self.service.submit_game(komi=b.komi, **kw)
        self._note_submitted(b, ticket)
        return ticket

    def _note_submitted(self, b: _Bucket, ticket: int) -> None:
        b.submitted += 1
        b.outstanding += 1
        self._ticket_bucket[ticket] = b.komi

    def _retire(self, ticket: int) -> None:
        komi = self._ticket_bucket.pop(ticket, None)
        if komi is not None:
            b = self._buckets[komi]
            b.completed += 1
            b.outstanding -= 1

    def shed_expired(self, now: Optional[float] = None) -> List[int]:
        """Shed expired host-pending queries (see the service method);
        keeps the per-bucket outstanding counts honest."""
        shed = self.service.shed_expired(now)
        for t in shed:
            self._retire(t)
        return shed

    # ------------------------------------------------------ pump/reconcile

    @property
    def depth(self) -> int:
        """Current in-flight window bound (mutable host attribute)."""
        return self.pipe.depth

    @property
    def in_flight_supersteps(self) -> int:
        return self.pipe.in_flight_supersteps

    def pump(self) -> int:
        """Flush and top the single window up to the current depth."""
        return self.pipe.pump()

    def reconcile(self, block: bool = True) -> List:
        """Retire the oldest superstep across *all* buckets at once.

        Feeds the adaptive controller: the reconcile's blocking wait
        (measured via the service's ``host_blocked_s`` delta) and the
        landed lag (device-completed results not yet polled) move the
        depth inside its clamp.
        """
        svc = self.service
        before = svc.host_blocked_s
        out = self.pipe.reconcile(block=block)
        for rec in out:
            self._retire(rec.ticket)
        if self.controller is not None:
            lag = int(svc._placement.landed.sum())
            self.pipe.depth = self.controller.observe(
                self.pipe.depth, svc.host_blocked_s - before, lag)
        return out

    def run_until_drained(self, max_steps: Optional[int] = None) -> List:
        """Pump + reconcile until every submission completes."""
        out = self.pipe.run_until_drained(max_steps)
        for rec in out:
            self._retire(rec.ticket)
        return out

    # ----------------------------------------------------------- telemetry

    def bucket_stats(self) -> Dict[float, dict]:
        """Per-bucket occupancy/queue/flow counters for ``/metrics``.

        ``queue_depth`` is the bucket's outstanding request count (host
        pending + device queued/active + landed-unpolled);
        ``shards_owned`` the size of its current partition.  The
        in-flight superstep count is pool-global (one pipeline) and
        lives in :meth:`stats`.
        """
        out = {}
        for komi, b in sorted(self._buckets.items()):
            out[komi] = {
                "queue_depth": b.outstanding,
                "submitted": b.submitted,
                "completed": b.completed,
                "shards_owned": int(self._partition(b.index).sum()),
            }
        return out

    def stats(self) -> dict:
        """Scheduler-level counters (pipeline stats + depth control)."""
        s = self.pipe.stats()
        s["buckets"] = len(self._buckets)
        s["borrowing"] = self.borrowing
        s["max_depth"] = self.max_depth
        if self.controller is not None:
            s["adaptive"] = True
            s["wait_ewma_s"] = self.controller.wait_ewma_s
            s["depth_adjustments"] = self.controller.adjustments
        else:
            s["adaptive"] = False
        return s


__all__ = ["BucketScheduler", "DepthController", "CLS_GAME", "CLS_SERVE"]
