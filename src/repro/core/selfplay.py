"""Self-play effective-speedup harness (the paper's experiment).

"We have performed self-play experiments in which a version of the program
with double the resources (2x # of threads) against a version with single
resources (1x # of threads) are compared."

``match(cfg_a, cfg_b)`` plays games between two MCTS configurations with
alternating colours (the paper enables alternating player colour), scores the
match with the Heinz 95% CI, and is the backend of ``benchmarks/fig_selfplay``
(Figs. 4, 5, 9, 11) and ``launch/selfplay.py``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core import stats
from repro.go.board import BLACK, GoEngine, GoState


class GameRecord(NamedTuple):
    winner: jax.Array       # +1 black / -1 white / 0 draw
    moves: jax.Array        # game length
    tree_nodes: jax.Array   # nodes in the *last* search tree (Fig. 12 metric)


def double_resources(cfg: MCTSConfig) -> MCTSConfig:
    """The paper's 2x player: twice the threads (lanes)."""
    return dataclasses.replace(cfg, lanes=cfg.lanes * 2,
                               sims_per_move=cfg.sims_per_move * 2)


def play_game(engine: GoEngine, player_a: MCTS, player_b: MCTS,
              rng: jax.Array, a_is_black: jax.Array,
              max_moves: Optional[int] = None) -> GameRecord:
    """One full game, A vs B; jit/vmap-safe."""
    cap = max_moves or engine.max_moves

    def cond(carry):
        st, _, _, nmoves = carry
        return (~st.done) & (nmoves < cap)

    def body(carry):
        st, key, nodes, nmoves = carry
        key, ka, kb = jax.random.split(key, 3)
        black_to_move = st.to_play == BLACK
        a_to_move = black_to_move == a_is_black
        res_a = player_a.search(st, ka)
        res_b = player_b.search(st, kb)
        move = jnp.where(a_to_move, res_a.action, res_b.action)
        nodes = jnp.where(a_to_move, res_a.tree.size, res_b.tree.size)
        return engine.play(st, move), key, nodes, nmoves + 1

    st0 = engine.init_state()
    st, _, nodes, nmoves = jax.lax.while_loop(
        cond, body, (st0, rng, jnp.int32(1), jnp.int32(0)))
    return GameRecord(winner=engine.result(st), moves=nmoves,
                      tree_nodes=nodes)


class MatchResult(NamedTuple):
    a_wins: int
    b_wins: int
    draws: int
    rate: stats.WinRate          # A's win rate with 95% CI
    mean_moves: float
    mean_tree_nodes: float


def match(engine: GoEngine, cfg_a: MCTSConfig, cfg_b: MCTSConfig,
          games: int, seed: int = 0, max_moves: Optional[int] = None,
          batch: int = 0, **mcts_kw) -> MatchResult:
    """Play ``games`` games with alternating colours; batched via vmap."""
    player_a = MCTS(engine, cfg_a, **mcts_kw)
    player_b = MCTS(engine, cfg_b, **mcts_kw)
    batch = batch or games

    @jax.jit
    def run_batch(keys, a_black):
        return jax.vmap(lambda k, ab: play_game(
            engine, player_a, player_b, k, ab, max_moves))(keys, a_black)

    key = jax.random.PRNGKey(seed)
    winners, lengths, nodes, colors = [], [], [], []
    done = 0
    while done < games:
        n = min(batch, games - done)
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n)
        a_black = (jnp.arange(done, done + n) % 2) == 0   # alternate colours
        rec = run_batch(keys, a_black)
        winners.append(jax.device_get(rec.winner))
        lengths.append(jax.device_get(rec.moves))
        nodes.append(jax.device_get(rec.tree_nodes))
        colors.append(jax.device_get(a_black))
        done += n

    import numpy as np
    w = np.concatenate(winners)
    c = np.concatenate(colors)
    a_sign = np.where(c, 1, -1)
    a_res = w * a_sign                     # +1 = A won
    a_wins = int((a_res > 0).sum())
    b_wins = int((a_res < 0).sum())
    draws = int((a_res == 0).sum())
    return MatchResult(
        a_wins=a_wins, b_wins=b_wins, draws=draws,
        rate=stats.win_rate(a_wins, b_wins, draws),
        mean_moves=float(np.concatenate(lengths).mean()),
        mean_tree_nodes=float(np.concatenate(nodes).mean()),
    )


def effective_speedup_point(engine: GoEngine, base_cfg: MCTSConfig,
                            games: int, seed: int = 0,
                            **mcts_kw) -> MatchResult:
    """One data point of Figs. 4/5/11: 2n lanes vs n lanes."""
    return match(engine, double_resources(base_cfg), base_cfg, games,
                 seed=seed, **mcts_kw)
