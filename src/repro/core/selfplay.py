"""Self-play effective-speedup harness (the paper's experiment).

"We have performed self-play experiments in which a version of the program
with double the resources (2x # of threads) against a version with single
resources (1x # of threads) are compared."

``match(cfg_a, cfg_b)`` plays games between two MCTS configurations with
alternating colours (the paper enables alternating player colour), scores the
match with the Heinz 95% CI, and is the backend of ``benchmarks/fig_selfplay``
(Figs. 4, 5, 9, 11) and ``launch/selfplay.py``.

``match`` runs on the batched game arena (core/arena.py): one search per
move, finished slots refilled from a pending queue.  ``play_game`` keeps
the seed's sequential double-search semantics as the correctness oracle
(tests/test_arena.py) and the loop ``benchmarks/bench_arena.py`` times as
the throughput baseline.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.config import MCTSConfig
from repro.core.mcts import MCTS
from repro.core import stats
from repro.go.board import BLACK, GoEngine


class GameRecord(NamedTuple):
    winner: jax.Array       # +1 black / -1 white / 0 draw
    moves: jax.Array        # game length
    tree_nodes: jax.Array   # nodes in the *last* search tree (Fig. 12 metric)


def double_resources(cfg: MCTSConfig) -> MCTSConfig:
    """The paper's 2x player: twice the threads (lanes)."""
    return dataclasses.replace(cfg, lanes=cfg.lanes * 2,
                               sims_per_move=cfg.sims_per_move * 2)


def play_game(engine: GoEngine, player_a: MCTS, player_b: MCTS,
              rng: jax.Array, a_is_black: jax.Array,
              max_moves: Optional[int] = None) -> GameRecord:
    """One full game, A vs B; jit/vmap-safe.

    Oracle semantics: both players search every move and the non-mover's
    result is discarded.  The arena path plays the identical game (same
    per-move ``key -> (key, ka, kb)`` split) with one search per move.
    """
    cap = max_moves or engine.max_moves

    def cond(carry):
        st, _, _, nmoves = carry
        return (~st.done) & (nmoves < cap)

    def body(carry):
        st, key, nodes, nmoves = carry
        key, ka, kb = jax.random.split(key, 3)
        black_to_move = st.to_play == BLACK
        a_to_move = black_to_move == a_is_black
        res_a = player_a._search(st, ka)
        res_b = player_b._search(st, kb)
        move = jnp.where(a_to_move, res_a.action, res_b.action)
        nodes = jnp.where(a_to_move, res_a.tree.size, res_b.tree.size)
        return engine.play(st, move), key, nodes, nmoves + 1

    st0 = engine.init_state()
    st, _, nodes, nmoves = jax.lax.while_loop(
        cond, body, (st0, rng, jnp.int32(1), jnp.int32(0)))
    return GameRecord(winner=engine.result(st), moves=nmoves,
                      tree_nodes=nodes)


class MatchResult(NamedTuple):
    a_wins: int
    b_wins: int
    draws: int
    rate: stats.WinRate          # A's win rate with 95% CI
    mean_moves: float
    mean_tree_nodes: float


def match(engine: GoEngine, cfg_a: MCTSConfig, cfg_b: MCTSConfig,
          games: int, seed: int = 0, max_moves: Optional[int] = None,
          batch: int = 0, refill: str = "device", **mcts_kw) -> MatchResult:
    """Play ``games`` games on the batched arena, colours balanced to ±1
    (the paper's alternating-colours methodology).

    ``batch`` bounds the number of concurrent arena slots (default: one
    slot per game, the seed behaviour); finished slots are refilled from
    the pending queue so long games never stall the rest of the match.
    ``refill`` picks the SearchService device-side refill (default) or
    the PR 1 host-queue loop — the games are bit-identical either way.
    """
    from repro.core.arena import Arena

    player_a = MCTS(engine, cfg_a, **mcts_kw)
    player_b = MCTS(engine, cfg_b, **mcts_kw)
    slots = batch or games
    slots = max(2, slots + (slots % 2))          # arena needs an even count
    arena = Arena(engine, player_a, player_b, slots=slots,
                  max_moves=max_moves, refill=refill)
    recs = arena.play_games(games, seed=seed)

    import numpy as np
    w = np.array([r.winner for r in recs])
    a_sign = np.array([1.0 if r.a_is_black else -1.0 for r in recs])
    a_res = w * a_sign                     # +1 = A won
    a_wins = int((a_res > 0).sum())
    b_wins = int((a_res < 0).sum())
    draws = int((a_res == 0).sum())
    return MatchResult(
        a_wins=a_wins, b_wins=b_wins, draws=draws,
        rate=stats.win_rate(a_wins, b_wins, draws),
        mean_moves=float(np.mean([r.moves for r in recs])),
        mean_tree_nodes=float(np.mean([r.tree_nodes for r in recs])),
    )


def effective_speedup_point(engine: GoEngine, base_cfg: MCTSConfig,
                            games: int, seed: int = 0,
                            **mcts_kw) -> MatchResult:
    """One data point of Figs. 4/5/11: 2n lanes vs n lanes."""
    return match(engine, double_resources(base_cfg), base_cfg, games,
                 seed=seed, **mcts_kw)
