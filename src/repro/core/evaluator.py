"""EvalService: a jitted policy/value net batched over superstep leaves.

The Xeon Phi paper's FUEGO is playout-guided; the modern tournament
programs it benchmarks against graft a neural evaluation onto the same
MCTS skeleton.  This module is that graft, built TPU-first: instead of an
asynchronous evaluation queue (the GPU-era design, where leaf requests
wait on a host-side batcher), the dispatch superstep *is* the batcher —
every iteration of every slot's search selects its ``lanes`` leaves, and
under the ``search_batch`` vmap those form one fixed-shape ``[G, lanes]``
eval batch pushed through a small :class:`TransformerLM` as part of the
same compiled program.  No queue, no staleness beyond the iteration, no
host round-trip.

Dataflow per search iteration (see docs/ARCHITECTURE.md "Evaluation
lane" for the superstep picture):

1. the lane scan selects ``L`` leaves (new children are allocated with a
   *uniform* prior — calling the net per lane would serialise it);
2. the leaves' board states are tokenised and one net forward yields
   ``(prior [L, A], value [L])``;
3. priors scatter into the trees' ``prior`` rows (overwriting the
   allocation-time uniform) and values mix into the playout returns with
   traced weight ``value_weight * prior_w`` — so the next iteration's
   PUCT descends under net guidance.

The blend weight ``prior_w`` stays traced end to end (kernels/uct_select)
— one compiled dispatch serves guided and unguided slots, and ``w = 0``
is bit-identical to the playout-only program.

Two contracts worth reading twice:

* **Params are compile-time constants.**  ``policy_value`` closes over
  ``self.params``; a jitted search bakes them in.  After a training step
  updates them, *rebuild* the :class:`repro.core.mcts.MCTS` player (and
  any service above it) — mutating ``evaluator.params`` does not reach
  an already-compiled dispatch.
* **The evaluator is also the trainable model.**  It exposes
  ``init(key)`` and ``loss(params, batch, z_loss)`` in the shape
  ``training/step.py`` expects, so ``init_train_state(evaluator, ...)``
  / ``make_train_step(evaluator, ...)`` close the self-play loop
  (examples/selfplay_guided.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import AttnConfig, ModelConfig
from repro.core.tree import normalize_prior
from repro.go.board import GoState
from repro.models.layers import ParamDef, init_params
from repro.models.transformer import TransformerLM

# Token vocabulary for board-plane tokens: cell tokens are board + 2
# (white stone 1, empty 2, black stone 3); position 0 is a to-play
# marker token (4 = black to move, 5 = white).
TOK_WHITE, TOK_EMPTY, TOK_BLACK = 1, 2, 3
TOK_BLACK_TO_PLAY, TOK_WHITE_TO_PLAY = 4, 5
VOCAB = 8


@dataclass(frozen=True)
class EvalConfig:
    """Static shape of one evaluation net (all fields bake into the trace).

    ``num_layers`` should stay <= 2: the transformer applies
    ``jax.checkpoint`` to deeper stacks, which is a training-memory
    trade the inside-the-search forward never wants.  ``value_weight``
    is the AlphaGo lambda — the *maximum* share of a backup taken from
    the value head; the effective share is ``value_weight * prior_w``
    with the traced per-slot blend weight, so it scales to zero exactly
    when the slot is unguided.
    """
    board_size: int = 9
    d_model: int = 32
    num_layers: int = 2
    num_heads: int = 2
    d_ff: int = 64
    value_weight: float = 0.5
    ckpt_dir: Optional[str] = None
    seed: int = 0

    @classmethod
    def parse(cls, spec: str, **overrides) -> "EvalConfig":
        """Build from a ``k=v,k=v`` CLI spec (``--eval-config``).

        Unknown keys raise; values are coerced by the field's default
        type.  ``parse("d_model=64,ckpt_dir=/tmp/net", board_size=9)``.
        """
        kv = dict(overrides)
        for part in filter(None, spec.split(",")):
            if "=" not in part:
                raise ValueError(f"eval-config entry {part!r} is not k=v")
            k, v = part.split("=", 1)
            kv[k] = v
        fields = {f.name: f for f in dataclasses.fields(cls)}
        out = {}
        for k, v in kv.items():
            if k not in fields:
                raise ValueError(
                    f"unknown eval-config key {k!r}; known: {sorted(fields)}")
            d = fields[k].default
            if isinstance(v, str) and not isinstance(d, str):
                v = type(d)(v) if d is not None else v
            out[k] = v
        return cls(**out)


def _model_config(cfg: EvalConfig) -> ModelConfig:
    """The board-token transformer: tiny, encoder-style, deterministic.

    ``causal=False`` — every board token attends to the whole position;
    ``dtype=float32`` — search bit-identity tests and the ``np.save``
    checkpoint format both want exact, platform-stable arithmetic;
    ``tie_embeddings=True`` — the vocab head is never used for actions
    (the point/pass/value heads below read the V-dim output), so tying
    just drops the dead ``head`` matrix.
    """
    return ModelConfig(
        name=f"eval{cfg.board_size}", family="dense",
        num_layers=cfg.num_layers, d_model=cfg.d_model, d_ff=cfg.d_ff,
        vocab_size=VOCAB,
        attn=AttnConfig(num_heads=cfg.num_heads, num_kv_heads=cfg.num_heads,
                        causal=False),
        tie_embeddings=True,
        max_seq_len=cfg.board_size * cfg.board_size + 1,
        dtype="float32")


class EvalService:
    """Policy/value evaluation bound to one board size and one param set.

    Construction loads params from ``cfg.ckpt_dir`` (latest step) when
    given, else falls back to a deterministic random init from
    ``cfg.seed`` — a service can always come up, guided by an untrained
    net, before any training has run.

    Interface consumed by the search (all jit/vmap-safe):

    ``policy_value(states, legal)``
        batched leaf evaluation: ``([L] states, bool[L, A]) ->
        (prior f32[L, A], value f32[L])``.  Priors are exactly zero on
        illegal actions and sum to 1 over legal ones
        (:func:`repro.core.tree.normalize_prior`); values are tanh-
        bounded black-perspective estimates.
    ``prior_fn(state, legal)``
        single-state adapter with the ``MCTS.prior_fn`` signature — the
        root-init path.

    Interface consumed by training (``training/step.py``):

    ``init(key)`` / ``loss(params, batch, z_loss)`` with batches of
    ``{tokens i32[B, S], legal bool[B, A], policy f32[B, A], value
    f32[B]}`` — policy cross-entropy over legal moves plus value MSE.
    """

    def __init__(self, cfg: EvalConfig, params=None):
        self.cfg = cfg
        self.n2 = cfg.board_size * cfg.board_size
        self.num_actions = self.n2 + 1          # + pass (last index)
        self.value_weight = float(cfg.value_weight)
        self.model = TransformerLM(_model_config(cfg))
        if params is not None:
            self.params = params
        else:
            self.params = self._load_or_init()

    # ------------------------------------------------------------- params

    def _head_defs(self):
        """Action/value heads as V-dim linear probes over the LM output.

        The transformer's (tied) output is already a ``[.., S, V]``
        projection; three learned V-vectors read it out — ``point`` at
        every board position, ``pass`` and ``value`` at the to-play
        marker token.  Keeping the heads on the V axis means the
        evaluator reuses the LM forward unchanged.
        """
        return {
            "point": ParamDef((VOCAB,), (None,)),
            "pass": ParamDef((VOCAB,), (None,)),
            "value": ParamDef((VOCAB,), (None,)),
        }

    def init(self, key: jax.Array):
        """Full param tree {net, heads} (the ``training/step.py`` hook)."""
        knet, khead = jax.random.split(key)
        return {"net": self.model.init(knet),
                "heads": init_params(self._head_defs(), khead, jnp.float32)}

    def _load_or_init(self):
        from repro.ckpt.checkpoint import latest_step, restore_checkpoint
        template = self.init(jax.random.PRNGKey(self.cfg.seed))
        if self.cfg.ckpt_dir is not None \
                and latest_step(self.cfg.ckpt_dir) is not None:
            tree, _, _ = restore_checkpoint(self.cfg.ckpt_dir, template)
            return tree
        return template

    # ------------------------------------------------------------ encoding

    def tokens(self, states: GoState) -> jax.Array:
        """Board-plane tokens ``i32[..., n2 + 1]`` for a batch of states.

        Position 0 carries the side to move; positions ``1..n2`` the
        board cells.  Works under any leading batch shape (and vmap).
        """
        board = states.board.astype(jnp.int32) + 2            # [..., n2]
        to_play = jnp.where(states.to_play > 0, TOK_BLACK_TO_PLAY,
                            TOK_WHITE_TO_PLAY).astype(jnp.int32)
        return jnp.concatenate(
            [to_play[..., None], board], axis=-1)

    # ----------------------------------------------------------- inference

    def _heads(self, params, tokens):
        """tokens [B, S] -> (action logits [B, A], value [B])."""
        feats, _ = self.model.forward(params["net"], tokens)   # [B, S, V]
        h = params["heads"]
        point = feats[..., 1:, :] @ h["point"]                 # [B, n2]
        pas = feats[..., 0, :] @ h["pass"]                     # [B]
        logits = jnp.concatenate([point, pas[..., None]], axis=-1)
        value = jnp.tanh(feats[..., 0, :] @ h["value"])        # [B]
        return logits, value

    def policy_value(self, states: GoState, legal: jax.Array):
        """Batched leaf evaluation (the superstep eval batch).

        ``states`` batched over a leading ``[L]`` axis, ``legal``
        ``bool[L, A]`` -> ``(prior f32[L, A], value f32[L])``.  Inside
        ``MCTS._simulate`` this is one net forward per iteration; the
        ``search_batch`` vmap lifts it to the ``[G, L]`` superstep
        batch.
        """
        logits, value = self._heads(self.params, self.tokens(states))
        masked = jnp.where(legal, logits, -1e9)
        prior = normalize_prior(jax.nn.softmax(masked, axis=-1), legal)
        return prior, value

    def prior_fn(self, state: GoState, legal: jax.Array) -> jax.Array:
        """Single-state policy prior (the ``MCTS.prior_fn`` root hook)."""
        prior, _ = self.policy_value(
            jax.tree.map(lambda x: x[None], state), legal[None])
        return prior[0]

    # ------------------------------------------------------------ training

    def loss(self, params, batch, z_loss: float = 0.0):
        """AlphaGo-style joint loss over self-play records.

        ``batch``: ``tokens i32[B, S]``, ``legal bool[B, A]``,
        ``policy f32[B, A]`` (visit-count distribution), ``value f32[B]``
        (game outcome, black perspective).  Returns ``(scalar, metrics)``
        in the ``make_train_step`` shape; ``z_loss`` penalises the
        squared legal-move logsumexp like the LM's z-loss.
        """
        logits, value = self._heads(params, batch["tokens"])
        legal = batch["legal"]
        masked = jnp.where(legal, logits, -1e9)
        lse = jax.nn.logsumexp(masked, axis=-1)
        logp = masked - lse[..., None]
        ce = -(batch["policy"] * jnp.where(legal, logp, 0.0)).sum(-1).mean()
        mse = jnp.square(value - batch["value"]).mean()
        total = ce + mse + z_loss * jnp.square(lse).mean()
        return total, {"ce": ce, "value_mse": mse, "aux": jnp.float32(0.0)}
