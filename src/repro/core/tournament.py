"""All-play-all tournament scheduler, multiplexed through one slot pool.

The paper's self-play methodology is a single 2x-vs-1x pairing; the
tournament scheduler generalises it to the full cross table.  Because the
UCT knobs ``(c_uct, virtual_loss)`` and the playout budget ``sims`` are
*per-slot traced* through the SearchService dispatch (core/service.py),
every pairing of every configuration plays **concurrently in one pool**
under **one compiled dispatch**: a game submitted for pairing ``(i, j)``
simply carries ``(cfg_i, cfg_j)``'s knobs as per-side traced values.  This
is the Scaling-MCTS follow-up's task-parallel regime (arXiv:1507.04383) —
differently-configured searches stay resident with zero re-setup — where
the pre-PR 4 scheduler retraced (or serialised) whenever configs differed.

Scheduling: games are submitted in pair-interleaved waves (wave ``w``
holds one game of every pairing).  Colour is **targeted**, not left to
the admission cell: each game carries a forced ``a_black`` demand
(``SearchService.submit_game(a_black=...)``), chosen so that (a) within
every pairing the Black owner alternates wave to wave — the strict
per-pairing +-1 balance the per-pair pools always had, which the PR 4
multiplexed path had weakened to an aggregate cap — and (b) the A-side
colour alternates with the global submission index, so the pool-wide
colour cap (+-1 aggregate, the paper's alternating-colours rule) still
holds and forced demands can never deadlock against it.  The dispatch
side (A or B) of each config follows from those two choices instead of
a fixed per-wave role; over a pairing's games each config still sees
both sides.  Results come back origin-tagged (ticket -> pairing), and
the cross table accumulates a win matrix, per-config points, and
Bradley–Terry Elo ratings (:func:`elo_estimate` adds the
covariance/CI the league schedules on — core/league.py).

Configs that differ in *static* search shape (``lanes``, ``max_nodes``,
``parallelism``, board) cannot share a compiled search; those tournaments
transparently fall back to the per-pair pools of PR 2 (one service per
pairing).  ``multiplex=True`` asserts the one-pool path and raises if the
configs are not trace-compatible.  ``mesh=`` shards the pool over a
one-axis device mesh with ``placement``/``rebalance`` as in
core/service.py — ``placement="config_affine"`` additionally keeps a
pairing's games on the shard that last hosted its configuration.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.config import MCTSConfig
from repro.core import stats
from repro.core.mcts import MCTS
from repro.core.service import LANE_TOURNAMENT, SearchService, pad_slots
from repro.go.board import GoEngine

# MCTSConfig fields that may differ between multiplexed configs: they are
# traced through the dispatch (seed is host-side bookkeeping only).
TRACED_FIELDS = ("c_uct", "virtual_loss", "sims_per_move", "prior_weight",
                 "seed")


def trace_compatible(configs: Sequence[MCTSConfig]) -> bool:
    """True when all configs share one compiled search shape.

    Configs differing only in :data:`TRACED_FIELDS` multiplex through one
    pool; any other difference (lanes, tree capacity, board, parallelism
    mode, ...) changes the compiled program and forces per-pair pools.
    """
    strip = {f: 0 for f in TRACED_FIELDS}
    base = dataclasses.replace(configs[0], **strip)
    return all(dataclasses.replace(c, **strip) == base for c in configs[1:])


# Elo points per unit of Bradley-Terry log-strength: elo = _ELO_SCALE * beta.
_ELO_SCALE = 400.0 / math.log(10.0)


class EloEstimate(NamedTuple):
    """Bradley–Terry ratings with their uncertainty (league scheduling).

    ``cov`` is the (pseudo-inverse) Fisher-information covariance of the
    mean-centred ratings in Elo² units; ``ci`` the per-config half-width
    ``z * sqrt(diag(cov))``.  The quantity the league schedules on is
    :meth:`separation`: a pairing is *separated* once the rating gap
    exceeds ``z`` standard errors of the *difference* (which uses the
    off-diagonal covariance — two configs estimated from the same games
    are correlated, so per-config CI overlap alone over-schedules).
    """
    elo: np.ndarray       # f64[P] ratings, mean 0
    cov: np.ndarray       # f64[P,P] covariance of the ratings (Elo^2)
    ci: np.ndarray        # f64[P] z * standard error per rating
    z: float              # confidence multiplier the CIs were built at

    def separation(self, i: int, j: int) -> float:
        """Rating gap of (i, j) in standard errors of the difference."""
        gap = abs(self.elo[i] - self.elo[j])
        se = math.sqrt(max(self.cov[i, i] + self.cov[j, j]
                           - 2.0 * self.cov[i, j], 0.0))
        if se == 0.0:
            # zero variance with zero gap is *no evidence* (an empty
            # cross table), not a resolved pairing
            return math.inf if gap > 0.0 else 0.0
        return gap / se

    def separated(self, i: int, j: int) -> bool:
        """True when pairing (i, j) is resolved at this confidence."""
        return self.separation(i, j) > self.z


def _bt_fit(score: np.ndarray, games: np.ndarray,
            iters: int) -> tuple:
    """Regularised Bradley–Terry MM fit -> (strengths, s, n, played)."""
    P = score.shape[0]
    played = (games > 0) & ~np.eye(P, dtype=bool)
    s = np.where(played, score + 0.5, 0.0)
    n = np.where(played, games + 1.0, 0.0)
    w = np.ones(P)
    for _ in range(iters):
        denom = (n / (w[:, None] + w[None, :] + 1e-30)).sum(axis=1)
        w = np.where(denom > 0, s.sum(axis=1) / np.maximum(denom, 1e-30), w)
        w = w / np.exp(np.mean(np.log(np.maximum(w, 1e-30))))
    return w, s, n, played


def elo_ratings(score: np.ndarray, games: np.ndarray,
                iters: int = 200) -> np.ndarray:
    """Bradley–Terry Elo fit of a cross table (deterministic, no RNG).

    ``score[i, j]`` is i's points against j (1 per win, 0.5 per draw) and
    ``games[i, j]`` the games they played.  Each played pairing gets one
    virtual draw so perfect scores stay finite; ratings are centred on a
    mean of 0 Elo.  :func:`elo_estimate` returns the same ratings with
    their covariance/CI — the league's scheduling signal.
    """
    w, _, _, _ = _bt_fit(score, games, iters)
    elo = _ELO_SCALE * np.log(np.maximum(w, 1e-30))
    return elo - elo.mean()


def elo_estimate(score: np.ndarray, games: np.ndarray,
                 iters: int = 200, z: float = stats.Z95) -> EloEstimate:
    """:func:`elo_ratings` plus a covariance / confidence-interval estimate.

    The covariance is the Moore–Penrose pseudo-inverse of the observed
    Fisher information of the Bradley–Terry log-strengths, evaluated at
    the (virtual-draw regularised) MM fit and projected onto the
    mean-zero constraint the ratings are reported under:
    ``I[i, j] = -n_ij p_ij p_ji`` off-diagonal, row sums on the diagonal,
    with ``p_ij = w_i / (w_i + w_j)``.  An unplayed config has no
    information; its variance comes out of the pseudo-inverse as the
    largest finite value the centring allows, so its CI dominates and the
    league schedules it first.  Scaled to Elo via ``400 / ln 10``.
    """
    w, _, n, _ = _bt_fit(score, games, iters)
    ws = np.maximum(w, 1e-30)
    p = ws[:, None] / (ws[:, None] + ws[None, :])
    info = -n * p * p.T
    np.fill_diagonal(info, 0.0)
    np.fill_diagonal(info, -info.sum(axis=1))
    # pseudo-inverse: inverts information on the mean-zero subspace the
    # centred ratings live in (the all-ones direction carries none)
    cov = np.linalg.pinv(info, hermitian=True) * _ELO_SCALE ** 2
    elo = _ELO_SCALE * np.log(ws)
    elo = elo - elo.mean()
    ci = z * np.sqrt(np.maximum(np.diag(cov), 0.0))
    return EloEstimate(elo=elo, cov=cov, ci=ci, z=z)


class PairResult(NamedTuple):
    """One pairing's mini-match, from player i's perspective."""
    i: int
    j: int
    i_wins: int
    j_wins: int
    draws: int
    rate: stats.WinRate       # i's win rate with 95% CI


class TournamentResult(NamedTuple):
    """The finished cross table: per-pair records plus derived standings."""
    names: Tuple[str, ...]
    pairs: Dict[Tuple[int, int], PairResult]
    points: np.ndarray        # f64[P]: 1 per win, 0.5 per draw
    games: int                # total games played
    win_matrix: np.ndarray    # f64[P,P]: points of row vs column
    elo: np.ndarray           # f64[P]: Bradley-Terry ratings, mean 0

    def table(self) -> str:
        """Human-readable standings, best first."""
        played = np.zeros(len(self.names), np.int64)
        for (i, j), pr in self.pairs.items():
            n = pr.i_wins + pr.j_wins + pr.draws
            played[i] += n
            played[j] += n
        order = np.argsort(-self.points)
        width = max(len(n) for n in self.names)
        lines = [f"{'player':<{width}}  points  elo     games"]
        for p in order:
            lines.append(f"{self.names[p]:<{width}}  "
                         f"{self.points[p]:<6.1f}  "
                         f"{self.elo[p]:<+7.0f} {played[p]}")
        return "\n".join(lines)


class Tournament:
    """All-pairs round-robin between MCTS configurations, one shared pool.

    Static-vs-traced contract: the slot count, superstep, mesh shape, and
    the configs' shared search shape compile **once**; each game's
    ``(c_uct, virtual_loss, sims, prior_weight)`` ride through the
    dispatch as traced per-slot values, so a tournament over N
    trace-compatible configs
    costs exactly one compilation regardless of N (pinned in
    tests/test_multiplex.py).  ``multiplex=None`` auto-detects
    compatibility; ``False`` forces the legacy per-pair pools.
    """

    def __init__(self, engine: GoEngine, configs: Sequence[MCTSConfig],
                 names: Optional[Sequence[str]] = None,
                 games_per_pair: int = 2, slots: int = 0,
                 max_moves: Optional[int] = None, seed: int = 0,
                 superstep: int = 4, mesh=None,
                 placement: str = "round_robin", rebalance: bool = True,
                 multihop: bool = True, pipeline_depth: int = 1,
                 multiplex: Optional[bool] = None, **mcts_kw):
        if len(configs) < 2:
            raise ValueError("tournament needs at least 2 configs")
        if names is not None and len(names) != len(configs):
            raise ValueError("names must match configs")
        compatible = trace_compatible(configs)
        if multiplex and not compatible:
            raise ValueError(
                "multiplex=True needs trace-compatible configs: only "
                f"{TRACED_FIELDS} may differ (lanes/max_nodes/board/"
                "parallelism change the compiled search shape)")
        self.multiplex = compatible if multiplex is None else bool(multiplex)
        self.engine = engine
        self.configs = list(configs)
        self.names = tuple(names) if names is not None else tuple(
            f"cfg{i}:{c.lanes}x{c.sims_per_move}"
            for i, c in enumerate(configs))
        self.games_per_pair = games_per_pair
        self.n_pairs = len(configs) * (len(configs) - 1) // 2
        slots = slots or min(games_per_pair *
                             (self.n_pairs if self.multiplex else 1), 8)
        self.mesh = mesh
        self.placement = placement
        self.rebalance = rebalance
        self.multihop = multihop
        self.pipeline_depth = pipeline_depth
        # pools shard over the mesh: pad the slot count so every shard
        # gets an even share (the legacy path reuses this shape per pair)
        self.slots = pad_slots(slots, mesh)
        self.max_moves = max_moves
        self.seed = seed
        self.superstep = superstep
        self.mcts_kw = mcts_kw
        self.host_syncs = 0
        self.service: Optional[SearchService] = None   # multiplexed pool

    # ------------------------------------------------------------ scheduling

    def round_robin(self) -> TournamentResult:
        """Play the full cross table; one pool when trace-compatible."""
        P = len(self.configs)
        self.host_syncs = 0
        if self.multiplex:
            per_pair = self._round_robin_multiplexed()
        else:
            per_pair = self._round_robin_paired()
        points = np.zeros(P)
        win = np.zeros((P, P))
        games = np.zeros((P, P))
        pairs: Dict[Tuple[int, int], PairResult] = {}
        total = 0
        for (i, j), (iw, jw, dr) in per_pair.items():
            pairs[(i, j)] = PairResult(
                i=i, j=j, i_wins=iw, j_wins=jw, draws=dr,
                rate=stats.win_rate(iw, jw, dr))
            points[i] += iw + 0.5 * dr
            points[j] += jw + 0.5 * dr
            win[i, j] = iw + 0.5 * dr
            win[j, i] = jw + 0.5 * dr
            games[i, j] = games[j, i] = iw + jw + dr
            total += iw + jw + dr
        return TournamentResult(names=self.names, pairs=pairs,
                                points=points, games=total,
                                win_matrix=win,
                                elo=elo_ratings(win, games))

    def _round_robin_multiplexed(self) -> Dict[Tuple[int, int],
                                               Tuple[int, int, int]]:
        """Every pairing in flight at once through one compiled pool.

        The shared players' static shape is ``configs[0]`` with the
        *maximum* playout budget (the compiled loop bound — smaller
        per-game budgets mask the tail); each game carries its pairing's
        traced knobs.  Wave ``w`` submits one game per pairing; the
        Black owner of pairing ``n`` alternates with ``w + n`` (strict
        per-pairing +-1, staggered across pairings) and the forced
        ``a_black`` flag alternates with the submission index (so the
        aggregate colour cap is consumed exactly alternately and the
        forced demands can never starve against it).
        """
        cfgs = self.configs
        shared = dataclasses.replace(
            cfgs[0], sims_per_move=max(c.sims_per_move for c in cfgs))
        player = MCTS(self.engine, shared, **self.mcts_kw)
        svc = SearchService(self.engine, player, player, self.slots,
                            max_moves=self.max_moves,
                            superstep=self.superstep, mesh=self.mesh,
                            placement=self.placement,
                            rebalance=self.rebalance,
                            multihop=self.multihop,
                            pipeline_depth=self.pipeline_depth)
        self.service = svc
        pair_list = list(itertools.combinations(range(len(cfgs)), 2))
        total = self.games_per_pair * len(pair_list)
        svc.reset(seed=self.seed, colour_cap=(total + 1) // 2,
                  game_capacity=total, ring_capacity=total + self.slots)
        meta: Dict[int, Tuple[int, int, int]] = {}  # ticket -> (i, j, a_side)
        g = 0                                       # global submission index
        for wave in range(self.games_per_pair):
            for n, (i, j) in enumerate(pair_list):
                black = i if (wave + n) % 2 == 0 else j
                a_black = g % 2 == 0
                a = black if a_black else (j if black == i else i)
                b = j if a == i else i
                t = svc.submit_game(
                    lane=LANE_TOURNAMENT,
                    sims=(cfgs[a].sims_per_move, cfgs[b].sims_per_move),
                    c_uct=(cfgs[a].c_uct, cfgs[b].c_uct),
                    virtual_loss=(cfgs[a].virtual_loss,
                                  cfgs[b].virtual_loss),
                    prior_weight=(cfgs[a].prior_weight,
                                  cfgs[b].prior_weight),
                    a_black=a_black)
                meta[t] = (i, j, a)
                g += 1
        recs = svc.drain()
        self.host_syncs += svc.host_syncs
        out = {p: [0, 0, 0] for p in pair_list}
        for r in recs:
            i, j, a_side = meta[r.ticket]
            # +1 = the A-side config won (A owns Black iff a_is_black)
            a_score = r.winner * (1.0 if r.a_is_black else -1.0)
            i_score = a_score if a_side == i else -a_score
            out[(i, j)][0 if i_score > 0 else 1 if i_score < 0 else 2] += 1
        return {p: tuple(v) for p, v in out.items()}

    def _round_robin_paired(self) -> Dict[Tuple[int, int],
                                          Tuple[int, int, int]]:
        """Legacy fallback: one pool per pairing (static-shape configs)."""
        P = len(self.configs)
        out = {}
        for n, (i, j) in enumerate(itertools.combinations(range(P), 2)):
            out[(i, j)] = self._play_pair(i, j, seed=self.seed + 1000 * n)
        return out

    def _play_pair(self, i: int, j: int,
                   seed: int) -> Tuple[int, int, int]:
        g = self.games_per_pair
        player_i = MCTS(self.engine, self.configs[i], **self.mcts_kw)
        player_j = MCTS(self.engine, self.configs[j], **self.mcts_kw)
        svc = SearchService(self.engine, player_i, player_j, self.slots,
                            max_moves=self.max_moves,
                            superstep=self.superstep, mesh=self.mesh,
                            placement=self.placement,
                            rebalance=self.rebalance,
                            multihop=self.multihop,
                            pipeline_depth=self.pipeline_depth)
        svc.reset(seed=seed, colour_cap=(g + 1) // 2, game_capacity=g,
                  ring_capacity=g + self.slots)
        for _ in range(g):
            svc.submit_game(lane=LANE_TOURNAMENT)
        recs = svc.drain()
        self.host_syncs += svc.host_syncs
        # +1 = player i won (i is "player A": owns Black where a_is_black)
        i_res = [r.winner * (1.0 if r.a_is_black else -1.0) for r in recs]
        i_wins = sum(1 for v in i_res if v > 0)
        j_wins = sum(1 for v in i_res if v < 0)
        draws = sum(1 for v in i_res if v == 0)
        return (i_wins, j_wins, draws)
