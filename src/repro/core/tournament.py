"""Round-robin tournament scheduler on the SearchService dispatcher.

The paper's self-play methodology is a single 2x-vs-1x pairing; the
tournament scheduler generalises it to the full cross table the ROADMAP
calls for: every unordered pair of configurations plays a colour-balanced
mini-match, and all games flow through one SearchService slot pool
(``LANE_TOURNAMENT`` tickets) — the same admission-controlled dispatch
that serves self-play and external queries.

Pairs are scheduled through the pool round-robin.  Search shapes (lanes,
budget) are *static* to the compiled dispatch, so every pair compiles its
own dispatch step (each pairing binds fresh players, and a jitted bound
method owns its own cache — making same-shape pairs share one compiled
program needs the per-slot traced (c_uct, virtual_loss) follow-up in the
ROADMAP).  Within a pair, games run concurrently across the pool's slots
with device-side refill and colour balance +-1 (the paper's
alternating-colours methodology).  ``mesh=`` shards each pair's pool over
a one-axis device mesh (slot counts are padded to an even per-shard
share), with ``placement``/``rebalance`` as in core/service.py.
"""
from __future__ import annotations

import itertools
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.config import MCTSConfig
from repro.core import stats
from repro.core.mcts import MCTS
from repro.core.service import LANE_TOURNAMENT, SearchService, pad_slots
from repro.go.board import GoEngine


class PairResult(NamedTuple):
    """One pairing's mini-match, from player i's perspective."""
    i: int
    j: int
    i_wins: int
    j_wins: int
    draws: int
    rate: stats.WinRate       # i's win rate with 95% CI


class TournamentResult(NamedTuple):
    names: Tuple[str, ...]
    pairs: Dict[Tuple[int, int], PairResult]
    points: np.ndarray        # f64[P]: 1 per win, 0.5 per draw
    games: int                # total games played

    def table(self) -> str:
        """Human-readable standings, best first."""
        played = np.zeros(len(self.names), np.int64)
        for (i, j), pr in self.pairs.items():
            n = pr.i_wins + pr.j_wins + pr.draws
            played[i] += n
            played[j] += n
        order = np.argsort(-self.points)
        width = max(len(n) for n in self.names)
        lines = [f"{'player':<{width}}  points  games"]
        for p in order:
            lines.append(f"{self.names[p]:<{width}}  "
                         f"{self.points[p]:<6.1f}  {played[p]}")
        return "\n".join(lines)


class Tournament:
    """All-pairs round-robin between MCTS configurations, one shared pool."""

    def __init__(self, engine: GoEngine, configs: Sequence[MCTSConfig],
                 names: Optional[Sequence[str]] = None,
                 games_per_pair: int = 2, slots: int = 0,
                 max_moves: Optional[int] = None, seed: int = 0,
                 superstep: int = 4, mesh=None,
                 placement: str = "round_robin", rebalance: bool = True,
                 **mcts_kw):
        if len(configs) < 2:
            raise ValueError("tournament needs at least 2 configs")
        if names is not None and len(names) != len(configs):
            raise ValueError("names must match configs")
        self.engine = engine
        self.configs = list(configs)
        self.names = tuple(names) if names is not None else tuple(
            f"cfg{i}:{c.lanes}x{c.sims_per_move}"
            for i, c in enumerate(configs))
        self.games_per_pair = games_per_pair
        slots = slots or min(games_per_pair, 8)
        self.mesh = mesh
        self.placement = placement
        self.rebalance = rebalance
        # pools shard over the mesh: pad the slot count so every shard
        # gets an even share (each pair's pool reuses this shape)
        self.slots = pad_slots(slots, mesh)
        self.max_moves = max_moves
        self.seed = seed
        self.superstep = superstep
        self.mcts_kw = mcts_kw
        self.host_syncs = 0

    def round_robin(self) -> TournamentResult:
        """Play every pair's mini-match through the service pool."""
        P = len(self.configs)
        points = np.zeros(P)
        pairs: Dict[Tuple[int, int], PairResult] = {}
        total = 0
        self.host_syncs = 0
        for n, (i, j) in enumerate(itertools.combinations(range(P), 2)):
            pair = self._play_pair(i, j, seed=self.seed + 1000 * n)
            pairs[(i, j)] = pair
            points[i] += pair.i_wins + 0.5 * pair.draws
            points[j] += pair.j_wins + 0.5 * pair.draws
            total += pair.i_wins + pair.j_wins + pair.draws
        return TournamentResult(names=self.names, pairs=pairs,
                                points=points, games=total)

    def _play_pair(self, i: int, j: int, seed: int) -> PairResult:
        g = self.games_per_pair
        player_i = MCTS(self.engine, self.configs[i], **self.mcts_kw)
        player_j = MCTS(self.engine, self.configs[j], **self.mcts_kw)
        svc = SearchService(self.engine, player_i, player_j, self.slots,
                            max_moves=self.max_moves,
                            superstep=self.superstep, mesh=self.mesh,
                            placement=self.placement,
                            rebalance=self.rebalance)
        svc.reset(seed=seed, colour_cap=(g + 1) // 2, game_capacity=g,
                  ring_capacity=g + self.slots)
        for _ in range(g):
            svc.submit_game(lane=LANE_TOURNAMENT)
        recs = svc.drain()
        self.host_syncs += svc.host_syncs
        # +1 = player i won (i is "player A": owns Black where a_is_black)
        i_res = [r.winner * (1.0 if r.a_is_black else -1.0) for r in recs]
        i_wins = sum(1 for v in i_res if v > 0)
        j_wins = sum(1 for v in i_res if v < 0)
        draws = sum(1 for v in i_res if v == 0)
        return PairResult(i=i, j=j, i_wins=i_wins, j_wins=j_wins,
                          draws=draws,
                          rate=stats.win_rate(i_wins, j_wins, draws))
