"""Streaming dispatch pipeline: overlap host I/O with device supersteps.

The Xeon Phi papers' knee past 32 threads is a *coordination* failure:
the host barriers on every batch of search work, so compute idles while
requests are marshalled.  The PR 2-4 dispatcher kept exactly that shape —
``flush() -> dispatch() -> poll()`` in strict sequence per superstep.
:class:`DispatchPipeline` breaks the barrier: it keeps up to ``depth``
supersteps in flight (JAX async dispatch makes ``dispatch`` an enqueue,
not a wait), capturing a :class:`~repro.core.service.RingView` back
buffer per superstep, and reconciles the oldest view while the device
runs the younger ones.  Host-side work — unpacking results, placement
bookkeeping, packing and flushing new submissions — happens while the
device computes, which is precisely the host<->device transfer overlap
the Phi offload studies identify as the first-order lever.

Contracts:

* ``depth=1`` *is* the synchronous path: one superstep in flight, its
  view reconciled immediately — bit-identical results, syncs, and
  bookkeeping (pinned in tests/test_pipeline.py);
* results are ticket-tagged and order-independent (see
  ``service.SearchResult``): a drain's result *set* is depth-invariant,
  and for submit-then-drain workloads the result *sequence* is too,
  because the device program never depends on host read timing;
* at every reconcile ``submitted == completed + in_flight + shed`` — the
  pipeline checks the service's accounting (including requests the
  serving tier shed before they flushed) and raises on drift;
* a ``service.reset()`` invalidates the window: stale views are evicted,
  never polled.
"""
from __future__ import annotations

import collections
from typing import List, Optional

from repro.compat import array_is_ready


class DispatchPipeline:
    """Keeps up to ``depth`` supersteps in flight over one SearchService.

    The pipeline owns no device state: it is a host-side window of
    :class:`~repro.core.service.RingView` completion handles plus the
    pump/reconcile policy.  ``pump()`` flushes submissions and tops the
    window up; ``reconcile()`` retires the oldest superstep and returns
    its new results; ``run_until_drained()`` alternates the two until
    every submission completes.  Several pipelines over one service are
    not supported (they would race the ring read cursor) — use one
    pipeline per service, as ``SearchService.drain`` and ``GoService``
    do.
    """

    def __init__(self, service, depth: Optional[int] = None,
                 steps: Optional[int] = None):
        self.service = service
        self.depth = int(service.pipeline_depth if depth is None else depth)
        self.steps = int(service.superstep if steps is None else steps)
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        self._window = collections.deque()      # oldest superstep first
        self.reconciles = 0
        self.steps_issued = 0
        self.max_in_flight = 0

    @property
    def in_flight_supersteps(self) -> int:
        """Issued but not yet reconciled supersteps (<= depth)."""
        return len(self._window)

    def pump(self) -> int:
        """Flush submissions and top the in-flight window up to depth.

        Every issue is an async enqueue — the host returns immediately
        holding the superstep's ring back buffer.  While the window is
        deep, also refresh the placement policy's landed-occupancy
        estimate (non-blocking; see ``SearchService.peek_landed``).
        Returns the number of supersteps issued.
        """
        svc = self.service
        self._evict_stale()
        svc.flush()
        issued = 0
        while len(self._window) < self.depth and svc.outstanding > 0:
            self._window.append(svc.dispatch_async(self.steps))
            self.steps_issued += self.steps
            issued += 1
        self.max_in_flight = max(self.max_in_flight, len(self._window))
        if self.depth > 1 and self._window:
            svc.peek_landed()
        return issued

    def reconcile(self, block: bool = True) -> List:
        """Retire the oldest in-flight superstep; return its new results.

        Blocks only until *that* superstep's computation lands (its ring
        view is a back buffer no younger superstep touches).  With
        ``block=False`` returns ``[]`` instead of waiting when the
        oldest superstep has not finished yet.  At depth 1 the view is
        the live ring, so the poll keeps the synchronous path's
        scale-with-new-results gather; deeper windows read the snapshot
        raw to stay off the device queue.  Raises if the service's
        request accounting drifted (``submitted != completed +
        in_flight``).
        """
        self._evict_stale()
        if not self._window:
            return []
        head = self._window[0]
        if not block and not array_is_ready(head.ring.count):
            return []
        self._window.popleft()
        out = self.service.poll(view=head if self.depth > 1 else None)
        self.reconciles += 1
        submitted, completed, in_flight = self.service.accounting()
        shed = self.service.shed_total
        if submitted != completed + in_flight + shed:
            raise RuntimeError(
                f"in-flight accounting drifted at reconcile "
                f"{self.reconciles}: {submitted} submitted != "
                f"{completed} completed + {in_flight} in flight + "
                f"{shed} shed")
        return out

    def _evict_stale(self) -> None:
        """Drop views issued before the service's last reset()."""
        while self._window and self._window[0].epoch != self.service.epoch:
            self._window.popleft()

    def stats(self) -> dict:
        """Counters for benchmarks: depth, in-flight high-water, steps."""
        return {"depth": self.depth, "steps_per_superstep": self.steps,
                "max_in_flight": self.max_in_flight,
                "reconciles": self.reconciles,
                "steps_issued": self.steps_issued}

    def run_until_drained(self, max_steps: Optional[int] = None) -> List:
        """Pump + reconcile until every submission completes.

        The drain loop of the dispatcher: with depth 1 this is exactly
        the synchronous ``flush -> dispatch -> poll`` sequence; deeper
        windows keep the device ``depth`` supersteps ahead of the host.
        ``max_steps`` bounds the issued dispatch steps (default scales
        with the outstanding work) and a stall raises.
        """
        svc = self.service
        svc.flush()
        budget = max_steps or (svc.outstanding * (svc.max_moves + 2)
                               + 2 * svc.slots + 16
                               + self.depth * self.steps)
        out: List = []
        while svc.outstanding > 0:
            if self.steps_issued > budget:
                raise RuntimeError(
                    f"DispatchPipeline stalled: {svc.outstanding} requests "
                    f"still outstanding after {self.steps_issued} steps")
            self.pump()
            out.extend(self.reconcile(block=True))
        self._window.clear()
        return out
