"""Cross-version and cross-backend JAX API shims.

The repo targets the new-style ``jax.shard_map`` surface (``check_vma`` /
``axis_names``).  Older JAX releases (<= 0.4.x) only ship
``jax.experimental.shard_map.shard_map`` whose equivalent knobs are
``check_rep`` and ``auto`` (the *complement* of ``axis_names``).  Every
shard_map call in the codebase goes through :func:`shard_map` below so the
version split lives in exactly one place.

The streaming dispatch pipeline (core/service.py + core/streaming.py)
additionally needs two capabilities that vary by backend/version:

* **buffer donation** — :func:`donate_jit` applies ``donate_argnums``
  only where XLA implements input-output aliasing (GPU/TPU); on CPU the
  donation would be silently unusable and warn per compile, so the shim
  degrades to a plain ``jax.jit``;
* **non-blocking readiness** — :func:`array_is_ready` answers "has this
  array's producing computation finished?" without forcing a sync, via
  ``jax.Array.is_ready`` where it exists and a conservative ``True``
  fallback (callers then pay an ordinary blocking fetch, which is always
  correct).
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Set

import jax
import numpy as np

# backends whose XLA compiler implements input-output aliasing, making
# jit buffer donation effective rather than a per-compile warning
DONATION_BACKENDS = ("gpu", "tpu", "cuda", "rocm")

# New-style shard_map supports partial-auto (``axis_names`` manual subsets).
# The old experimental API has an ``auto=`` argument, but its XLA lowering
# path crashes on non-trivial programs (manual-subgroup check failures), so
# callers needing partial-auto must provide a full-manual fallback.
HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f: Callable, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: Optional[bool] = None,
              axis_names: Optional[Set[str]] = None) -> Callable:
    """``jax.shard_map`` with new-style kwargs on any supported JAX.

    ``axis_names`` — axes the body is *manual* over (new API).  On old JAX
    this becomes ``auto = mesh.axis_names - axis_names``.  ``check_vma``
    maps to the old ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)

    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def donate_jit(fn: Callable, donate_argnums: Sequence[int] = (),
               static_argnums: Sequence[int] = ()) -> Callable:
    """``jax.jit`` with buffer donation where the backend implements it.

    On :data:`DONATION_BACKENDS` the listed arguments are donated (their
    buffers alias the outputs — the dispatch pipeline's slot pool reuses
    its device memory across supersteps instead of allocating per call).
    On CPU, XLA ignores donation and warns on every compile, so the shim
    returns an undonated jit — bit-identical results, no warning spam.

    Callers must treat donated arguments as consumed either way: never
    hold a reference to a donated input across the call (the streaming
    ring snapshots exist precisely because the result ring is *excluded*
    from donation, see core/service.py).
    """
    if jax.default_backend() in DONATION_BACKENDS:
        return jax.jit(fn, static_argnums=tuple(static_argnums),
                       donate_argnums=tuple(donate_argnums))
    return jax.jit(fn, static_argnums=tuple(static_argnums))


def array_is_ready(x: Any) -> bool:
    """True when ``x``'s producing computation has already finished.

    Non-blocking: used by the dispatch pipeline's ``reconcile(block=
    False)`` to skip a not-yet-landed superstep without forcing a host
    sync.  JAX grew ``jax.Array.is_ready`` in the 0.4.x line; where it
    is missing the shim answers ``True`` — suitable only for callers
    about to issue the blocking fetch anyway (a caller that must *never*
    block, like ``SearchService.peek_landed``, checks for the native
    method itself and skips instead).
    """
    is_ready = getattr(x, "is_ready", None)
    if is_ready is None:
        return True
    return bool(is_ready())


def make_service_mesh(n_shard: int, axis: str = "shard",
                      devices: Optional[Sequence[Any]] = None):
    """A one-axis mesh over the first ``n_shard`` devices.

    The SearchService shards its slot pool over exactly one mesh axis;
    this helper builds that mesh portably (``jax.make_mesh`` only grew a
    ``devices=`` argument after 0.4.x, and always wants every device).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    if not 1 <= n_shard <= len(devices):
        raise ValueError(f"need 1 <= n_shard <= {len(devices)} available "
                         f"device(s), got {n_shard}")
    return jax.sharding.Mesh(np.asarray(devices[:n_shard]), (axis,))
