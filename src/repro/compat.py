"""Cross-version JAX API shims.

The repo targets the new-style ``jax.shard_map`` surface (``check_vma`` /
``axis_names``).  Older JAX releases (<= 0.4.x) only ship
``jax.experimental.shard_map.shard_map`` whose equivalent knobs are
``check_rep`` and ``auto`` (the *complement* of ``axis_names``).  Every
shard_map call in the codebase goes through :func:`shard_map` below so the
version split lives in exactly one place.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Set

import jax
import numpy as np

# New-style shard_map supports partial-auto (``axis_names`` manual subsets).
# The old experimental API has an ``auto=`` argument, but its XLA lowering
# path crashes on non-trivial programs (manual-subgroup check failures), so
# callers needing partial-auto must provide a full-manual fallback.
HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f: Callable, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: Optional[bool] = None,
              axis_names: Optional[Set[str]] = None) -> Callable:
    """``jax.shard_map`` with new-style kwargs on any supported JAX.

    ``axis_names`` — axes the body is *manual* over (new API).  On old JAX
    this becomes ``auto = mesh.axis_names - axis_names``.  ``check_vma``
    maps to the old ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)

    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def make_service_mesh(n_shard: int, axis: str = "shard",
                      devices: Optional[Sequence[Any]] = None):
    """A one-axis mesh over the first ``n_shard`` devices.

    The SearchService shards its slot pool over exactly one mesh axis;
    this helper builds that mesh portably (``jax.make_mesh`` only grew a
    ``devices=`` argument after 0.4.x, and always wants every device).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    if not 1 <= n_shard <= len(devices):
        raise ValueError(f"need 1 <= n_shard <= {len(devices)} available "
                         f"device(s), got {n_shard}")
    return jax.sharding.Mesh(np.asarray(devices[:n_shard]), (axis,))
